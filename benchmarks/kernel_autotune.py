"""Kernel block autotuning benchmark: measured winners vs the static
analytic plan, on the paper's two workload regimes.

    PYTHONPATH=src python benchmarks/kernel_autotune.py [--smoke]

* **rmsnorm** — the compute-bound regime: one fused pass over the rows,
  cost dominated by the per-block arithmetic;
* **flash attention** — the memory-bound regime: blocked K/V streaming
  through VMEM, cost dominated by tile traffic.

For each workload the ``KernelTuner`` wall-clocks candidate blocks
seeded from the analytic prior (``tuning.plan_1d`` /
``tuning.plan_attention``) and persists the winner through the
calibration store.  Reported speedup is *measured winner vs measured
prior from the same search harness* — the winner is the argmin over a
candidate set that contains the prior, so tuned >= 1.0x static is the
invariant the paper's argument rests on (an independent re-timing of
both plans is also reported).  A second tuner over the same store then
re-resolves every plan and must run **zero** searches: that is the
persistence claim (later processes skip the search).

Emits ``BENCH_kernel_autotune.json`` next to the calibration JSON
(``calibration_kernel_autotune.json``); CI uploads both as artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.calibration import CalibrationCache  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import tuning  # noqa: E402
from repro.kernels.autotune import KernelTuner  # noqa: E402

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def best_of(fn, repeats: int) -> float:
    fn()  # warm (compile already paid, but keep the discipline)
    best = float("inf")
    for _ in range(repeats):
        t = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t)
    return best


def bench_rmsnorm(tuner: KernelTuner, *, rows: int, d: int,
                  repeats: int) -> dict:
    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.randn(rows, d).astype(np.float32))
    g = jnp.asarray(rs.randn(d).astype(np.float32))

    static_block = min(128, max(8, rows))
    out_t = kops.rmsnorm(x, g, tuner=tuner)          # triggers the search
    rep = tuner.reports[-1]
    tuned_block = rep.winner[0]
    out_s = kops.rmsnorm(x, g, block_rows=static_block)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_s),
                               rtol=1e-5, atol=1e-5)

    t_static = best_of(lambda: jax.block_until_ready(
        kops.rmsnorm(x, g, block_rows=static_block)), repeats)
    t_tuned = t_static if tuned_block == static_block else best_of(
        lambda: jax.block_until_ready(
            kops.rmsnorm(x, g, block_rows=tuned_block)), repeats)
    return {
        "workload": "rmsnorm", "regime": "compute-bound",
        "shape": [rows, d],
        "static_block": static_block, "tuned_block": tuned_block,
        "search_static_s": rep.prior_seconds,
        "search_tuned_s": rep.winner_seconds,
        "speedup_search": round(rep.prior_seconds / rep.winner_seconds, 3)
        if rep.measured and rep.winner_seconds else 1.0,
        "retimed_static_s": t_static, "retimed_tuned_s": t_tuned,
        "speedup_retimed": round(t_static / t_tuned, 3) if t_tuned else 1.0,
        "candidates": len(rep.timings),
    }


def bench_attention(tuner: KernelTuner, *, b: int, h: int, sq: int,
                    skv: int, d: int, repeats: int) -> dict:
    rs = np.random.RandomState(1)
    q = jnp.asarray(rs.randn(b, h, sq, d).astype(np.float32))
    k = jnp.asarray(rs.randn(b, h, skv, d).astype(np.float32))
    v = jnp.asarray(rs.randn(b, h, skv, d).astype(np.float32))

    sbq, sbk = tuning.plan_attention(sq, skv, d, bytes_per_elem=4)
    sbq, sbk = min(sbq, max(8, sq)), min(sbk, max(128, skv))
    out_t = kops.flash_attention(q, k, v, causal=True, tuner=tuner)
    rep = tuner.reports[-1]
    tbq, tbk = rep.winner
    out_s = kops.flash_attention(q, k, v, causal=True,
                                 block_q=sbq, block_kv=sbk)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_s),
                               rtol=2e-4, atol=2e-4)

    t_static = best_of(lambda: jax.block_until_ready(kops.flash_attention(
        q, k, v, causal=True, block_q=sbq, block_kv=sbk)), repeats)
    t_tuned = t_static if (tbq, tbk) == (sbq, sbk) else best_of(
        lambda: jax.block_until_ready(kops.flash_attention(
            q, k, v, causal=True, block_q=tbq, block_kv=tbk)), repeats)
    return {
        "workload": "flash_attention", "regime": "memory-bound",
        "shape": [b, h, sq, skv, d],
        "static_block": [sbq, sbk], "tuned_block": [tbq, tbk],
        "search_static_s": rep.prior_seconds,
        "search_tuned_s": rep.winner_seconds,
        "speedup_search": round(rep.prior_seconds / rep.winner_seconds, 3)
        if rep.measured and rep.winner_seconds else 1.0,
        "retimed_static_s": t_static, "retimed_tuned_s": t_tuned,
        "speedup_retimed": round(t_static / t_tuned, 3) if t_tuned else 1.0,
        "candidates": len(rep.timings),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI: prove the loop closes")
    ap.add_argument("--cal-file", default=os.path.join(
        REPO, "calibration_kernel_autotune.json"))
    ap.add_argument("--out", default=os.path.join(
        REPO, "BENCH_kernel_autotune.json"))
    ap.add_argument("--fresh", action="store_true",
                    help="delete the calibration file first (force search)")
    args = ap.parse_args()

    if args.fresh and os.path.exists(args.cal_file):
        os.remove(args.cal_file)
    repeats = 2 if args.smoke else 5
    shapes = dict(
        rmsnorm=dict(rows=256 if args.smoke else 2048,
                     d=256 if args.smoke else 1024, repeats=repeats),
        attention=dict(b=1, h=2 if args.smoke else 4,
                       sq=64 if args.smoke else 256,
                       skv=64 if args.smoke else 256,
                       d=32 if args.smoke else 64, repeats=repeats),
    )

    tuner = KernelTuner(CalibrationCache(args.cal_file),
                        repeats=repeats)
    print(f"kernel autotune [{'smoke' if args.smoke else 'full'}] "
          f"hw={tuner.hardware} store={args.cal_file}")
    results = [bench_rmsnorm(tuner, **shapes["rmsnorm"]),
               bench_attention(tuner, **shapes["attention"])]
    for r in results:
        print(f"  {r['workload']:16s} ({r['regime']:13s}) "
              f"static {r['static_block']} -> tuned {r['tuned_block']} | "
              f"search {r['speedup_search']:.2f}x | "
              f"retimed {r['speedup_retimed']:.2f}x")

    # Second run, same process: a fresh tuner over a fresh cache object
    # bound to the same file must answer every plan from the persisted
    # winners — zero searches.  Plan resolution only; no re-timing.
    tuner2 = KernelTuner(CalibrationCache(args.cal_file), repeats=repeats)
    rs = np.random.RandomState(2)
    kops.rmsnorm(
        jnp.asarray(rs.randn(shapes["rmsnorm"]["rows"],
                             shapes["rmsnorm"]["d"]).astype(np.float32)),
        jnp.ones((shapes["rmsnorm"]["d"],)), tuner=tuner2)
    a = shapes["attention"]
    kops.flash_attention(
        jnp.asarray(rs.randn(a["b"], a["h"], a["sq"], a["d"])
                    .astype(np.float32)),
        jnp.asarray(rs.randn(a["b"], a["h"], a["skv"], a["d"])
                    .astype(np.float32)),
        jnp.asarray(rs.randn(a["b"], a["h"], a["skv"], a["d"])
                    .astype(np.float32)),
        causal=True, tuner=tuner2)
    print(f"  second run: {tuner2.searches} searches "
          f"({tuner2.cache_hits} persisted winners reused)")

    ok = all(r["speedup_search"] >= 1.0 for r in results) \
        and tuner2.searches == 0
    blob = {
        "results": results,
        "first_run_searches": tuner.searches,
        "second_run_searches": tuner2.searches,
        "second_run_reused": tuner2.cache_hits,
        "hardware": tuner.hardware,
        "calibration_file": os.path.abspath(args.cal_file),
        "smoke": bool(args.smoke),
        "ok": ok,
    }
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"-> {os.path.abspath(args.out)}")
    if not ok:
        print("FAIL: tuned below static or persisted winners not reused")
        sys.exit(1)


if __name__ == "__main__":
    main()
