"""Benchmark harness: one function per paper table/figure plus the
framework's own microbenches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--skip-wallclock]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-wallclock", action="store_true",
                    help="model-based figures only (fast)")
    args = ap.parse_args()

    from . import executor_overhead, figures

    suites = [
        ("executor API v2 + decision-engine overhead (empty tasks; "
         "writes BENCH_decision_engine.json)",
         executor_overhead.bench_executor_overhead),
        ("fig1 (chunks/core sweep)", figures.fig1_chunks_per_core),
        ("fig2 (adjacent-difference, static vs acc)",
         figures.fig2_adjacent_difference),
        ("fig3 (artificial work, Intel)", figures.fig3_artificial_intel),
        ("fig4 (artificial work, AMD)", figures.fig4_artificial_amd),
        ("T0 calibration (measured on this host)", figures.table_t0_this_host),
        ("straggler mitigation (beyond paper)",
         figures.table_straggler_mitigation),
    ]
    if not args.skip_wallclock:
        from . import wallclock

        suites += [
            ("kernel wall-clock (interpret mode)", wallclock.bench_kernels),
            ("algorithm wall-clock", wallclock.bench_algorithms),
            ("train-step wall-clock (reduced)", wallclock.bench_train_step),
        ]

    print("name,us_per_call,derived")
    failed = 0
    for title, fn in suites:
        print(f"# --- {title} ---")
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
