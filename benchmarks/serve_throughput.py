"""Serving throughput benchmark: fused adaptive-depth decode vs the
per-tick path, adaptive vs static policies.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]

A synthetic **open-loop** arrival trace (seeded Poisson interarrivals,
jittered prompt lengths) is replayed against three scheduler
configurations over the same slot pool geometry:

* **fused**    — ``AdaptiveCoreChunk`` + ``dispatch_depth="auto"``: the
  fused on-device decode loop (serve/decode_loop.py) advances the slot
  pool up to ``k`` tokens per dispatch with donated cache buffers, ``k``
  decided per tick from the measured host-overhead/device-step ratio
  (``serve_dispatch_depth`` decisions in the ExecutionModel trace);
* **per-tick** — same adaptive policy, legacy decode granularity: one
  device round-trip (``block_until_ready`` + ``device_get``) per token;
* **static**   — ``StaticCoreChunk`` (OpenMP-static / HPX-default
  semantics) on the per-tick path: fixed core count and chunks-per-core,
  no measurement anywhere.

Open-loop means arrivals do not wait for the system: a request is
submitted as soon as the wall clock passes its timestamp, so a slow
policy builds queue depth and pays for it in p95 latency.  Emits
``BENCH_serve.json`` with tokens/sec, latency percentiles, the
dispatch-granularity accounting (host-overhead-per-token,
dispatches-per-token, host-round-trips-per-token), and achieved
per-device rates (TFLOP/s, HBM GB/s and roofline bandwidth utilization,
from the decode step's XLA cost analysis x the scheduler's
decode-loop-iteration counter) per configuration.

``--mesh DATA,MODEL`` additionally replays the trace against the fused
adaptive configuration sharded over a device mesh (tensor-parallel
within a replica, ``DATA`` data-parallel slot groups) with
``n_replicas x slots`` lanes and per-device batch width decided by
``serve_mesh_batch`` — the ``mesh`` section of the report.

``--smoke`` doubles as the CI regression guard: it exits non-zero if
the fused adaptive configuration fails to beat the static baseline,
and (with ``--mesh``) if the sharded run collapses below
``MESH_SMOKE_FLOOR`` of the single-device fused run or its
``serve_mesh_batch`` decisions never reach online provenance.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.acc import AdaptiveCoreChunk, StaticCoreChunk  # noqa: E402
from repro.core.adaptive import adaptive  # noqa: E402
from repro.core.executor import SequentialExecutor  # noqa: E402
from repro.core.hardware import TPU_V5E  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import (ServeScheduler, materialize,  # noqa: E402
                         percentile, templated_trace, trace_summary)

# Mesh smoke guard floor: host-emulated devices
# (--xla_force_host_platform_device_count) time-share ONE cpu, so the
# sharded run cannot beat the single-device run in wall clock — global
# mesh throughput lands well under 1x and per-device throughput under
# 1/n_devices.  What the guard can catch on such hosts is a sharding
# regression that tanks the path (bad layouts forcing per-step
# resharding, a lost donation recompiling every dispatch): those show up
# as order-of-magnitude collapses, not percents.  On real accelerator
# meshes the per-device column in ``device_metrics`` is the scaling
# metric; here we assert the global ratio stays above this floor.
MESH_SMOKE_FLOOR = 0.05

# Paged smoke guard floor: the synthetic trace has no shared prefixes,
# so the paged pool buys nothing here and pays the per-dispatch page
# table upload plus the gather indirection.  On CPU the interpret-mode
# Pallas paged kernel also pays a per-page grid step (page_size-sized
# tiles instead of one contiguous block_kv), which lands the honest
# ratio around 0.3-0.4x of contiguous fused — on real accelerators the
# tile DMA is the only difference.  The guard catches collapses (a
# lost donation or a recompile per dispatch is an order of magnitude,
# not percents); the prefix-reuse *win* is guarded in
# benchmarks/load_harness.py on the shared_prefix trace.
PAGED_SMOKE_FLOOR = 0.25

# Speculative smoke guard target: on the templated (motif-tiled,
# high n-gram self-overlap) trace the prompt-lookup drafter gets real
# acceptance, so speculative-adaptive must deliver at least this
# multiple of the non-speculative fused run's tokens/s — and its
# serve_spec_depth decisions must reach online provenance (the
# acceptance EMA actually fed back).  Low-overlap traces are guarded in
# benchmarks/load_harness.py (backoff keeps spec within 0.95x there).
SPEC_SMOKE_TARGET = 1.2


def synthetic_trace(n_requests: int, *, mean_interarrival_s: float,
                    prompt_lens: tuple[int, ...], new_tokens: int,
                    vocab: int, seed: int = 0):
    """[(arrival_offset_s, prompt, max_new_tokens)] — one seeded draw so
    every configuration replays the identical load."""
    rng = np.random.RandomState(seed)
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += float(rng.exponential(mean_interarrival_s))
        # Arrival gaps, prompt lengths and prompt tokens all come from
        # the one seeded stream: a single --seed pins the whole load.
        plen = int(prompt_lens[rng.randint(0, len(prompt_lens))])
        prompt = rng.randint(0, vocab, size=plen).astype(np.int32)
        trace.append((t, prompt, new_tokens))
    return trace


def run_policy(name: str, policy, cfg, params, trace, *, n_slots: int,
               max_len: int, dispatch_depth=None, mesh=None,
               paged=False, speculate=None):
    sched = ServeScheduler(cfg, params, n_slots=n_slots, max_len=max_len,
                           executor=adaptive(SequentialExecutor(), policy),
                           dispatch_depth=dispatch_depth, mesh=mesh,
                           paged=paged, speculate=speculate)
    sched.warmup()
    # Untimed steady-state warm: one request per distinct prompt length
    # compiles every shape-dependent host op (token slice / pad per
    # length) and seeds the online calibrations, so the timed replay
    # below measures the serving loop — not whichever configuration
    # runs first paying the process's one-time compiles.
    by_len = {}
    for _, prompt, _ in trace:
        by_len.setdefault(prompt.shape[0], prompt)
    for prompt in by_len.values():
        sched.submit(prompt, max_new_tokens=4)
    sched.run_until_idle()
    sched.clear_finished()
    sched.decode_dispatches = sched.decode_tokens = 0
    sched.host_roundtrips = 0
    sched.host_overhead_s = 0.0
    sched.decode_loop_iters = 0
    sched.prefill_stall_s = 0.0
    sched.spec_verifies = sched.spec_emitted = sched.spec_rounds = 0
    # Snapshot the engine trace so the report covers only the timed
    # replay's depth decisions, not the warm phase's seeded ones.
    model = sched.decision_model()
    depth_seen = len(model.trace.entries("serve_dispatch_depth")) \
        if model is not None else 0
    mesh_seen = len(model.trace.entries("serve_mesh_batch")) \
        if model is not None else 0
    spec_seen = len(model.trace.entries("serve_spec_depth")) \
        if model is not None else 0

    t0 = time.monotonic()
    # deque: the arrival trace is consumed strictly front-first, and a
    # list.pop(0) here is O(n) per arrival — O(n^2) over the replay,
    # pure host overhead charged to whichever policy is being measured.
    pending = collections.deque(trace)
    rids = []
    while pending or sched.pending:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            offset, prompt, n_new = pending.popleft()
            rids.append(sched.submit(prompt, max_new_tokens=n_new,
                                     arrival=t0 + offset))
        if sched.pending:
            sched.tick()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.01))
    outs = sched.results()    # drains any in-flight fused dispatches
    makespan = time.monotonic() - t0

    lats = [sched.requests[r].finished_at - sched.requests[r].arrival
            for r in rids]
    ttfts = [sched.requests[r].first_token_at - sched.requests[r].arrival
             for r in rids]
    gen = sum(len(outs[r]) for r in rids)
    chunks = [rec.chunk for rec in sched.trace if rec.prefill_ops]
    depths = [rec.depth for rec in sched.trace if rec.depth > 0]
    report = {
        "policy": name,
        "requests": len(rids),
        "generated_tokens": gen,
        "makespan_s": round(makespan, 4),
        "tokens_per_s": round(gen / makespan, 2) if makespan else 0.0,
        "latency_p50_ms": round(percentile(lats, 50) * 1e3, 1),
        "latency_p95_ms": round(percentile(lats, 95) * 1e3, 1),
        "ttft_p50_ms": round(percentile(ttfts, 50) * 1e3, 1),
        "ticks": len(sched.trace),
        "mean_prefill_chunk": round(float(np.mean(chunks)), 1)
        if chunks else 0.0,
        "mean_dispatch_depth": round(float(np.mean(depths)), 1)
        if depths else 0.0,
        # Dispatch-granularity accounting: the quantities the
        # serve_dispatch_depth decision trades against each other.
        "host_overhead_ms_per_token":
            round(sched.host_overhead_s / gen * 1e3, 3) if gen else 0.0,
        "dispatches_per_token":
            round(sched.decode_dispatches / gen, 3) if gen else 0.0,
        "host_roundtrips_per_token":
            round(sched.host_roundtrips / gen, 3) if gen else 0.0,
        "smoothed_t_iter_s":
            sched.acc.cache.peek_t_iter(sched.prefill_key)
            if hasattr(sched.acc, "cache") else None,
        # Decode-lane time lost to prefill chunks with nothing in
        # flight to hide them behind — what serve_prefill_interleave
        # trades against admission starvation.
        "prefill_stall_s": round(sched.prefill_stall_s, 4),
        "prefill_stall_ms_per_tick":
            round(sched.prefill_stall_s / len(sched.trace) * 1e3, 4)
            if sched.trace else 0.0,
    }
    if paged:
        report["prefix"] = sched.pool.prefix_stats()
    if dispatch_depth is not None and model is not None:
        entries = model.trace.entries("serve_dispatch_depth")[depth_seen:]
        report["depth_decisions"] = len(entries)
        report["depth_provenance"] = sorted(
            {e.decision.provenance for e in entries})
    if mesh is not None and model is not None:
        entries = model.trace.entries("serve_mesh_batch")[mesh_seen:]
        report["mesh_decisions"] = len(entries)
        report["mesh_provenance"] = sorted(
            {e.decision.provenance for e in entries})
        report["mesh_trace"] = [e.decision.explain() for e in entries[-6:]]
    if speculate is not None:
        st = sched.spec_stats()
        report["speculate"] = {
            "mode": str(speculate),
            "final_depth": st["depth"],
            "verifies": st["verifies"],
            "emitted": st["emitted"],
            "tokens_per_verify": round(st["tokens_per_verify"], 3),
            "acceptance_rate": round(st["acceptance_rate"], 4),
        }
        if model is not None:
            entries = model.trace.entries("serve_spec_depth")[spec_seen:]
            report["spec_decisions"] = len(entries)
            report["spec_provenance"] = sorted(
                {e.decision.provenance for e in entries})
            report["spec_trace"] = [e.decision.explain()
                                    for e in entries[-4:]]
    # Achieved per-device rates from the decode step's XLA cost analysis
    # (analysis/roofline.py).  cost_analysis counts a fori_loop body
    # ONCE, so the figures are per loop iteration per device — the
    # scheduler's decode_loop_iters counter is the multiplier.  The
    # bandwidth-utilization column anchors to the TPU v5e roofline spec
    # so runs on different hosts stay comparable.
    costs = sched.decode_cost_analysis()
    iters = sched.decode_loop_iters
    if costs is not None and makespan > 0:
        hbm_bps = costs["hbm_bytes_per_device"] * iters / makespan
        report["device_metrics"] = {
            "n_devices": costs["n_devices"],
            "decode_loop_iters": iters,
            "decode_flops_per_device_per_iter": costs["flops_per_device"],
            "decode_hbm_bytes_per_device_per_iter":
                costs["hbm_bytes_per_device"],
            "collective_wire_bytes_per_device_per_iter":
                costs["collective_wire_bytes_per_device"],
            "tflops_per_device":
                round(costs["flops_per_device"] * iters / makespan / 1e12,
                      9),
            "hbm_gb_per_s_per_device": round(hbm_bps / 1e9, 4),
            "hbm_bw_utilization_tpu_v5e": round(hbm_bps / TPU_V5E.mem_bw,
                                                9),
        }
    print(f"  {name:9s} {report['tokens_per_s']:8.1f} tok/s | "
          f"p50 {report['latency_p50_ms']:7.1f}ms | "
          f"host {report['host_overhead_ms_per_token']:6.2f}ms/tok | "
          f"{report['dispatches_per_token']:.2f} dispatches/tok | "
          f"{report['host_roundtrips_per_token']:.2f} round-trips/tok | "
          f"stall {report['prefill_stall_ms_per_tick']:.2f}ms/tick | "
          f"{report['ticks']} ticks")
    dm = report.get("device_metrics")
    if dm:
        print(f"  {'':9s} {dm['tflops_per_device'] * 1e3:8.4f} GFLOP/s/dev"
              f" | hbm {dm['hbm_gb_per_s_per_device']:7.3f} GB/s/dev "
              f"({dm['hbm_bw_utilization_tpu_v5e']:.2e} of v5e bw) | "
              f"{dm['n_devices']} device(s) x "
              f"{dm['decode_loop_iters']} decode iters")
    sp = report.get("speculate")
    if sp:
        print(f"  {'':9s} spec depth={sp['final_depth']} "
              f"{sp['tokens_per_verify']:.2f} tok/verify "
              f"(acceptance {sp['acceptance_rate']:.0%}) | provenance "
              f"{report.get('spec_provenance')}")
    return report, sched


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI; exits non-zero if the fused "
                         "adaptive path loses to the static baseline")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="single seed for the arrival and prompt-length "
                         "RNGs (every configuration replays the same "
                         "draw)")
    ap.add_argument("--paged", action="store_true",
                    help="also run the fused adaptive configuration on "
                         "the paged KV pool (and shard the --mesh run's "
                         "pool the same way); with --smoke, fails if "
                         "the paged run collapses below "
                         "PAGED_SMOKE_FLOOR of the contiguous fused run")
    ap.add_argument("--mesh", default="off",
                    help="also run the fused adaptive configuration "
                         "sharded over a 'DATA,MODEL' device mesh "
                         "(launch/mesh.make_serve_mesh) with "
                         "n_replicas x slots lanes; emits the 'mesh' "
                         "section of BENCH_serve.json.  Pair with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU hosts")
    ap.add_argument("--trace-out", default=None,
                    help="write the mesh run's (or, without --mesh, the "
                         "fused run's) ExecutionModel decision trace to "
                         "this file")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    n_requests = args.requests or (8 if args.smoke else 16)
    new_tokens = args.new_tokens or (24 if args.smoke else 48)
    prompt_lens = (8, 12, 16) if args.smoke else (16, 32, 64, 96)
    n_slots = 2 if args.smoke else 4
    max_len = max(prompt_lens) + new_tokens + 1

    cfg = get_config("qwen3-0.6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    # Arrivals dense enough to keep the queue non-empty for every
    # configuration: an open-loop trace that starves the scheduler
    # measures the arrival process, not the serving loop.
    trace = synthetic_trace(
        n_requests, mean_interarrival_s=0.002,
        prompt_lens=prompt_lens, new_tokens=new_tokens,
        vocab=cfg.vocab_size, seed=args.seed)

    print(f"serve throughput: {n_requests} requests, slots={n_slots}, "
          f"prompts {prompt_lens}, +{new_tokens} tokens each")
    fused_rep, fused_sched = run_policy(
        "fused", AdaptiveCoreChunk(), cfg, params, trace,
        n_slots=n_slots, max_len=max_len, dispatch_depth="auto")
    paged_rep = None
    if args.paged:
        paged_rep, _ = run_policy(
            "paged", AdaptiveCoreChunk(), cfg, params, trace,
            n_slots=n_slots, max_len=max_len, dispatch_depth="auto",
            paged=True)
    per_tick_rep, _ = run_policy(
        "per-tick", AdaptiveCoreChunk(), cfg, params, trace,
        n_slots=n_slots, max_len=max_len)
    static_rep, _ = run_policy(
        "static", StaticCoreChunk(cores=1, chunks_per_core=8), cfg, params,
        trace, n_slots=n_slots, max_len=max_len)

    def ratio(a, b):
        return round(a["tokens_per_s"] / b["tokens_per_s"], 3) \
            if b["tokens_per_s"] else float("nan")

    # Speculative section: fused-adaptive with and without
    # self-speculation, replaying a *templated* trace
    # (loadgen.templated_trace: motif-tiled prompts with high n-gram
    # self-overlap) where the prompt-lookup drafter gets real
    # acceptance.  The delta isolates what speculation buys; the random
    # traces above stay speculation-free so the other ratios are
    # unchanged.  The section runs speculation's home configuration —
    # a SINGLE decode lane (latency-bound serving, no batch to fill the
    # width; one lane also makes loop rounds equal per-lane verifies,
    # so the tokens-per-verify win is not diluted by the max() over
    # lanes) on a model a step up from reduced(): with 2 layers at
    # d_model 64 the per-round fixed costs (draft gather, history
    # shift, write-out) dominate the forward and drown the win, while
    # at 4 layers x d_model 128 the step is weight-bound and the wider
    # verify rides the same weight stream.  Generation long enough for
    # the drafter's bigram table to lock onto the motif cycle.
    spec_cfg = dataclasses.replace(cfg, n_layers=4, d_model=128,
                                   d_ff=256, head_dim=32)
    spec_params = lm.init_params(jax.random.PRNGKey(0), spec_cfg)
    spec_new = 128
    spec_reqs = templated_trace(
        n_requests, rate_rps=200.0, motif_len=6, median_prompt=16,
        prompt_sigma=0.3, max_prompt=32, median_new=spec_new,
        new_sigma=0.0, max_new=spec_new, seed=args.seed, slo=None)
    spec_mat = materialize(spec_reqs, spec_cfg.vocab_size, seed=args.seed)
    spec_trace = [(tr.arrival_s, toks, tr.new_tokens)
                  for tr, toks in spec_mat]
    # Headroom for the reserved draft margin (the last spec_d - 1 cache
    # positions are unusable under speculation — scheduler docstring).
    spec_max_len = max(tr.prompt_len + tr.new_tokens
                       for tr in spec_reqs) + 9
    print(f"templated trace (speculation section): "
          f"{trace_summary(spec_reqs)}")
    specoff_rep, _ = run_policy(
        "spec-off", AdaptiveCoreChunk(), spec_cfg, spec_params, spec_trace,
        n_slots=1, max_len=spec_max_len, dispatch_depth=12)
    spec_rep, _ = run_policy(
        "spec-auto", AdaptiveCoreChunk(), spec_cfg, spec_params, spec_trace,
        n_slots=1, max_len=spec_max_len, dispatch_depth=12,
        speculate="auto")

    fused_over_per_tick = ratio(fused_rep, per_tick_rep)
    adaptive_over_static = ratio(fused_rep, static_rep)
    spec_over_non_spec = ratio(spec_rep, specoff_rep)
    blob = {"adaptive": fused_rep, "per_tick": per_tick_rep,
            "static": static_rep,
            "fused_over_per_tick": fused_over_per_tick,
            "adaptive_over_static": adaptive_over_static,
            "speculative": {
                "templated_trace": trace_summary(spec_reqs),
                "spec_off": specoff_rep,
                "spec_auto": spec_rep,
                "spec_over_non_spec": spec_over_non_spec,
            },
            "smoke": bool(args.smoke)}
    print(f"  spec-auto/spec-off on templated trace: "
          f"{spec_over_non_spec:.2f}x")
    spec_ok = True
    if args.smoke:
        if spec_over_non_spec < SPEC_SMOKE_TARGET:
            print(f"FAIL: speculative-adaptive {spec_over_non_spec:.2f}x "
                  f"non-speculative on the templated trace (target "
                  f"{SPEC_SMOKE_TARGET}x) — speculation regression")
            spec_ok = False
        if "online" not in spec_rep.get("spec_provenance", []):
            print("FAIL: serve_spec_depth decisions never reached online "
                  "provenance during the timed replay: "
                  f"{spec_rep.get('spec_provenance')}")
            spec_ok = False

    paged_ok = True
    if paged_rep is not None:
        paged_over_fused = ratio(paged_rep, fused_rep)
        blob["paged"] = paged_rep
        blob["paged_over_fused"] = paged_over_fused
        print(f"  paged/fused: {paged_over_fused:.2f}x on a "
              "no-shared-prefix trace (page-table tax only)")
        if args.smoke and paged_over_fused < PAGED_SMOKE_FLOOR:
            print(f"FAIL: paged fused decode {paged_over_fused:.3f}x "
                  f"contiguous (floor {PAGED_SMOKE_FLOOR}) — paged-path "
                  "regression")
            paged_ok = False

    mesh_ok = True
    trace_sched = fused_sched
    if args.mesh.strip().lower() not in ("off", "none", ""):
        from repro.launch.mesh import make_serve_mesh, n_data_replicas

        data, model_par = (int(x) for x in args.mesh.split(","))
        mesh = make_serve_mesh(data, model_par)
        reps = n_data_replicas(mesh)
        mesh_slots = n_slots * reps    # same per-replica pool geometry
        print(f"mesh {data}x{model_par} over {mesh.devices.size} "
              f"{jax.default_backend()} devices: {reps} replicas x "
              f"{mesh_slots // reps} slots = {mesh_slots} lanes")
        mesh_rep, trace_sched = run_policy(
            "mesh", AdaptiveCoreChunk(), cfg, params, trace,
            n_slots=mesh_slots, max_len=max_len, dispatch_depth="auto",
            mesh=mesh, paged=args.paged)
        n_dev = int(mesh.devices.size)
        per_dev = round(mesh_rep["tokens_per_s"] / n_dev, 2)
        mesh_over_single = ratio(mesh_rep, fused_rep)
        blob["mesh"] = {
            "mesh_shape": {"data": data, "model": model_par},
            "n_devices": n_dev,
            "n_replicas": reps,
            "n_slots": mesh_slots,
            "paged": bool(args.paged),
            "backend": jax.default_backend(),
            "tokens_per_s_per_device": per_dev,
            "mesh_over_single_fused": mesh_over_single,
            "report": mesh_rep,
        }
        print(f"  mesh/single-fused: {mesh_over_single:.2f}x global | "
              f"{per_dev:.1f} tok/s/device over {n_dev} devices")
        if args.smoke:
            # See MESH_SMOKE_FLOOR: emulated devices share one cpu, so
            # the guard is the global ratio (a sharding regression shows
            # as a collapse) plus the decision loop having gone online.
            if mesh_over_single < MESH_SMOKE_FLOOR:
                print("FAIL: mesh-sharded throughput "
                      f"{mesh_over_single:.3f}x single-device fused "
                      f"(floor {MESH_SMOKE_FLOOR}) — sharded-serving "
                      "regression")
                mesh_ok = False
            if "online" not in mesh_rep.get("mesh_provenance", []):
                print("FAIL: serve_mesh_batch decisions never reached "
                      "online provenance during the timed replay: "
                      f"{mesh_rep.get('mesh_provenance')}")
                mesh_ok = False

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"fused/per-tick throughput: {fused_over_per_tick:.2f}x | "
          f"adaptive/static: {adaptive_over_static:.2f}x -> {out}")
    if args.trace_out:
        model = trace_sched.decision_model()
        if model is not None:
            path = os.path.abspath(args.trace_out)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                f.write(model.explain() + "\n")
            print(f"-> {path}")
    if args.smoke and adaptive_over_static < 1.0:
        print("FAIL: fused adaptive below the static baseline "
              f"({adaptive_over_static:.2f}x) — dispatch-granularity "
              "regression")
        return 1
    if not mesh_ok or not paged_ok or not spec_ok:
        return 1
    if not args.smoke and fused_over_per_tick < 1.3:
        print("WARNING: fused decode below the 1.3x target over the "
              "per-tick path on this host")
    return 0


if __name__ == "__main__":
    sys.exit(main())
