"""Serving throughput benchmark: adaptive vs static continuous batching.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]

A synthetic **open-loop** arrival trace (seeded Poisson interarrivals,
jittered prompt lengths) is replayed against two schedulers over the
same slot pool geometry:

* **adaptive** — ``AdaptiveCoreChunk``: per-tick batch width and prefill
  chunk from the Overhead-Law decision over the queued tokens, with
  online feedback smoothing observed chunk timings back into the
  calibration cache;
* **static**   — ``StaticCoreChunk`` (OpenMP-static / HPX-default
  semantics): fixed core count and chunks-per-core, so the queue is
  always split into ``cores * chunks_per_core`` pieces regardless of how
  expensive an iteration actually is.

Open-loop means arrivals do not wait for the system: a request is
submitted as soon as the wall clock passes its timestamp, so a slow
policy builds queue depth and pays for it in p95 latency.  Emits
``BENCH_serve.json`` with tokens/sec and latency percentiles per policy.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.acc import AdaptiveCoreChunk, StaticCoreChunk  # noqa: E402
from repro.core.adaptive import adaptive  # noqa: E402
from repro.core.executor import SequentialExecutor  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import ServeScheduler, percentile  # noqa: E402


def synthetic_trace(n_requests: int, *, mean_interarrival_s: float,
                    prompt_lens: tuple[int, ...], new_tokens: int,
                    vocab: int, seed: int = 0):
    """[(arrival_offset_s, prompt, max_new_tokens)] — one seeded draw so
    both policies replay the identical load."""
    rng = np.random.RandomState(seed)
    t = 0.0
    trace = []
    for i in range(n_requests):
        t += float(rng.exponential(mean_interarrival_s))
        plen = int(prompt_lens[i % len(prompt_lens)])
        prompt = rng.randint(0, vocab, size=plen).astype(np.int32)
        trace.append((t, prompt, new_tokens))
    return trace


def run_policy(name: str, policy, cfg, params, trace, *, n_slots: int,
               max_len: int) -> dict:
    sched = ServeScheduler(cfg, params, n_slots=n_slots, max_len=max_len,
                           executor=adaptive(SequentialExecutor(), policy))
    sched.warmup()

    t0 = time.monotonic()
    pending = list(trace)
    rids = []
    while pending or sched.pending:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            offset, prompt, n_new = pending.pop(0)
            rids.append(sched.submit(prompt, max_new_tokens=n_new,
                                     arrival=t0 + offset))
        if sched.pending:
            sched.tick()
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.01))
    makespan = time.monotonic() - t0

    outs = sched.results()
    lats = [sched.requests[r].finished_at - sched.requests[r].arrival
            for r in rids]
    ttfts = [sched.requests[r].first_token_at - sched.requests[r].arrival
             for r in rids]
    gen = sum(len(outs[r]) for r in rids)
    chunks = [rec.chunk for rec in sched.trace if rec.prefill_ops]
    report = {
        "policy": name,
        "requests": len(rids),
        "generated_tokens": gen,
        "makespan_s": round(makespan, 4),
        "tokens_per_s": round(gen / makespan, 2) if makespan else 0.0,
        "latency_p50_ms": round(percentile(lats, 50) * 1e3, 1),
        "latency_p95_ms": round(percentile(lats, 95) * 1e3, 1),
        "ttft_p50_ms": round(percentile(ttfts, 50) * 1e3, 1),
        "ticks": len(sched.trace),
        "mean_prefill_chunk": round(float(np.mean(chunks)), 1)
        if chunks else 0.0,
        "smoothed_t_iter_s":
            sched.acc.cache.peek_t_iter(sched.prefill_key)
            if hasattr(sched.acc, "cache") else None,
    }
    print(f"  {name:9s} {report['tokens_per_s']:8.1f} tok/s | "
          f"p50 {report['latency_p50_ms']:7.1f}ms | "
          f"p95 {report['latency_p95_ms']:7.1f}ms | "
          f"mean chunk {report['mean_prefill_chunk']:.0f} | "
          f"{report['ticks']} ticks")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI: prove the benchmark runs")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--new-tokens", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    n_requests = args.requests or (4 if args.smoke else 16)
    new_tokens = args.new_tokens or (4 if args.smoke else 16)
    prompt_lens = (12, 24, 48) if args.smoke else (16, 32, 64, 96)
    n_slots = 2 if args.smoke else 4
    max_len = max(prompt_lens) + new_tokens + 1

    cfg = get_config("qwen3-0.6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(
        n_requests, mean_interarrival_s=0.02 if args.smoke else 0.05,
        prompt_lens=prompt_lens, new_tokens=new_tokens,
        vocab=cfg.vocab_size, seed=0)

    print(f"serve throughput: {n_requests} requests, slots={n_slots}, "
          f"prompts {prompt_lens}, +{new_tokens} tokens each")
    adaptive_rep = run_policy("adaptive", AdaptiveCoreChunk(), cfg, params,
                              trace, n_slots=n_slots, max_len=max_len)
    static_rep = run_policy(
        "static", StaticCoreChunk(cores=1, chunks_per_core=8), cfg, params,
        trace, n_slots=n_slots, max_len=max_len)

    speedup = (adaptive_rep["tokens_per_s"] /
               static_rep["tokens_per_s"]) if static_rep["tokens_per_s"] \
        else float("nan")
    blob = {"adaptive": adaptive_rep, "static": static_rep,
            "adaptive_over_static": round(speedup, 3),
            "smoke": bool(args.smoke)}
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"adaptive/static throughput: {speedup:.2f}x -> {out}")
    if not args.smoke and speedup < 1.0:
        print("WARNING: adaptive below static baseline on this host")


if __name__ == "__main__":
    main()
