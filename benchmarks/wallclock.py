"""Real wall-clock microbenchmarks on this container (1 CPU core):
kernels (interpret mode) vs jnp oracle, and the algorithm layer's
dispatch overheads.  These are the honest measured numbers; the
SimMachine figures carry the multi-core story."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # compile
    best = float("inf")
    for _ in range(repeats):
        t = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t)
    return best


def bench_kernels() -> list[str]:
    from repro import kernels as K
    from repro.kernels import ref as R

    rows = []
    x = jnp.asarray(np.random.RandomState(0).randn(65536).astype(np.float32))
    pairs = [
        ("adjacent_difference", lambda: K.adjacent_difference(x),
         lambda: R.adjacent_difference_ref(x)),
        ("reduce_sum", lambda: K.reduce_sum(x),
         lambda: R.reduce_sum_ref(x)),
        ("inclusive_scan", lambda: K.inclusive_scan(x),
         lambda: R.inclusive_scan_ref(x)),
    ]
    for name, kf, rf in pairs:
        tk = _time(kf)
        tr = _time(rf)
        rows.append(f"kernel/{name}/interp,{tk*1e6:.1f},ref_us={tr*1e6:.1f}")
    q = jnp.asarray(np.random.RandomState(1).randn(1, 4, 256, 64)
                    .astype(np.float32))
    k_ = jnp.asarray(np.random.RandomState(2).randn(1, 2, 256, 64)
                     .astype(np.float32))
    tk = _time(lambda: K.flash_attention(q, k_, k_, block_q=64,
                                         block_kv=128))
    tr = _time(lambda: R.attention_ref(q, k_, k_))
    rows.append(f"kernel/flash_attention/interp,{tk*1e6:.1f},"
                f"ref_us={tr*1e6:.1f}")
    return rows


def bench_algorithms() -> list[str]:
    from repro import algorithms as alg
    from repro.core import HostParallelExecutor, adaptive, par, seq

    rows = []
    x = jnp.asarray(np.random.RandomState(0).randn(1 << 20)
                    .astype(np.float32))
    with HostParallelExecutor(max_workers=2) as host:
        # v2: the acc object rides on the executor, not the call site.
        pol = par.on(adaptive(host))
        for name, fn in [
            ("adjacent_difference", alg.adjacent_difference),
            ("inclusive_scan", alg.inclusive_scan),
        ]:
            t_seq = _time(lambda f=fn: f(seq, x))
            t_acc = _time(lambda f=fn: f(pol, x))
            rows.append(f"alg/{name}/seq,{t_seq*1e6:.1f},n=1M")
            rows.append(f"alg/{name}/acc,{t_acc*1e6:.1f},"
                        f"ratio={t_seq/max(t_acc,1e-12):.2f}")
    return rows


def bench_train_step() -> list[str]:
    import jax

    from repro.configs import get_config
    from repro.data import make_batch
    from repro.models import init_params
    from repro.optim import AdamWConfig, adamw
    from repro.train import make_train_step

    rows = []
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    batch = make_batch(cfg, 4, 64, kind="train")
    step = jax.jit(make_train_step(cfg, AdamWConfig(), accum=2))

    def run():
        p, o, m = step(params, opt, batch)
        return m["loss"]

    t = _time(run)
    toks = 4 * 64
    rows.append(f"train/reduced-qwen3-step,{t*1e6:.1f},"
                f"tok_per_s={toks/t:.0f}")
    return rows
