"""Executor-API dispatch-overhead and decision-engine microbenchmarks.

Empty-task latency of each v2 execution function, per backend, plus the
per-decision overhead of the unified ``ExecutionModel`` engine — the
dispatch and decision costs the Overhead Law's T0 ultimately pays for.
Rows follow the harness CSV convention: ``name,us_per_call,derived``.

The engine numbers also land in ``BENCH_decision_engine.json`` so the
unification itself shows up in the benchmark artifacts and cannot
silently regress the hot path (a serve tick makes one engine decision;
a kernel call resolves one tuned plan).

    PYTHONPATH=src python benchmarks/executor_overhead.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (HostParallelExecutor, SequentialExecutor, adaptive,
                        make_chunks, when_all)
from repro.core.calibration import CalibrationCache
from repro.core.model import DecisionKey, ExecutionModel

N_CHUNKS = 16
REPEATS = 200

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_decision_engine.json")


def _empty(_chunk) -> None:
    return None


def _per_call(fn, repeats: int = REPEATS) -> float:
    fn()  # warm (pool threads, code paths)
    start = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - start) / repeats


def _bench_backend(name: str, ex) -> list[str]:
    chunks = make_chunks(N_CHUNKS, 1)
    rows = []
    t = _per_call(lambda: ex.sync_execute(_empty, chunks[0]))
    rows.append(f"exec/{name}/sync_execute,{t*1e6:.2f},empty_task")
    t = _per_call(lambda: ex.async_execute(_empty, chunks[0]).result())
    rows.append(f"exec/{name}/async_execute,{t*1e6:.2f},empty_task")
    t = _per_call(
        lambda: when_all(ex.bulk_async_execute(_empty, chunks)).result())
    rows.append(f"exec/{name}/bulk_async_execute,{t*1e6:.2f},"
                f"n_chunks={N_CHUNKS}")

    def chain():
        f = ex.async_execute(_empty, chunks[0])
        for _ in range(4):
            f = ex.then_execute(lambda _v: None, f)
        return f.result()

    t = _per_call(chain)
    rows.append(f"exec/{name}/then_execute_chain4,{t*1e6:.2f},empty_task")
    return rows


def bench_decision_engine(repeats: int = REPEATS, *,
                          smoke: bool = False) -> tuple[list[str], dict]:
    """Per-decision overhead of the unified engine, per query type.

    ``cores_chunk`` is the serve-tick / algorithm-plan hot path;
    ``observe`` runs once per timed chunk on the feedback path;
    ``tuned_blocks`` (store-hit) is what every kernel call pays once a
    winner is persisted.  The tuned sweep itself is measured work, not
    engine overhead, so the benchmark pre-seeds the store and reports
    the hit rate to prove the lookups stay hits.
    """
    model = ExecutionModel(CalibrationCache(), hardware="bench")
    rows: list[str] = []

    key = DecisionKey("bench_tick", ("engine",))
    t_decide = _per_call(
        lambda: model.cores_chunk(key, t_iter=2e-9, count=1 << 20,
                                  t0=1e-5, max_cores=16), repeats)
    rows.append(f"engine/cores_chunk,{t_decide*1e6:.2f},ns_per_decision="
                f"{t_decide*1e9:.0f}")

    obs_key = DecisionKey("bench_obs", ("engine",))
    t_observe = _per_call(
        lambda: model.observe(obs_key, 1024, 1e-3), repeats)
    rows.append(f"engine/observe,{t_observe*1e6:.2f},ns_per_observation="
                f"{t_observe*1e9:.0f}")

    tuned_key = DecisionKey("pallas_block", ("bench_kernel", 8192),
                            dtype="float32", hardware="bench")
    model.tuned_blocks(tuned_key, [(256,), (512,)], lambda b: None,
                       ("block",))   # one seed search, then all hits
    before = model.cache_hits
    t_tuned = _per_call(
        lambda: model.tuned_blocks(tuned_key, [(256,), (512,)],
                                   lambda b: None, ("block",)), repeats)
    hits = model.cache_hits - before
    hit_rate = hits / max(repeats + 1, 1)   # +1: the warm call
    rows.append(f"engine/tuned_blocks_hit,{t_tuned*1e6:.2f},"
                f"hit_rate={hit_rate:.3f}")

    report = {
        "ns_per_decision": t_decide * 1e9,
        "ns_per_observation": t_observe * 1e9,
        "ns_per_tuned_lookup": t_tuned * 1e9,
        "tuned_hit_rate": hit_rate,
        "decisions": model.decisions,
        "observations": model.observations,
        "searches": model.searches,
        "cache_hits": model.cache_hits,
        "trace_len": len(model.trace),
        # Same convention as BENCH_serve.json: a smoke-produced file is
        # self-identifying, never mistaken for a full run.
        "smoke": smoke,
        "repeats": repeats,
    }
    return rows, report


def _bench_all(*, smoke: bool = False) -> tuple[list[str], dict]:
    """Every suite: executor dispatch per backend + decision engine.
    Smoke runs skip the backend sweeps and use few engine repeats."""
    rows: list[str] = []
    if not smoke:
        rows += _bench_backend("seq", SequentialExecutor())
        with HostParallelExecutor(max_workers=2) as host:
            rows += _bench_backend("host2", host)
            # The adaptive wrapper should add only delegation cost.
            rows += _bench_backend("adaptive(host2)", adaptive(host))
    engine_rows, report = bench_decision_engine(
        repeats=20 if smoke else REPEATS, smoke=smoke)
    return rows + engine_rows, report


def bench_executor_overhead() -> list[str]:
    """benchmarks/run.py suite entry point (full run)."""
    rows, report = _bench_all()
    _write_report(report)
    return rows


def _write_report(report: dict, out: str = DEFAULT_OUT) -> None:
    try:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
    except OSError:  # pragma: no cover - read-only checkout
        pass


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="engine-only, few repeats: prove the benchmark "
                         "runs and emit a smoke-flagged "
                         "BENCH_decision_engine.json")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    rows, report = _bench_all(smoke=args.smoke)
    for row in rows:
        print(row)
    _write_report(report, args.out)
    print(f"# wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
