"""Executor-API dispatch-overhead microbenchmarks.

Empty-task latency of each v2 execution function, per backend, plus the
deprecated v1 sync path — so future PRs can detect regressions in the
dispatch cost the Overhead Law's T0 ultimately pays for.  Rows follow the
harness CSV convention: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time
import warnings

from repro.core import (HostParallelExecutor, SequentialExecutor, adaptive,
                        make_chunks, when_all)

N_CHUNKS = 16
REPEATS = 200


def _empty(_chunk) -> None:
    return None


def _per_call(fn, repeats: int = REPEATS) -> float:
    fn()  # warm (pool threads, code paths)
    start = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - start) / repeats


def _bench_backend(name: str, ex) -> list[str]:
    chunks = make_chunks(N_CHUNKS, 1)
    rows = []
    t = _per_call(lambda: ex.sync_execute(_empty, chunks[0]))
    rows.append(f"exec/{name}/sync_execute,{t*1e6:.2f},empty_task")
    t = _per_call(lambda: ex.async_execute(_empty, chunks[0]).result())
    rows.append(f"exec/{name}/async_execute,{t*1e6:.2f},empty_task")
    t = _per_call(
        lambda: when_all(ex.bulk_async_execute(_empty, chunks)).result())
    rows.append(f"exec/{name}/bulk_async_execute,{t*1e6:.2f},"
                f"n_chunks={N_CHUNKS}")

    def chain():
        f = ex.async_execute(_empty, chunks[0])
        for _ in range(4):
            f = ex.then_execute(lambda _v: None, f)
        return f.result()

    t = _per_call(chain)
    rows.append(f"exec/{name}/then_execute_chain4,{t*1e6:.2f},empty_task")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        t = _per_call(lambda: ex.bulk_sync_execute(_empty, chunks))
    rows.append(f"exec/{name}/bulk_sync_execute(deprecated),{t*1e6:.2f},"
                f"n_chunks={N_CHUNKS}")
    return rows


def bench_executor_overhead() -> list[str]:
    rows = _bench_backend("seq", SequentialExecutor())
    with HostParallelExecutor(max_workers=2) as host:
        rows += _bench_backend("host2", host)
        # The adaptive wrapper should add only delegation cost.
        rows += _bench_backend("adaptive(host2)", adaptive(host))
    return rows
