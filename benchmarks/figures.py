"""Paper-figure reproductions (one function per figure/table).

The paper's machines (40-core Skylake, 48-core EPYC) are reproduced via
the calibrated SimMachine (this container has 1 core — see DESIGN.md §2);
T0 on THIS host is measured for real by the empty-task benchmark.
Each function returns a list of CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

from repro.core import (ADJACENT_DIFFERENCE, AMD_EPYC_48C, EPYC_48,
                        INTEL_SKYLAKE_40C, SKYLAKE_40,
                        HostParallelExecutor, artificial_work,
                        t_iter_analytic)
from repro.core import overhead_law as ol
from repro.core.calibration import measure_t0_empty_task
from repro.core.model import AnalyticOverheadLaw

# The ExecutionModel's analytic prior policy — the figure baselines ask
# it directly (SimMachine sweeps need no cache/trace/engine state).
PRIOR = AnalyticOverheadLaw()

SIZES = [2 ** k for k in range(10, 25)]
T_MEM = t_iter_analytic(ADJACENT_DIFFERENCE, INTEL_SKYLAKE_40C)
T_CPU = t_iter_analytic(artificial_work(256), INTEL_SKYLAKE_40C)
T_CPU_AMD = t_iter_analytic(artificial_work(256), AMD_EPYC_48C)


def _acc_time(m, t_iter, n):
    # T0 calibrated by the empty-task benchmark at full region width
    d = PRIOR.decide(t_iter=t_iter, count=n, t0=m.t0_for(m.cores),
                     max_cores=m.cores)
    return m.run_decision(d), d


def fig1_chunks_per_core() -> list[str]:
    """Fig 1: speedup vs size for C in {1,4,8} at 2/16/32 cores
    (adjacent-difference body)."""
    rows = []
    for cores in (2, 16, 32):
        for c in (1, 4, 8):
            for n in SIZES[::3]:
                s = SKYLAKE_40.speedup(t_iter=T_MEM, count=n, n_cores=cores,
                                       chunks_per_core=c)
                t = T_MEM * n / s
                rows.append(f"fig1/cores{cores}/C{c}/n{n},"
                            f"{t*1e6:.3f},speedup={s:.3f}")
    return rows


def fig2_adjacent_difference() -> list[str]:
    """Fig 2: static core counts vs acc (memory-bound)."""
    rows = []
    for n in SIZES[::2]:
        best = 0.0
        for cores in (1, 2, 4, 8, 16, 32, 40):
            s = SKYLAKE_40.speedup(t_iter=T_MEM, count=n, n_cores=cores,
                                   chunks_per_core=4)
            best = max(best, s)
            rows.append(f"fig2/static{cores}/n{n},"
                        f"{T_MEM*n/s*1e6:.3f},speedup={s:.3f}")
        t_acc, d = _acc_time(SKYLAKE_40, T_MEM, n)
        s_acc = T_MEM * n / t_acc
        rows.append(f"fig2/acc/n{n},{t_acc*1e6:.3f},"
                    f"speedup={s_acc:.3f};cores={d.n_cores};"
                    f"chunk={d.chunk_elems};vs_best={s_acc/max(best,1e-9):.3f}")
    return rows


def _fig34(machine, t_iter, tag) -> list[str]:
    rows = []
    for n in SIZES[::2]:
        best = 0.0
        for cores in (1, 4, 16, machine.cores):
            s = machine.speedup(t_iter=t_iter, count=n, n_cores=cores,
                                chunks_per_core=4)
            best = max(best, s)
            rows.append(f"{tag}/static{cores}/n{n},"
                        f"{t_iter*n/s*1e6:.3f},speedup={s:.3f}")
        t_acc, d = _acc_time(machine, t_iter, n)
        s_acc = t_iter * n / t_acc
        rows.append(f"{tag}/acc/n{n},{t_acc*1e6:.3f},"
                    f"speedup={s_acc:.3f};cores={d.n_cores};"
                    f"vs_best={s_acc/max(best,1e-9):.3f}")
    return rows


def fig3_artificial_intel() -> list[str]:
    """Fig 3: compute-bound, Intel 40c."""
    return _fig34(SKYLAKE_40, T_CPU, "fig3")


def fig4_artificial_amd() -> list[str]:
    """Fig 4: compute-bound, AMD 48c."""
    return _fig34(EPYC_48, T_CPU_AMD, "fig4")


def table_t0_this_host() -> list[str]:
    """Measured T0 (empty-task benchmark) on THIS container — the paper's
    calibration step, executed for real."""
    with HostParallelExecutor(max_workers=2) as ex:
        t0 = measure_t0_empty_task(ex, repeats=16)
    t_opt = ol.t_opt(t0, 0.95)
    return [f"t0/host,{t0*1e6:.2f},t_opt_us={t_opt*1e6:.2f};t_opt_eq_19t0="
            f"{abs(t_opt - 19*t0) < 1e-12}"]


def table_straggler_mitigation() -> list[str]:
    """Beyond-paper: C-deep over-decomposition bounds straggler impact."""
    from repro.runtime import straggler_step_time

    rows = []
    for c in (1, 2, 4, 8, 16, 32):
        rel = straggler_step_time(n_devices=256, chunks_per_device=c,
                                  slowdown=5.0)
        rows.append(f"straggler/C{c},{rel*1e6:.2f},relative_step={rel:.3f}")
    return rows
