"""Render the dry-run artifacts (runs/dryrun/*.json) as the §Roofline
table (markdown) — one row per (arch × shape × mesh)."""
from __future__ import annotations

import glob
import json
import os

HEADER = ("| arch | shape | mesh | accum | compute (ms) | memory (ms) | "
          "collective (ms) | dominant | useful % | roofline % | HBM GiB | "
          "next lever |")
SEP = "|" + "---|" * 12


def _lever(rec: dict) -> str:
    dom = rec.get("dominant", "?")
    if dom == "collective":
        return "reduce FSDP gathers / int8 sync / EP a2a"
    if dom == "memory":
        return "fused (flash) attention; bf16 master; remat policy"
    return "causal block skipping; MXU-aligned tiles"


def rows(run_dir: str = "runs/dryrun") -> list[str]:
    out = []
    for path in sorted(glob.glob(os.path.join(run_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            arch, shape, mesh = rec["cell"].split("__")[:3]
            out.append(f"| {arch} | {shape} | {mesh} | – | – | – | – | "
                       f"SKIP | – | – | – | {rec['reason'][:40]} |")
            continue
        if rec.get("status") != "ok":
            arch, shape, mesh = rec["cell"].split("__")[:3]
            out.append(f"| {arch} | {shape} | {mesh} | – | – | – | – | "
                       f"ERROR | – | – | – | {rec.get('error','')[:40]} |")
            continue
        hbm = (rec.get("argument_bytes", 0)
               + rec.get("peak_memory_bytes", 0)) / 2 ** 30
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {rec.get('accum')} "
            f"| {rec['compute_s']*1e3:.2f} | {rec['memory_s']*1e3:.2f} "
            f"| {rec['collective_s']*1e3:.2f} | {rec['dominant']} "
            f"| {rec['useful_fraction']*100:.1f} "
            f"| {rec['roofline_fraction']*100:.2f} | {hbm:.2f} "
            f"| {_lever(rec)} |")
    return out


def render(run_dir: str = "runs/dryrun") -> str:
    return "\n".join([HEADER, SEP] + rows(run_dir))


def main() -> None:
    print(render())


if __name__ == "__main__":
    main()
