"""Trace-replay load harness: SLO-goodput is the headline number.

    PYTHONPATH=src python benchmarks/load_harness.py [--smoke]

Replays seeded request traces (serve/loadgen.py: Poisson, bursty MMPP,
heavy-tailed lognormal lengths, shared-prefix system-prompt mix)
through the asyncio ``ServeFrontend``
with streaming, cancellation (a seeded fraction of clients abandon
mid-stream), deadline shedding and bounded-queue backpressure enabled —
sustained open-loop traffic, not the 8-request makespan smoke that
``BENCH_serve.json`` reports.

Two configurations per trace, identical load:

* **adaptive** — ``AdaptiveCoreChunk`` + fused auto-depth decode +
  ``admission="adaptive"`` (the ``serve_admission`` ExecutionModel
  decision throttles burst admission from queue depth and measured
  tick time);
* **static**   — ``StaticCoreChunk`` on the per-tick decode path with
  greedy fill-every-slot admission: no measurement anywhere.

Reported per configuration (into ``BENCH_load.json``): **SLO-goodput**
(tokens/s from requests that completed within their deadline — the
number we quote), p50/p99 TTFT, p99 inter-token latency, deadline-miss
rate, shed/cancelled/rejected counts, and the admission-decision
provenance mix.  The ``shared_prefix`` trace adds a third
configuration — **paged** (the adaptive config on the
``PagedKVCachePool`` with copy-on-write prefix reuse) — and reports
its prefix-cache hit rate, prefill-tokens-avoided and per-tick
prefill-stall time alongside the goodput comparison against the
contiguous pool.  ``--speculate`` adds a **speculative** configuration
(adaptive + ``speculate="auto"``) on the ``templated`` trace (motif-
tiled, high n-gram self-overlap — where the prompt-lookup drafter gets
real acceptance) and on the ``heavy`` trace (low overlap — where the
``serve_spec_depth`` decision must back off to depth 1), reporting
acceptance rate, tokens-per-verify and decision provenance.
``--smoke`` runs small fixed-seed heavy-tailed and shared-prefix
traces and exits non-zero if adaptive SLO-goodput falls below static,
if the shared-prefix hit rate is zero, if paged goodput falls below
0.9x contiguous, or (with ``--speculate``) if speculative goodput
falls below non-speculative on the templated trace or below 0.95x on
the heavy trace (the CI regression guards).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.acc import AdaptiveCoreChunk, StaticCoreChunk  # noqa: E402
from repro.core.adaptive import adaptive  # noqa: E402
from repro.core.executor import SequentialExecutor  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import (GENERATORS, QueueFullError,  # noqa: E402
                         ServeFrontend, ServeScheduler, SLOModel,
                         materialize, percentile, trace_summary)


def make_trace(kind: str, n: int, seed: int, slo: SLOModel):
    """One seeded trace per (kind, n, seed): every configuration replays
    the identical load."""
    if kind == "poisson":
        return GENERATORS[kind](n, rate_rps=40.0, new_tokens=10,
                                seed=seed, slo=slo)
    if kind == "bursty":
        return GENERATORS[kind](n, base_rate_rps=15.0, burst_rate_rps=150.0,
                                mean_dwell_s=(1.0, 0.3), new_tokens=10,
                                seed=seed, slo=slo)
    if kind == "heavy":
        return GENERATORS[kind](n, rate_rps=40.0, seed=seed, slo=slo)
    if kind == "templated":
        # High n-gram self-overlap (motif-tiled prompts, cyclic greedy
        # continuations) — the workload where the prompt-lookup drafter
        # gets real acceptance, so the speculative configuration's win
        # is measurable under the full async front end.
        return GENERATORS[kind](n, rate_rps=40.0, motif_len=6,
                                median_prompt=16, prompt_sigma=0.3,
                                max_prompt=32, median_new=32,
                                new_sigma=0.3, max_new=64,
                                seed=seed, slo=slo)
    if kind == "shared_prefix":
        # Shaped like the production case for prefix reuse — a long
        # shared system prompt, short per-request suffixes and answers
        # — and driven hard enough, under a tight TTFT-dominated SLO,
        # to *deeply* saturate the contiguous pool (which must prefill
        # all 512 shared tokens per request) while the paged pool,
        # skipping them on every prefix hit, stays clear.  Both ends
        # matter: at a rate every policy absorbs the avoided prefill
        # becomes idle time instead of goodput and the comparison
        # ties, and a baseline only marginally over its cliff flips
        # with run-to-run machine noise.  Short answers keep the
        # comparison about prefill (what the cache avoids) rather
        # than decode volume.
        tight = SLOModel(ttft_s=0.25, per_token_s=0.015)
        return GENERATORS[kind](n, rate_rps=150.0, prefix_len=512,
                                median_new=2, max_new=4,
                                seed=seed, slo=tight)
    raise ValueError(f"unknown trace kind {kind!r}")


def build_sched(policy: str, cfg, params, *, n_slots: int,
                max_len: int) -> ServeScheduler:
    if policy in ("adaptive", "paged", "speculative"):
        return ServeScheduler(
            cfg, params, n_slots=n_slots, max_len=max_len,
            executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
            dispatch_depth="auto", admission="adaptive",
            paged=policy == "paged",
            speculate="auto" if policy == "speculative" else None)
    return ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=max_len,
        executor=adaptive(SequentialExecutor(),
                          StaticCoreChunk(cores=1, chunks_per_core=8)),
        admission="greedy")


async def replay(frontend: ServeFrontend, mat_trace, *,
                 cancel_frac: float, seed: int) -> float:
    """Open-loop replay: every request is submitted at its trace time
    regardless of system state; a seeded ``cancel_frac`` of clients
    abandon their stream mid-generation.  Returns the makespan."""
    rng = np.random.RandomState(seed + 7919)
    cancel_at = {}
    for i, (tr, _) in enumerate(mat_trace):
        if rng.random_sample() < cancel_frac and tr.new_tokens >= 2:
            cancel_at[i] = int(rng.randint(1, tr.new_tokens))
    t0 = time.monotonic()

    async def one(i, tr, prompt):
        delay = tr.arrival_s - (time.monotonic() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        deadline = None if tr.deadline_s is None else t0 + tr.deadline_s
        try:
            stream = await frontend.submit(prompt, tr.new_tokens,
                                           deadline=deadline)
        except QueueFullError:
            return          # backpressure: shed at the door, counted
        k = cancel_at.get(i)
        got = 0
        async for _tok in stream:
            got += 1
            if k is not None and got >= k:
                await stream.cancel()

    await asyncio.gather(*(one(i, tr, p)
                           for i, (tr, p) in enumerate(mat_trace)))
    return time.monotonic() - t0


def run_config(name: str, cfg, params, mat_trace, *, n_slots: int,
               max_len: int, max_queue: int, cancel_frac: float,
               seed: int) -> tuple[dict, ServeScheduler]:
    sched = build_sched(name, cfg, params, n_slots=n_slots,
                        max_len=max_len)
    sched.warmup()
    # Untimed prewarm: compile every distinct prompt-length host op so
    # the timed replay measures serving, not the process's one-time
    # compiles (same discipline as benchmarks/serve_throughput.py).
    by_len = {}
    for tr, prompt in mat_trace:
        by_len.setdefault(int(tr.prompt_len), prompt)
    for prompt in by_len.values():
        sched.submit(prompt, max_new_tokens=4)
    sched.run_until_idle()
    sched.clear_finished()
    sched.decode_dispatches = sched.decode_tokens = 0
    sched.host_roundtrips = 0
    sched.host_overhead_s = 0.0
    sched.deadline_misses = sched.shed = sched.cancelled = 0
    sched.spec_verifies = sched.spec_emitted = sched.spec_rounds = 0
    if sched.paged:
        # Cached prefix entries from the prewarm stay live (that's the
        # steady state a hot system prompt reaches); only the counters
        # reset so the reported hit rate covers the replayed trace.
        sched.pool.reset_prefix_stats()
        sched.prefill_stall_s = 0.0
    model = sched.decision_model()
    admit_seen = len(model.trace.entries("serve_admission")) \
        if model is not None else 0
    spec_seen = len(model.trace.entries("serve_spec_depth")) \
        if model is not None else 0

    frontend = ServeFrontend(sched, max_queue=max_queue)

    async def go():
        async with frontend:
            return await replay(frontend, mat_trace,
                                cancel_frac=cancel_frac, seed=seed)

    makespan = asyncio.run(go())

    recs = list(frontend.records.values())
    completed = [r for r in recs if r.status == "completed"]
    in_slo = [r for r in completed if not r.missed]
    cancelled = sum(1 for r in recs if r.status == "cancelled")
    shed = sum(1 for r in recs if r.status == "shed")
    late = sum(1 for r in completed if r.missed)
    eligible = max(len(mat_trace) - cancelled, 1)
    ttfts = [r.first_token_at - r.submitted_at for r in recs
             if r.first_token_at is not None]
    itls = [b - a for r in recs
            for a, b in zip(r.token_times, r.token_times[1:], strict=False)]
    gen = sum(r.tokens for r in recs)
    report = {
        "policy": name,
        "requests": len(mat_trace),
        "completed": len(completed),
        "completed_in_slo": len(in_slo),
        "generated_tokens": gen,
        "makespan_s": round(makespan, 3),
        # The headline: tokens that arrived in time, per second.
        "slo_goodput_tok_s": round(
            sum(r.tokens for r in in_slo) / makespan, 2) if makespan
        else 0.0,
        "tokens_per_s": round(gen / makespan, 2) if makespan else 0.0,
        "ttft_p50_ms": round(percentile(ttfts, 50) * 1e3, 1),
        "ttft_p99_ms": round(percentile(ttfts, 99) * 1e3, 1),
        "itl_p99_ms": round(percentile(itls, 99) * 1e3, 1),
        "deadline_miss_rate": round(
            (late + shed + frontend.rejected) / eligible, 4),
        "late_completions": late,
        "shed": shed,
        "cancelled": cancelled,
        "rejected": frontend.rejected,
        "ticks": len(sched.trace),
        "host_overhead_ms_per_token":
            round(sched.host_overhead_s / gen * 1e3, 3) if gen else 0.0,
    }
    if sched.paged:
        stats = sched.pool.prefix_stats()
        stats["prefix_hit_rate"] = round(stats["prefix_hit_rate"], 4)
        report["prefix"] = stats
        report["prefill_stall_s"] = round(sched.prefill_stall_s, 4)
    if sched._spec:
        st = sched.spec_stats()
        report["speculate"] = {
            "final_depth": st["depth"],
            "verifies": st["verifies"],
            "emitted": st["emitted"],
            "tokens_per_verify": round(st["tokens_per_verify"], 3),
            "acceptance_rate": round(st["acceptance_rate"], 4),
        }
        if model is not None:
            entries = model.trace.entries("serve_spec_depth")[spec_seen:]
            report["spec_decisions"] = len(entries)
            report["spec_provenance"] = sorted(
                {e.decision.provenance for e in entries})
    if model is not None:
        entries = model.trace.entries("serve_admission")[admit_seen:]
        report["admission_decisions"] = len(entries)
        report["admission_provenance"] = sorted(
            {e.decision.provenance for e in entries})
        widths = [e.decision.cores for e in entries]
        report["mean_admission_width"] = round(
            float(np.mean(widths)), 2) if widths else 0.0
        if sched.paged:
            for label, kind in (("page_size", "serve_page_size"),
                                ("interleave", "serve_prefill_interleave")):
                es = model.trace.entries(kind)
                report[f"{label}_provenance"] = sorted(
                    {e.decision.provenance for e in es})
    extra = ""
    if sched.paged:
        extra = (f" | prefix hits {report['prefix']['prefix_hit_rate']:.0%}"
                 f" avoided {report['prefix']['prefill_tokens_avoided']} tok"
                 f" | stall {report['prefill_stall_s'] * 1e3:.0f}ms")
    if sched._spec:
        sp = report["speculate"]
        extra = (f" | spec depth={sp['final_depth']} "
                 f"{sp['tokens_per_verify']:.2f} tok/verify "
                 f"(acceptance {sp['acceptance_rate']:.0%})")
    print(f"  {name:9s} goodput {report['slo_goodput_tok_s']:8.1f} tok/s "
          f"| ttft p99 {report['ttft_p99_ms']:7.1f}ms "
          f"| itl p99 {report['itl_p99_ms']:6.1f}ms "
          f"| miss {report['deadline_miss_rate']:.1%} "
          f"| shed {shed} cancelled {cancelled} rejected "
          f"{frontend.rejected}{extra}")
    return report, sched


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fixed-seed heavy-tailed trace; exits "
                         "non-zero if adaptive SLO-goodput < static")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per trace (default: 1000 heavy / "
                         "256 others; 64 with --smoke)")
    ap.add_argument("--traces", default=None,
                    help="comma list from {heavy,poisson,bursty,"
                         "shared_prefix,templated} (default: all four "
                         "random kinds; heavy + shared_prefix with "
                         "--smoke, plus templated with --speculate)")
    ap.add_argument("--speculate", action="store_true",
                    help="additionally run the speculative "
                         "configuration (adaptive + speculate='auto') "
                         "on the templated and heavy traces; with "
                         "--smoke, fails if speculative goodput falls "
                         "below non-speculative on the templated trace "
                         "or below 0.95x on the heavy (low-overlap) "
                         "trace — the backoff guard")
    ap.add_argument("--seed", type=int, default=0,
                    help="single seed for arrivals, lengths, prompt "
                         "tokens and cancellation choices")
    ap.add_argument("--cancel-frac", type=float, default=0.05,
                    help="fraction of clients that abandon mid-stream")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=128)
    ap.add_argument("--slo-ttft-ms", type=float, default=750.0)
    ap.add_argument("--slo-per-token-ms", type=float, default=60.0)
    ap.add_argument("--trace-out", default=None,
                    help="write the adaptive run's ExecutionModel "
                         "decision trace to this file")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_load.json"))
    args = ap.parse_args()

    kinds = (args.traces.split(",") if args.traces
             else (["heavy", "shared_prefix"] if args.smoke
                   else ["heavy", "poisson", "bursty", "shared_prefix"]))
    if args.speculate and "templated" not in kinds:
        kinds.append("templated")
    slo = SLOModel(ttft_s=args.slo_ttft_ms / 1e3,
                   per_token_s=args.slo_per_token_ms / 1e3)

    cfg = get_config("qwen3-0.6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    blob: dict = {"traces": {}, "smoke": bool(args.smoke),
                  "seed": args.seed,
                  "slo": {"ttft_ms": args.slo_ttft_ms,
                          "per_token_ms": args.slo_per_token_ms}}
    guard_ok = True
    explain_dump = None
    for kind in kinds:
        # The shared_prefix smoke needs enough sustained arrivals for
        # the contiguous pool's prefill queue to actually build — a
        # 64-request burst is absorbed by every policy and the paged
        # comparison degenerates to parity noise.
        n = args.requests or ((256 if kind == "shared_prefix" else 64)
                              if args.smoke
                              else (1000 if kind in ("heavy",
                                                     "shared_prefix")
                                    else 256))
        trace = make_trace(kind, n, args.seed, slo)
        max_len = max(t.prompt_len + t.new_tokens for t in trace) + 1
        # The shared-prefix trace additionally runs the paged pool with
        # copy-on-write prefix reuse against the contiguous adaptive
        # config — same load, same policy, only the cache layout
        # differs — so the goodput delta isolates what paging buys.
        # --speculate adds the speculative configuration on the
        # templated trace (where the drafter gets real acceptance) and
        # the heavy trace (low overlap: the backoff tax measurement).
        policies = (("paged", "adaptive", "static")
                    if kind == "shared_prefix" else ("adaptive", "static"))
        if args.speculate and kind in ("templated", "heavy"):
            policies = ("speculative",) + policies
            # Reserved draft margin: the last spec_d - 1 cache
            # positions are unusable under speculation (scheduler
            # docstring); every policy gets the same geometry so the
            # comparison stays layout-for-layout.
            max_len += 8
        mat = materialize(trace, cfg.vocab_size, seed=args.seed)
        print(f"{kind}: {trace_summary(trace)}")
        reports = {}
        for policy in policies:
            reports[policy], sched = run_config(
                policy, cfg, params, mat, n_slots=args.slots,
                max_len=max_len, max_queue=args.max_queue,
                cancel_frac=args.cancel_frac, seed=args.seed)
            if policy == "adaptive" and args.trace_out:
                model = sched.decision_model()
                if model is not None:
                    explain_dump = model.explain()
        ratio = (reports["adaptive"]["slo_goodput_tok_s"]
                 / reports["static"]["slo_goodput_tok_s"]) \
            if reports["static"]["slo_goodput_tok_s"] else float("inf")
        blob["traces"][kind] = {
            "trace": trace_summary(trace),
            **{p: reports[p] for p in policies},
            "adaptive_over_static_goodput": round(ratio, 3)
            if ratio != float("inf") else None,
        }
        print(f"  adaptive/static SLO-goodput: "
              f"{'inf' if ratio == float('inf') else f'{ratio:.2f}x'}")
        if reports["adaptive"]["slo_goodput_tok_s"] \
                < reports["static"]["slo_goodput_tok_s"]:
            guard_ok = False
        if kind == "shared_prefix":
            pr = (reports["paged"]["slo_goodput_tok_s"]
                  / reports["adaptive"]["slo_goodput_tok_s"]) \
                if reports["adaptive"]["slo_goodput_tok_s"] else float("inf")
            blob["traces"][kind]["paged_over_contiguous_goodput"] = \
                round(pr, 3) if pr != float("inf") else None
            hit = reports["paged"]["prefix"]["prefix_hit_rate"]
            print(f"  paged/contiguous SLO-goodput: "
                  f"{'inf' if pr == float('inf') else f'{pr:.2f}x'} "
                  f"(prefix hit rate {hit:.0%})")
            if hit <= 0.0:
                print("FAIL: shared-prefix trace produced a zero "
                      "prefix-cache hit rate — reuse is not engaging")
                guard_ok = False
            # Smoke guard: the paged pool must not lose to contiguous.
            # A small tolerance keeps run-to-run parity noise (the two
            # policies tie when neither saturates on a fast runner)
            # from flaking CI; a real regression — lost prefix cache,
            # donation bug, recompile per dispatch — lands far below.
            if reports["paged"]["slo_goodput_tok_s"] \
                    < 0.9 * reports["adaptive"]["slo_goodput_tok_s"]:
                print("FAIL: paged SLO-goodput below the contiguous "
                      "adaptive baseline on the shared-prefix trace")
                guard_ok = False
        if "speculative" in policies:
            sr = (reports["speculative"]["slo_goodput_tok_s"]
                  / reports["adaptive"]["slo_goodput_tok_s"]) \
                if reports["adaptive"]["slo_goodput_tok_s"] else float("inf")
            blob["traces"][kind]["speculative_over_adaptive_goodput"] = \
                round(sr, 3) if sr != float("inf") else None
            print(f"  speculative/adaptive SLO-goodput: "
                  f"{'inf' if sr == float('inf') else f'{sr:.2f}x'} "
                  f"({kind} trace)")
            if args.smoke and sr != float("inf"):
                if kind == "templated" and sr < 0.95:
                    # Open-loop goodput is arrival-bound here: both
                    # configurations absorb the offered rate and tie,
                    # so the guard is "must not lose" with the same
                    # noise tolerance as the paged guard — the raw
                    # speculative throughput multiplier (1.2x) is
                    # guarded in benchmarks/serve_throughput.py where
                    # the replay is device-bound.
                    print("FAIL: speculative SLO-goodput below "
                          "non-speculative on the templated trace")
                    guard_ok = False
                if kind == "heavy" and sr < 0.95:
                    # Low-overlap trace: acceptance collapses, the
                    # serve_spec_depth decision must back off to depth
                    # 1 and keep the speculation tax within noise.
                    print("FAIL: speculative SLO-goodput below 0.95x "
                          "adaptive on the heavy (low-overlap) trace — "
                          "acceptance backoff is not engaging")
                    guard_ok = False

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(blob, f, indent=1)
    print(f"-> {out}")
    if explain_dump is not None and args.trace_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.trace_out)),
                    exist_ok=True)
        with open(args.trace_out, "w") as f:
            f.write(explain_dump + "\n")
        print(f"-> {args.trace_out}")
    if args.smoke and not guard_ok:
        print("FAIL: adaptive SLO-goodput below the static baseline — "
              "serving-front-end regression")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
