"""Re-derive collective terms in dry-run artifacts with the ring-wire
model (all-reduce = 2× buffer bytes; see roofline.wire_bytes) and refresh
the derived fields.  Idempotent; run after a sweep if the parser/metric
changed:

    PYTHONPATH=src python -m benchmarks.reprocess_artifacts [runs/dryrun]
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.analysis.roofline import wire_bytes
from repro.core.hardware import TPU_V5E


def reprocess(path: str) -> bool:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return False
    det = rec.get("collective_detail") or {}
    if "multiplier" in det:      # calibrated (train/prefill) record
        mult = det["multiplier"]
        wa = wire_bytes(det["group"]["bytes"])
        wb = wire_bytes(det["base"]["bytes"])
        wt = wb + mult * (wa - wb)
    elif "bytes" in det:         # direct (decode) record
        wt = wire_bytes(det["bytes"])
    else:
        return False
    rec["collective_bytes_per_device"] = wt
    rec["collective_s"] = wt / TPU_V5E.link_bw
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    rec["dominant"] = max(terms, key=terms.get)
    rec["step_time_s"] = max(terms.values())
    ideal = rec["model_flops"] / (rec["chips"] * TPU_V5E.peak_flops)
    rec["roofline_fraction"] = (ideal / rec["step_time_s"]
                                if rec["step_time_s"] > 0 else 0.0)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    return True


def main() -> None:
    run_dir = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun"
    n = sum(reprocess(p)
            for p in sorted(glob.glob(os.path.join(run_dir, "*.json"))))
    print(f"reprocessed {n} artifacts in {run_dir}")


if __name__ == "__main__":
    main()
