"""Multi-device paths (8 fake CPU devices, subprocess: jax locks device
count at first init): mesh algorithms, compressed-DP training, elastic
resharding, sharding-rule divisibility."""

import pytest

MESH_ALGOS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import par, MeshExecutor, StaticCoreChunk, AdaptiveCoreChunk
from repro.launch.mesh import make_mesh
from repro import algorithms as alg

mesh = make_mesh((8,), ("data",))
pol = par.on(MeshExecutor(mesh)).with_(StaticCoreChunk(cores=8))
x = jnp.asarray(np.random.RandomState(1).rand(1003).astype(np.float32))
xs = np.asarray(x)

np.testing.assert_allclose(np.asarray(alg.transform(pol, x, lambda c: c*3-1)),
                           xs*3-1, rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(float(alg.reduce(pol, x, jnp.add)),
                           np.sum(xs, dtype=np.float32), rtol=1e-4)
np.testing.assert_allclose(np.asarray(alg.inclusive_scan(pol, x)),
                           np.cumsum(xs), rtol=1e-4)
ref = np.concatenate([xs[:1], np.diff(xs)])
np.testing.assert_allclose(np.asarray(alg.adjacent_difference(pol, x)), ref,
                           rtol=1e-4, atol=1e-6)
st = np.asarray(alg.stencil3(pol, x))
refst = xs.copy(); refst[1:-1] = xs[:-2] - 2*xs[1:-1] + xs[2:]
np.testing.assert_allclose(st, refst, rtol=1e-4, atol=1e-5)
# acc on mesh uses the analytic T0 path
pol_acc = par.on(MeshExecutor(mesh)).with_(AdaptiveCoreChunk())
np.testing.assert_allclose(np.asarray(alg.adjacent_difference(pol_acc, x)),
                           ref, rtol=1e-4, atol=1e-6)
print("MESH_OK")
"""

COMPRESSED_DP = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.configs import get_config
from repro.models import init_params
from repro.optim import AdamWConfig, adamw
from repro.train import (make_train_step, make_compressed_dp_train_step,
                         init_error_feedback)
from repro.data import make_batch
from repro.launch.mesh import make_mesh

cfg = get_config("qwen3-0.6b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=1e-3)
opt = adamw.init_state(params)
mesh = make_mesh((8,), ("data",))
batch = make_batch(cfg, 8, 32, kind="train", seed=0)

step_c = make_compressed_dp_train_step(cfg, opt_cfg, mesh)
ef = init_error_feedback(params, 8)
p, o = params, opt
for _ in range(5):
    p, o, ef, m = step_c(p, o, ef, batch)
loss_c = float(m["loss"])

step_u = jax.jit(make_train_step(cfg, opt_cfg))
pu, ou = params, opt
for _ in range(5):
    pu, ou, mu = step_u(pu, ou, batch)
loss_u = float(mu["loss"])
assert abs(loss_c - loss_u) < 0.05, (loss_c, loss_u)
print(f"COMPRESS_OK {loss_c:.4f} {loss_u:.4f}")
"""

ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.runtime import surviving_mesh, elastic_plan, reshard
from repro.core.cost_model import WorkloadProfile
from jax.sharding import PartitionSpec as P

m8 = surviving_mesh(8)
assert m8.shape["data"] * m8.shape["model"] == 8
# lose half the devices -> re-mesh over 4
m4 = surviving_mesh(4)
assert m4.shape["data"] * m4.shape["model"] == 4
prof = WorkloadProfile(flops_per_elem=1e6, bytes_per_elem=100)
d8 = elastic_plan(prof, 10**6, m8)
d4 = elastic_plan(prof, 10**6, m4)
assert d4.n_cores <= 4 and d8.n_cores <= 8
tree = {"w": jnp.arange(32.0).reshape(8, 4)}
t4 = reshard(tree, m4, {"w": P("data", None)})
assert t4["w"].sharding.mesh.shape["data"] == m4.shape["data"]
print("ELASTIC_OK")
"""

DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp, functools
from repro.configs import get_config, base
from repro.launch import sharding
from repro.launch.mesh import make_mesh
from repro.models import lm, flags
from repro.optim import adamw, AdamWConfig
from repro.train import make_train_step
from repro.data import make_batch, input_specs
from repro.analysis import roofline

# a reduced arch on a small (4,2) mesh: lower+compile+RUN one step
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config("mixtral-8x22b").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init_state(params)
batch = make_batch(cfg, 8, 16, kind="train", seed=0)
pspec = sharding.param_specs(params, mesh)
ospec = sharding.opt_specs(pspec)
bspec = {k: sharding.batch_specs(cfg, mesh, 8)[k] for k in batch}
step = make_train_step(cfg, AdamWConfig(lr=1e-3), accum=2)
from jax.sharding import NamedSharding, PartitionSpec as P
jitted = jax.jit(step,
                 in_shardings=(sharding.to_shardings(mesh, pspec),
                               sharding.to_shardings(mesh, ospec),
                               sharding.to_shardings(mesh, bspec)))
with flags.activation_sharding(NamedSharding(mesh, P("data", None, None))):
    lowered = jitted.lower(
        jax.eval_shape(functools.partial(lm.init_params, cfg=cfg),
                       jax.random.PRNGKey(0)),
        jax.eval_shape(adamw.init_state, params),
        {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()})
compiled = lowered.compile()
ma = compiled.memory_analysis()
assert ma.argument_size_in_bytes > 0
cb = roofline.collective_bytes(compiled.as_text())
assert cb["bytes"]["total"] > 0, "sharded step must communicate"
# and it actually RUNS distributed
params = jax.device_put(params, sharding.to_shardings(mesh, pspec))
opt = jax.device_put(opt, sharding.to_shardings(mesh, ospec))
batch = jax.device_put(batch, sharding.to_shardings(mesh, bspec))
with flags.activation_sharding(NamedSharding(mesh, P("data", None, None))):
    p2, o2, m = jax.jit(step, in_shardings=(
        sharding.to_shardings(mesh, pspec),
        sharding.to_shardings(mesh, ospec),
        sharding.to_shardings(mesh, bspec)))(params, opt, batch)
assert np.isfinite(float(m["loss"]))
print(f"DRYRUN_SMALL_OK loss={float(m['loss']):.3f} "
      f"coll={cb['bytes']['total']:.0f}")
"""


MESH_SERVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.core.acc import AdaptiveCoreChunk
from repro.core.adaptive import adaptive
from repro.core.executor import SequentialExecutor
from repro.data import make_batch
from repro.launch.mesh import make_serve_mesh, n_data_replicas
from repro.models import lm
from repro.serve import ServeScheduler

# Sharded fused serving must produce byte-identical tokens to the
# single-device fused path: tensor-parallel matmuls within a replica
# plus the 'data'-sharded slot pool may not change a single argmax.
cfg = get_config("qwen3-0.6b").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)
tokens = make_batch(cfg, 3, 14, kind="prefill", seed=11)["tokens"]
spec = [(14, 9), (9, 3), (6, 7)]      # (prompt_len, new_tokens) per req

def run(depth, mesh=None, n_slots=2):
    sched = ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=48,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=depth, mesh=mesh)
    sched.warmup()
    rids = [sched.submit(tokens[i][:p], max_new_tokens=n)
            for i, (p, n) in enumerate(spec)]
    outs = sched.run_until_idle()
    assert sched.pool.allocations == 1, "donation invariant broke"
    return [outs[r] for r in rids], sched

mesh = make_serve_mesh(4, 2)
assert n_data_replicas(mesh) == 4
for k in (1, 4):
    ref, _ = run(k)
    got, sched = run(k, mesh=mesh, n_slots=4)
    assert got == ref, (k, got, ref)
    entries = sched.decision_model().trace.entries("serve_mesh_batch")
    assert entries, "mesh run made no serve_mesh_batch decisions"
    for e in entries:
        assert "mesh=4x2" in e.decision.key.hardware
        assert e.decision.batch_width == e.decision.cores * 4
print("MESH_SERVE_OK")
"""


PAGED_MESH_SERVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.core.acc import AdaptiveCoreChunk
from repro.core.adaptive import adaptive
from repro.core.executor import SequentialExecutor
from repro.data import make_batch
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.serve import ServeScheduler

# The paged pool on a 4x2 mesh must not move a single argmax vs the
# contiguous single-device fused path: page-table indirection and the
# 'data'-replicated page stores are pure layout.
cfg = get_config("qwen3-0.6b").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)
tokens = make_batch(cfg, 3, 14, kind="prefill", seed=11)["tokens"]
spec = [(14, 9), (9, 3), (6, 7)]

def run(depth, paged, mesh=None, n_slots=2):
    sched = ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=48,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=depth, mesh=mesh, paged=paged)
    sched.warmup()
    rids = [sched.submit(tokens[i][:p], max_new_tokens=n)
            for i, (p, n) in enumerate(spec)]
    outs = sched.run_until_idle()
    assert sched.pool.allocations == 1, "donation invariant broke"
    return [outs[r] for r in rids], sched

mesh = make_serve_mesh(4, 2)
for k in (1, 4):
    ref, _ = run(k, paged=False)
    got_single, _ = run(k, paged=True)
    assert got_single == ref, ("single", k)
    got_mesh, sched = run(k, paged=True, mesh=mesh, n_slots=4)
    assert got_mesh == ref, ("mesh", k)
    assert sched.decision_model().trace.entries("serve_page_size"), \
        "paged mesh run made no serve_page_size decisions"
print("PAGED_MESH_SERVE_OK")
"""


@pytest.mark.parametrize("name,code,marker", [
    ("mesh_algorithms", MESH_ALGOS, "MESH_OK"),
    ("compressed_dp", COMPRESSED_DP, "COMPRESS_OK"),
    ("elastic", ELASTIC, "ELASTIC_OK"),
    ("dryrun_small", DRYRUN_SMALL, "DRYRUN_SMALL_OK"),
    ("mesh_serve", MESH_SERVE, "MESH_SERVE_OK"),
    ("paged_mesh_serve", PAGED_MESH_SERVE, "PAGED_MESH_SERVE_OK"),
])
def test_multidevice(subproc, name, code, marker):
    r = subproc(code, n_devices=8)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr[-3000:]}"
    assert marker in r.stdout


def test_sharding_rules_divisibility():
    """Every spec axis must divide its dim on the production meshes (the
    _fit fallback guarantees it); check against real param trees."""
    import jax

    from repro.configs import ARCH_NAMES, get_config
    from repro.launch import sharding
    from repro.models import lm

    class StubMesh:
        shape = {"data": 16, "model": 16}

    for name in ARCH_NAMES:
        cfg = get_config(name)
        params_s = jax.eval_shape(
            lambda k, c=cfg: lm.init_params(k, c), jax.random.PRNGKey(0))
        specs = sharding.param_specs(params_s, StubMesh())
        flat_p = jax.tree_util.tree_flatten_with_path(params_s)[0]
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s, strict=True):
            # spec may be shorter than the leaf rank (trailing dims
            # unsharded) -- truncation is the semantics here
            for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
                if ax is not None:
                    assert dim % StubMesh.shape[ax] == 0, (name, path, spec)
