"""Training loop, checkpointing, fault tolerance, serving integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.data import make_batch
from repro.models import init_params
from repro.optim import AdamWConfig, adamw
from repro.runtime import (FaultTolerantTrainer, SimulatedFailure,
                           mitigation_table)
from repro.serve import ServeEngine
from repro.train import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    batch = make_batch(cfg, 4, 32, kind="train", seed=0)
    return cfg, params, opt, batch


def test_loss_decreases(setup):
    cfg, params, opt, batch = setup
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    p, o = params, opt
    losses = []
    for _ in range(8):
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_grad_accum_equivalence(setup):
    """accum=2 on a homogeneous batch == accum=1 (same grads, same step)."""
    cfg, params, opt, batch = setup
    s1 = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), accum=1))
    s2 = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), accum=2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2),
                    strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip(setup, tmp_path):
    cfg, params, opt, _ = setup
    d = str(tmp_path / "ck")
    checkpointer.save(d, 7, (params, opt))
    path = checkpointer.latest(d)
    assert path and path.endswith("step_00000007")
    (p2, o2), step = checkpointer.restore(path, (params, opt))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_atomicity(setup, tmp_path):
    cfg, params, opt, _ = setup
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        checkpointer.save(d, s, {"x": jnp.ones(3)}, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    # a checkpoint without DONE must be invisible
    os.remove(os.path.join(d, "step_00000005", "DONE"))
    assert checkpointer.latest(d).endswith("step_00000004")


def test_async_checkpointer(setup, tmp_path):
    cfg, params, opt, _ = setup
    d = str(tmp_path / "ck")
    ac = checkpointer.AsyncCheckpointer(d)
    ac.save_async(3, {"w": jnp.arange(5)})
    ac.wait()
    assert checkpointer.latest(d).endswith("step_00000003")


def test_ft_restart_recovers(setup, tmp_path):
    cfg, params, opt, batch = setup
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    fails = {5, 9}

    def hook(s):
        if s in fails:
            fails.discard(s)
            raise SimulatedFailure(f"node lost @{s}")

    def data():
        i = 0
        while True:
            yield make_batch(cfg, 4, 32, kind="train", seed=i)
            i += 1

    tr = FaultTolerantTrainer(step, str(tmp_path / "ft"), save_every=3,
                              failure_hook=hook)
    p, o, log = tr.run(params, opt, data(), num_steps=12)
    assert len(log) >= 12          # all 12 steps eventually ran
    assert not fails               # both failures were hit and survived
    assert checkpointer.latest(str(tmp_path / "ft")) is not None


def test_ft_exceeds_max_restarts(setup, tmp_path):
    cfg, params, opt, batch = setup
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))

    def hook(s):
        raise SimulatedFailure("always")

    def data():
        while True:
            yield batch

    tr = FaultTolerantTrainer(step, str(tmp_path / "ft2"), save_every=3,
                              failure_hook=hook, max_restarts=2)
    with pytest.raises(SimulatedFailure):
        tr.run(params, opt, data(), num_steps=5)


def test_serve_prefill_chunking_consistent(setup):
    """Chunked prefill (acc-sized chunks) == one big prefill."""
    cfg, params, _, _ = setup
    tokens = make_batch(cfg, 2, 17, kind="prefill", seed=3)["tokens"]
    e1 = ServeEngine(cfg, params, batch=2, max_len=64)
    l1 = e1.prefill(tokens, chunk=5)
    e2 = ServeEngine(cfg, params, batch=2, max_len=64)
    l2 = e2.prefill(tokens, chunk=17)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)
    assert e1.pos == e2.pos == 17


def test_swa_ring_cache_matches_full(setup):
    """For pos < window the ring cache must equal full attention."""
    cfg0 = get_config("h2o-danube-1.8b").reduced()
    from repro.models import forward, forward_cached, init_caches

    params = init_params(jax.random.PRNGKey(1), cfg0)
    batch = make_batch(cfg0, 2, 12, kind="train", seed=2)
    full, _ = forward(params, batch, cfg0)
    caches = init_caches(cfg0, 2, 12)
    for t in range(12):
        lg, caches = forward_cached(params, batch["tokens"][:, t:t + 1],
                                    caches, t, cfg0)
        err = float(jnp.max(jnp.abs(lg[:, 0].astype(jnp.float32)
                                    - full[:, t].astype(jnp.float32))))
        assert err < 2e-2, (t, err)


def test_straggler_mitigation_c8():
    tab = mitigation_table(slowdown=5.0, n_devices=64)
    assert tab[8] < tab[1]          # C=8 strictly better than C=1
    assert tab[8] < 1.6             # bounded overhead at 5x stragglers


def test_windowed_prefill_crosses_ring_boundary():
    """Prefill longer than the SWA window must chunk at ring boundaries
    (regression: dynamic_update_slice overflow)."""
    cfg = get_config("h2o-danube-1.8b").reduced()   # window 16 reduced
    from repro.models import init_params as ip

    params = ip(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch=2, max_len=64)
    tokens = make_batch(cfg, 2, 40, kind="prefill", seed=1)["tokens"]
    logits = eng.prefill(tokens, chunk=24)      # 24 > window=16
    assert logits.shape[0] == 2 and eng.pos == 40
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
