"""Property-based tests (hypothesis) for the acc execution-parameters
object — the system's core invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (AdaptiveCoreChunk, SequentialExecutor,
                        StaticCoreChunk)
from repro.core import overhead_law as ol
from repro.core.simmachine import SimMachine


class _FakeExec:
    def __init__(self, n):
        self._n = n

    def num_units(self):
        return self._n


times = st.floats(min_value=1e-10, max_value=1e-3, allow_nan=False)
counts = st.integers(min_value=1, max_value=10**8)
cores = st.integers(min_value=1, max_value=4096)


@given(t_iter=times, count=counts, t0=times, max_cores=cores)
@settings(max_examples=200, deadline=None)
def test_decision_invariants(t_iter, count, t0, max_cores):
    d = ol.decide(t_iter=t_iter, n_elements=count, t0=t0,
                  max_cores=max_cores)
    assert 1 <= d.n_cores <= max_cores
    assert 1 <= d.chunk_elems <= count
    assert d.n_chunks * d.chunk_elems >= count
    assert d.n_cores <= max(d.n_chunks, 1)
    # the model never predicts worse-than-sequential execution
    assert d.predicted_time <= d.t1 * (1 + 1e-9) or d.n_cores == 1


@given(t_iter=times, t0=times, max_cores=st.integers(2, 512),
       c1=st.integers(10, 10**7), c2=st.integers(10, 10**7))
@settings(max_examples=200, deadline=None)
def test_cores_monotone_in_workload(t_iter, t0, max_cores, c1, c2):
    lo, hi = sorted((c1, c2))
    d_lo = ol.decide(t_iter=t_iter, n_elements=lo, t0=t0,
                     max_cores=max_cores)
    d_hi = ol.decide(t_iter=t_iter, n_elements=hi, t0=t0,
                     max_cores=max_cores)
    assert d_hi.n_cores >= d_lo.n_cores  # bigger workload, >= cores


@given(t_iter=st.floats(1e-9, 1e-6), count=st.integers(100, 10**7),
       static_cores=st.integers(1, 40))
@settings(max_examples=100, deadline=None)
def test_acc_beats_static_under_model(t_iter, count, static_cores):
    """The paper's claim, stated precisely: the acc decision is the
    fastest configuration *among those meeting the efficiency target*
    (Eq. 7 optimises for E=0.95, not raw minimum time — a static config
    below the target may be faster but wastes cores; paper Section 5:
    "it leaves cores available for other parallel tasks")."""
    t0 = 18e-6
    d = ol.decide(t_iter=t_iter, n_elements=count, t0=t0, max_cores=40)
    static_time = ol.predicted_time(t_iter * count, static_cores, t0)
    static_eff = ol.efficiency(t_iter * count, static_cores, t0)
    if static_eff >= d.efficiency_target or static_cores == 1:
        assert d.predicted_time <= static_time * (1 + 1e-9)
    # and in the large-workload regime acc matches the unrestricted best
    if t_iter * count >= 1000 * t0:
        best = min(ol.predicted_time(t_iter * count, n, t0)
                   for n in range(1, 41))
        assert d.predicted_time <= best * 1.05


@given(t_iter=st.floats(5e-10, 2e-7), count=st.integers(1000, 2 * 10**6))
@settings(max_examples=30, deadline=None)
def test_acc_tracks_envelope_on_simmachine(t_iter, count):
    """acc within 25% of the best static config on the calibrated machine
    model (noise, per-task overheads and core-dependent region overheads
    the closed form doesn't know), and never below sequential."""
    m = SimMachine(name="t", cores=40, t0=18e-6, t_task=0.6e-6, jitter=0.0)
    d = ol.decide(t_iter=t_iter, n_elements=count, t0=m.t0_for(m.cores),
                  max_cores=40)
    t_acc = m.run_decision(d)
    t_seq = t_iter * count
    best_static = min(
        m.run(t_iter=t_iter, count=count, n_cores=n,
              chunk_elems=max(count // (n * 4), 1))
        for n in (1, 2, 4, 8, 16, 32, 40))
    assert t_acc <= max(best_static * 1.25, t_seq * 1.001)


def test_acc_customization_point_dispatch_order():
    """params overloads beat executor methods beat defaults (tag_invoke)."""
    from repro.core import customization as cp

    class ExecWithCP(SequentialExecutor):
        def processing_units_count(self, t_iter, count):
            return 7

    acc = AdaptiveCoreChunk(t0_override=1e-5)
    ex = ExecWithCP()
    # params (acc) takes precedence over the executor overload
    n = cp.processing_units_count(acc, ex, 1e-6, 10)
    assert n == 1  # acc decides sequential for a tiny workload
    # without params, the executor's overload wins over the default
    n2 = cp.processing_units_count(None, ex, 1e-6, 10)
    assert n2 == 7
    # with neither, the default queries num_units
    n3 = cp.processing_units_count(None, SequentialExecutor(), 1e-6, 10)
    assert n3 == 1


def test_static_params_match_openmp_semantics():
    st_ = StaticCoreChunk(cores=8, chunks_per_core=2)
    ex = _FakeExec(40)
    assert st_.processing_units_count(ex, 0.0, 1000) == 8
    assert st_.get_chunk_size(ex, 0.0, 8, 1000) == 63  # ceil(1000/16)


def test_acc_caches_measurement():
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    calls = []

    def body(start, size):
        calls.append(1)

    ex = SequentialExecutor()
    acc.measure_iteration(ex, body, 1000, key="k")
    n_after_first = len(calls)
    acc.measure_iteration(ex, body, 1000, key="k")
    assert len(calls) == n_after_first  # measured once per workload key
