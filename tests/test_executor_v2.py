"""Executor API v2: futures, async bulk execution, continuation chaining,
executor properties, the AdaptiveExecutor, and the removed v1 surface."""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import detail
from repro.core import (AdaptiveCoreChunk, AdaptiveExecutor, Chunk,
                        ExecutorAnnotations, Future, HostParallelExecutor,
                        MeshExecutor, SequentialExecutor,
                        UnsupportedOperation, UnsupportedProperty,
                        WorkloadProfile, adaptive, make_chunks,
                        mesh_executor_of, par, params_of, prefer, require,
                        seq, unwrap_executor, when_all, with_hint,
                        with_params, with_priority)
from repro.core import customization as cp


@pytest.fixture
def host():
    with HostParallelExecutor(max_workers=4) as ex:
        yield ex


# ---------------------------------------------------------------------------
# Futures
# ---------------------------------------------------------------------------

def test_futures_resolve_in_order(host):
    """when_all yields values in submission order even when later chunks
    finish first."""
    chunks = make_chunks(8, 1)

    def thunk(c: Chunk) -> int:
        time.sleep(0.002 * (len(chunks) - c.start))  # earlier chunks slower
        return c.start

    futs = host.bulk_async_execute(thunk, chunks)
    assert when_all(futs).result() == [c.start for c in chunks]
    assert all(f.done() for f in futs)


def test_future_ready_and_exceptional():
    assert Future.ready(41).result() == 41
    f = Future.exceptional(ValueError("boom"))
    with pytest.raises(ValueError, match="boom"):
        f.result()
    assert when_all([]).result() == []


def test_when_all_propagates_exception(host):
    def thunk(c: Chunk):
        if c.start == 2:
            raise RuntimeError("chunk 2 failed")
        return c.start

    futs = host.bulk_async_execute(thunk, make_chunks(4, 1))
    with pytest.raises(RuntimeError, match="chunk 2 failed"):
        when_all(futs).result()


def test_then_execute_chains(host):
    for ex in (SequentialExecutor(), host):
        f = ex.async_execute(lambda: 1)
        g = ex.then_execute(lambda v: v + 1, f)
        h = ex.then_execute(lambda v: v * 3, g)
        assert h.result() == 6

    # exceptions propagate down the chain
    f = host.async_execute(lambda: 1)
    g = host.then_execute(lambda v: 1 / 0, f)
    h = host.then_execute(lambda v: v + 1, g)
    with pytest.raises(ZeroDivisionError):
        h.result()


def test_sync_and_async_execute_single_task(host):
    for ex in (SequentialExecutor(), host):
        assert ex.sync_execute(lambda a, b: a + b, 2, 3) == 5
        assert ex.async_execute(lambda a: a * 2, 21).result() == 42


# ---------------------------------------------------------------------------
# Executor properties / annotations
# ---------------------------------------------------------------------------

def test_properties_round_trip_through_dataclasses_replace(host):
    hi = host.with_priority("high")
    assert hi.annotations.priority == "high"
    assert host.annotations.priority == "normal"     # original untouched
    assert hi is not host

    hinted = hi.with_hint({"numa": 0})
    assert hinted.annotations.priority == "high"     # annotations compose
    assert hinted.annotations.hint == {"numa": 0}

    # the annotation record is a frozen dataclass: replace() round-trips
    ann = dataclasses.replace(hinted.annotations, priority="low")
    assert ann == ExecutorAnnotations(priority="low", hint={"numa": 0})
    with pytest.raises(dataclasses.FrozenInstanceError):
        ann.priority = "normal"

    # clones share the pool: annotated executor still executes
    assert hinted.sync_execute(lambda: "ran") == "ran"


def test_policy_with_is_the_params_property():
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    pol = par.with_(acc)
    assert pol.params is acc and par.params is None
    assert prefer(with_params, par, acc).params is acc
    assert dataclasses.replace(pol, params=None).params is None


def test_policy_property_forwarding(host):
    pol = par.on(host).with_priority("high").with_hint("large-batch")
    assert pol.executor.annotations.priority == "high"
    assert pol.executor.annotations.hint == "large-batch"
    assert host.annotations.priority == "normal"
    with pytest.raises(ValueError, match="no bound executor"):
        par.with_priority("high")


def test_prefer_degrades_require_raises():
    class Plain:
        pass

    target = Plain()
    assert prefer(with_priority, target, "high") is target
    with pytest.raises(UnsupportedProperty):
        require(with_priority, target, "high")
    # tag call syntax == prefer
    assert with_hint(target, "x") is target


def test_params_of_sees_through_wrappers(host):
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    assert params_of(host) is None
    assert params_of(host.with_params(acc)) is acc
    assert params_of(adaptive(host, acc)) is acc
    # annotation found on the wrapper even with a bare inner executor
    assert params_of(AdaptiveExecutor(host)) is not None


def test_unwrap_and_mesh_detection(host):
    import jax

    assert unwrap_executor(adaptive(host)) is host
    assert mesh_executor_of(host) is None
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
    mexec = MeshExecutor(mesh)
    assert mesh_executor_of(mexec) is mexec
    assert mesh_executor_of(adaptive(mexec)) is mexec


# ---------------------------------------------------------------------------
# MeshExecutor: no silent sequential bulk execution
# ---------------------------------------------------------------------------

def test_mesh_executor_bulk_raises_unsupported():
    import jax

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
    mexec = MeshExecutor(mesh)
    with pytest.raises(UnsupportedOperation, match="shard_map"):
        mexec.bulk_async_execute(lambda c: c, make_chunks(4, 1))
    with pytest.raises(UnsupportedOperation, match="shard_map"):
        mexec.bulk_sync_execute(lambda c: c, make_chunks(4, 1))
    # single-task execution still works (whole SPMD programs)
    assert mexec.sync_execute(lambda: 7) == 7


# ---------------------------------------------------------------------------
# Removed v1 surface
# ---------------------------------------------------------------------------

def test_bulk_sync_execute_removed_with_pointer():
    """The deprecated v1 shim is gone: access fails hard (AttributeError,
    so hasattr-style probing sees a v2-only surface) and the message
    points at the bulk_async_execute spelling."""
    for make in (SequentialExecutor, lambda: HostParallelExecutor(2)):
        ex = make()
        assert not hasattr(ex, "bulk_sync_execute")
        with pytest.raises(AttributeError, match="bulk_async_execute"):
            ex.bulk_sync_execute(lambda c: c.start, make_chunks(4, 2))
        # other missing attributes still raise a plain AttributeError
        with pytest.raises(AttributeError):
            ex.no_such_attribute
        if hasattr(ex, "shutdown"):
            ex.shutdown()


def test_algorithms_run_without_removed_shim(host):
    from repro import algorithms as alg

    x = jnp.asarray(np.random.RandomState(0).rand(4096).astype(np.float32))
    out = alg.transform(
        par.on(host).with_(AdaptiveCoreChunk(t0_override=1e-5)),
        x, lambda c: c * 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# AdaptiveExecutor
# ---------------------------------------------------------------------------

def test_adaptive_executor_plan_matches_explicit_acc(host):
    """Deterministic (analytic-profile) check: par.on(adaptive(ex)) makes
    the same core/chunk decision as par.on(ex).with_(acc)."""
    profile = WorkloadProfile(flops_per_elem=2e5, bytes_per_elem=8,
                              name="synthetic")
    mk = lambda: AdaptiveCoreChunk(t0_override=1e-5)
    n = 1 << 20
    p_explicit = detail.plan(par.on(host).with_(mk()), n, profile)
    p_adaptive = detail.plan(par.on(adaptive(host, mk())), n, profile)
    assert (p_explicit.cores, p_explicit.chunk_elems) == \
           (p_adaptive.cores, p_adaptive.chunk_elems)
    assert p_explicit.cores > 1       # the comparison is non-trivial


def test_adaptive_executor_customization_point_dispatch(host):
    """The wrapper overloads the three customization points, so dispatch
    rule 2 (executor attribute lookup) finds them with no params bound."""
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    ae = adaptive(host, acc)
    profile = WorkloadProfile(flops_per_elem=2e5, bytes_per_elem=8, name="s")
    t_iter = cp.measure_iteration(None, ae, profile, 1 << 20)
    assert t_iter == acc.measure_iteration(ae, profile, 1 << 20)
    cores = cp.processing_units_count(None, ae, t_iter, 1 << 20)
    assert cores == acc.processing_units_count(ae, t_iter, 1 << 20)
    chunk = cp.get_chunk_size(None, ae, t_iter, cores, 1 << 20)
    assert chunk == acc.get_chunk_size(ae, t_iter, cores, 1 << 20)


@dataclasses.dataclass
class _RecordingAcc(AdaptiveCoreChunk):
    log: list = dataclasses.field(default_factory=list)

    def processing_units_count(self, executor, t_iter, count):
        n = super().processing_units_count(executor, t_iter, count)
        self.log.append(("cores", count, n))
        return n

    def get_chunk_size(self, executor, t_iter, cores, count):
        c = super().get_chunk_size(executor, t_iter, cores, count)
        self.log.append(("chunk", count, c))
        return c


def test_adaptive_executor_runs_every_algorithm_same_decisions(host):
    """Acceptance: par.on(AdaptiveExecutor(host)) runs the full algorithm
    suite, results match seq, and the recorded core/chunk decisions equal
    those of the equivalent par.on(host).with_(acc) calls (one shared acc:
    the measurement cache makes the second pass deterministic)."""
    from repro import algorithms as alg

    rs = np.random.RandomState(0)
    x = jnp.asarray(rs.rand(50_000).astype(np.float32))
    y = jnp.asarray(rs.rand(50_000).astype(np.float32))
    double = lambda c: c * 2
    square = lambda c: c * c
    pred = lambda c: c > 0.5

    calls = [
        ("transform", lambda p: alg.transform(p, x, double)),
        ("transform2", lambda p: alg.transform(p, x, jnp.add, y)),
        ("for_each", lambda p: alg.for_each(p, x, double)),
        ("copy", lambda p: alg.copy(p, x)),
        ("fill", lambda p: alg.fill(p, x, 3.0)),
        ("generate", lambda p: alg.generate(p, 50_000,
                                            lambda i: i.astype(jnp.float32))),
        ("reduce", lambda p: alg.reduce(p, x)),
        ("transform_reduce", lambda p: alg.transform_reduce(p, x, square)),
        ("count_if", lambda p: alg.count_if(p, x, pred)),
        ("all_of", lambda p: alg.all_of(p, x, lambda c: c > -1)),
        ("any_of", lambda p: alg.any_of(p, x, pred)),
        ("none_of", lambda p: alg.none_of(p, x, lambda c: c > 2)),
        ("min_element", lambda p: alg.min_element(p, x)),
        ("max_element", lambda p: alg.max_element(p, x)),
        ("inclusive_scan", lambda p: alg.inclusive_scan(p, x)),
        ("exclusive_scan", lambda p: alg.exclusive_scan(p, x, 0.0)),
        ("adjacent_difference", lambda p: alg.adjacent_difference(p, x)),
        ("stencil3", lambda p: alg.stencil3(p, x)),
        ("artificial_work", lambda p: alg.artificial_work(p, x, iters=8)),
    ]

    acc = _RecordingAcc(t0_override=1e-5)
    pol_explicit = par.on(host).with_(acc)
    pol_adaptive = par.on(AdaptiveExecutor(host, params=acc))

    # these wrap their body in a fresh lambda per call, so the measurement
    # cache key differs between the two passes and t_iter is re-measured
    # (wall-clock): decisions are equal only up to timing noise — compare
    # results, not logs, for them.
    unstable_keys = {"copy", "fill", "artificial_work"}

    for name, call in calls:
        ref = call(seq)
        acc.log.clear()
        out_e = call(pol_explicit)
        log_explicit = list(acc.log)
        acc.log.clear()
        out_a = call(pol_adaptive)
        log_adaptive = list(acc.log)
        if name not in unstable_keys:
            assert log_explicit == log_adaptive, name
        for r, o in zip(
                ref if isinstance(ref, tuple) else (ref,),
                out_a if isinstance(out_a, tuple) else (out_a,),
                strict=True):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=2e-4, atol=1e-5,
                                       err_msg=name)
        for r, o in zip(
                ref if isinstance(ref, tuple) else (ref,),
                out_e if isinstance(out_e, tuple) else (out_e,),
                strict=True):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=2e-4, atol=1e-5,
                                       err_msg=name)


def test_adaptive_wrapper_is_idempotent(host):
    ae = adaptive(host)
    assert adaptive(ae) is ae
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    ae2 = adaptive(ae, acc)
    assert ae2.inner is host and ae2.params is acc


# ---------------------------------------------------------------------------
# Pool lifecycle
# ---------------------------------------------------------------------------

def test_collected_annotation_clone_does_not_kill_shared_pool():
    """Clones made by with_priority/with_hint share the pool but must not
    shut it down when garbage-collected (regression: a dropped temporary
    clone's __del__ used to close the original's pool)."""
    import gc

    with HostParallelExecutor(max_workers=2) as ex:
        assert ex.sync_execute(lambda: 1) == 1
        # chained annotation drops the intermediate with_priority clone
        annotated = ex.with_priority("high").with_hint("x")
        del annotated
        gc.collect()
        assert ex.sync_execute(lambda: 2) == 2   # pool still alive
        survivor = ex.with_params(AdaptiveCoreChunk(t0_override=1e-5))
        assert survivor.sync_execute(lambda: 3) == 3


def test_host_executor_context_manager():
    with HostParallelExecutor(max_workers=2) as ex:
        assert ex._pool is not None
        assert when_all(ex.bulk_async_execute(
            lambda c: c.start, make_chunks(4, 1))).result() == [0, 1, 2, 3]
    assert ex._pool is None           # pool shut down on exit
    # reusable after exit: a fresh pool is created lazily
    assert ex.sync_execute(lambda: 1) == 1
    ex.shutdown()
