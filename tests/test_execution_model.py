"""ExecutionModel engine: Decision IR, provenance ladder, trace,
CalibrationCache v3 migration, and the policy unification invariants.

Plain tests run everywhere; the hypothesis property sweeps (determinism
under a fixed cache state, provenance monotonicity under arbitrary
operation interleavings) skip when hypothesis is missing — same
convention as tests/test_acc_properties.py.
"""
import json
import os

import pytest

from repro.core import customization as cp
from repro.core import overhead_law as ol
from repro.core.acc import AdaptiveCoreChunk
from repro.core.calibration import SCHEMA_VERSION, CalibrationCache
from repro.core.executor import SequentialExecutor
from repro.core.model import (ANALYTIC, MEASURED, ONLINE, Decision,
                              DecisionKey, ExecutionModel,
                              default_cores_chunk, provenance_max,
                              provenance_rank)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Decision IR
# ---------------------------------------------------------------------------

def test_decision_key_wraps_legacy_tuples_identically():
    """Legacy workload keys (plain tuples) must keep their exact cache
    identity through the IR, or every persisted calibration would be
    orphaned by the unification."""
    legacy = ("serve_prefill", "qwen3-0.6b", 64, 2)
    assert DecisionKey.wrap(legacy).cache_key() == legacy
    assert DecisionKey.wrap(DecisionKey("x", (1,))).cache_key() == ("x", 1)
    # non-tuple keys (tag_workload accepts any hashable) keep their
    # identity verbatim: repr("emb") != repr(("emb",)) in the store
    assert DecisionKey.wrap("emb").cache_key() == "emb"
    assert DecisionKey.wrap((1, "x")).cache_key() == (1, "x")
    # typed keys append dtype and hardware after the shape
    k = DecisionKey("pallas_block", ("rmsnorm", 8192), dtype="float32",
                    hardware="hw-a")
    assert k.cache_key() == ("pallas_block", "rmsnorm", 8192, "float32",
                             "hw-a")


def test_decision_inputs_and_explain():
    m = ExecutionModel(CalibrationCache(), hardware="test")
    d = m.cores_chunk(DecisionKey("serve_tick", ("cfg", 64)),
                      t_iter=1e-6, count=10_000, t0=1e-5, max_cores=8)
    assert isinstance(d, Decision)
    assert d.input("count") == 10_000 and d.input("missing", 42) == 42
    assert d.acc is not None and d.cores == d.acc.n_cores
    line = d.explain()
    assert "serve_tick" in line and "overhead-law" in line
    assert f"cores={d.cores}" in line


def test_engine_shared_per_cache():
    cache = CalibrationCache()
    assert ExecutionModel.of(cache) is ExecutionModel.of(cache)
    assert ExecutionModel.of(CalibrationCache()) is not \
        ExecutionModel.of(cache)
    # acc objects and feedback recorders over one cache share the engine
    acc = AdaptiveCoreChunk(cache=cache)
    assert acc.model is ExecutionModel.of(cache)


def test_trace_records_every_decision_and_bounds():
    m = ExecutionModel(CalibrationCache(), hardware="test", trace_limit=4)
    for i in range(6):
        m.cores_chunk(("k", i), t_iter=1e-6, count=100, t0=1e-5,
                      max_cores=4)
    assert m.decisions == 6
    assert len(m.trace) == 4 and m.trace.dropped == 2
    text = m.explain()
    assert "6 decisions" in text and "aged out" in text


# ---------------------------------------------------------------------------
# Determinism: decisions are a pure function of (cache state, inputs)
# ---------------------------------------------------------------------------

def test_decisions_deterministic_for_fixed_cache_state():
    m = ExecutionModel(CalibrationCache(), hardware="test")
    kw = dict(t_iter=2e-7, count=1 << 20, t0=1e-5, max_cores=40)
    d1 = m.cores_chunk(("wl", "a"), **kw)
    d2 = m.cores_chunk(("wl", "a"), **kw)
    assert d1 == d2   # frozen dataclasses: full field equality
    # a cache mutation (online refinement) may change the *next*
    # decision's provenance but determinism still holds per state
    m.observe(("wl", "a"), 1024, 1e-3)
    d3 = m.cores_chunk(("wl", "a"), **kw)
    d4 = m.cores_chunk(("wl", "a"), **kw)
    assert d3 == d4 and d3.provenance == ONLINE


if HAVE_HYPOTHESIS:
    times = st.floats(min_value=1e-10, max_value=1e-3, allow_nan=False)
    counts = st.integers(min_value=1, max_value=10**8)

    @given(t_iter=times, count=counts, t0=times,
           max_cores=st.integers(1, 4096))
    @settings(max_examples=100, deadline=None)
    def test_cores_chunk_deterministic_property(t_iter, count, t0,
                                                max_cores):
        m = ExecutionModel(CalibrationCache(), hardware="test")
        kw = dict(t_iter=t_iter, count=count, t0=t0, max_cores=max_cores)
        assert m.cores_chunk("wl", **kw) == m.cores_chunk("wl", **kw)

    # Arbitrary interleavings of evidence-producing operations: the
    # provenance reported for a key must never decrease.
    ops = st.lists(st.sampled_from(["decide", "measure", "observe"]),
                   min_size=1, max_size=12)

    @given(ops=ops)
    @settings(max_examples=100, deadline=None)
    def test_provenance_monotone_property(ops):
        m = ExecutionModel(CalibrationCache(), hardware="test")
        key = ("wl", "p")
        seen = []
        for op in ops:
            if op == "measure":
                m.measured_t_iter(key, lambda: 1e-6)
            elif op == "observe":
                m.observe(key, 128, 1e-3)
            d = m.cores_chunk(key, t_iter=1e-6, count=10_000, t0=1e-5,
                              max_cores=8)
            seen.append(d.provenance)
        ranks = [provenance_rank(p) for p in seen]
        assert ranks == sorted(ranks), seen


# ---------------------------------------------------------------------------
# Provenance ladder: analytic -> measured -> online, never down
# ---------------------------------------------------------------------------

def test_provenance_upgrades_and_never_downgrades():
    m = ExecutionModel(CalibrationCache(), hardware="test")
    key = ("wl", "ladder")
    kw = dict(t_iter=1e-6, count=10_000, t0=1e-5, max_cores=8)
    assert m.cores_chunk(key, **kw).provenance == ANALYTIC
    m.measured_t_iter(key, lambda: 1e-6)
    assert m.cores_chunk(key, **kw).provenance == MEASURED
    m.observe(key, 256, 1e-3)
    assert m.cores_chunk(key, **kw).provenance == ONLINE
    # a later one-shot measurement note must not demote the key
    m.cache.note_provenance(key, MEASURED)
    assert m.cores_chunk(key, **kw).provenance == ONLINE
    assert provenance_max(MEASURED, ONLINE) == ONLINE
    assert provenance_rank(ANALYTIC) < provenance_rank(MEASURED) \
        < provenance_rank(ONLINE)


def test_provenance_survives_persistence(tmp_path):
    path = os.path.join(tmp_path, "cal.json")
    m1 = ExecutionModel(CalibrationCache(path), hardware="test")
    m1.observe(("wl", "x"), 128, 1e-3)
    m2 = ExecutionModel(CalibrationCache(path), hardware="test")
    assert m2.provenance_of(("wl", "x")) == ONLINE


def test_tick_evidence_counts_toward_provenance():
    """A serve tick's t_iter blends the prefill/decode calibrations;
    their provenance must show on the tick decision."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    m.observe(("serve_prefill", "cfg"), 64, 1e-3)
    d = m.cores_chunk(("serve_tick", "cfg"), t_iter=1e-6, count=100,
                      t0=1e-5, max_cores=4,
                      evidence=(("serve_prefill", "cfg"),
                                ("serve_decode", "cfg")))
    assert d.provenance == ONLINE


def test_dispatch_depth_amortises_host_overhead():
    """serve_dispatch_depth: the paper's T_opt floor along the time
    axis — depth = ceil(E/(1-E) * T0 / t_iter), clamped to the compiled
    loop's bound, and 1 when dispatches are free."""
    import math

    m = ExecutionModel(CalibrationCache(), hardware="test")
    key = DecisionKey("serve_dispatch_depth", ("cfg",))
    d = m.dispatch_depth(key, host_overhead_s=1e-3, device_step_s=2e-3,
                         max_depth=32)
    assert d.chunk == math.ceil(ol.t_opt(1e-3) / 2e-3)
    assert d.key.kind == "serve_dispatch_depth"
    # deeper when host overhead grows; clamped at the compiled bound
    d_deep = m.dispatch_depth(key, host_overhead_s=1e-1,
                              device_step_s=2e-3, max_depth=32)
    assert d_deep.chunk == 32
    # free dispatches need no fusing; unknown device time amortises fully
    assert m.dispatch_depth(key, host_overhead_s=0.0, device_step_s=1e-3,
                            max_depth=32).chunk == 1
    assert m.dispatch_depth(key, host_overhead_s=1e-3, device_step_s=0.0,
                            max_depth=32).chunk == 32
    assert all(e.decision.key.kind == "serve_dispatch_depth"
               for e in m.trace.entries("serve_dispatch_depth"))


def test_dispatch_depth_provenance_follows_evidence():
    """The depth decision's inputs are smoothed store entries; once the
    serve loop has observed real host/device timings the decision must
    report online provenance."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    key = DecisionKey("serve_dispatch_depth", ("cfg",))
    host_key = ("serve_host_tick", "cfg")
    dev_key = ("serve_decode_fused", "cfg")
    d = m.dispatch_depth(key, host_overhead_s=1e-3, device_step_s=1e-3,
                         max_depth=16, evidence=(host_key, dev_key))
    assert d.provenance == ANALYTIC
    m.observe(host_key, 1, 2e-3)
    m.observe(dev_key, 8, 8e-3)
    d = m.dispatch_depth(key, host_overhead_s=2e-3, device_step_s=1e-3,
                         max_depth=16, evidence=(host_key, dev_key))
    assert d.provenance == ONLINE


def test_mesh_batch_decision_and_provenance():
    """serve_mesh_batch: per-replica width from the Overhead-Law prior
    over the per-replica slot count, global batch_width = width x
    replicas; analytic until the serve loop's evidence keys carry real
    observations, then online — and never back."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    key = DecisionKey("serve_mesh_batch", ("cfg",),
                      hardware="cpu:cpu:8|mesh=4x2")
    host_key = ("serve_host_tick", "cfg")
    dev_key = ("serve_decode_fused", "cfg")
    d = m.mesh_batch(key, demand=8, n_replicas=4, slots_per_replica=2,
                     host_tick_s=1e-3, device_step_s=1e-3,
                     evidence=(host_key, dev_key))
    assert d.provenance == ANALYTIC
    assert 1 <= d.cores <= 2                       # capped per replica
    assert d.batch_width == d.cores * 4            # global lane cap
    assert d.key.hardware == "cpu:cpu:8|mesh=4x2"  # mesh-shaped key
    # expensive device step over many lanes, cheap host tick -> the
    # overhead law widens the per-replica batch to the slot cap
    wide = m.mesh_batch(key, demand=64, n_replicas=4, slots_per_replica=8,
                        host_tick_s=1e-4, device_step_s=5e-2,
                        evidence=(host_key, dev_key))
    assert wide.cores == 8 and wide.batch_width == 32
    m.observe(host_key, 1, 2e-3)
    m.observe(dev_key, 8, 8e-3)
    d2 = m.mesh_batch(key, demand=8, n_replicas=4, slots_per_replica=2,
                      host_tick_s=2e-3, device_step_s=1e-3,
                      evidence=(host_key, dev_key))
    assert d2.provenance == ONLINE
    # provenance never downgrades once the store holds observations,
    # even on a later call with an empty evidence tuple
    d3 = m.mesh_batch(key, demand=2, n_replicas=4, slots_per_replica=2,
                      host_tick_s=2e-3, device_step_s=1e-3)
    assert d3.provenance == ONLINE
    assert all(e.decision.key.kind == "serve_mesh_batch"
               for e in m.trace.entries("serve_mesh_batch"))


# ---------------------------------------------------------------------------
# Measured-search policy through the engine
# ---------------------------------------------------------------------------

def test_tuned_blocks_search_then_store_hit():
    m = ExecutionModel(CalibrationCache(), hardware="test")
    key = DecisionKey("pallas_block", ("k", 8192), dtype="float32",
                      hardware="hw-t")
    calls = []
    d1 = m.tuned_blocks(key, [(256,), (512,)],
                        lambda b: calls.append(b), ("block",))
    assert d1.provenance == MEASURED and d1.input("measured") is True
    assert m.searches == 1 and calls
    n = len(calls)
    d2 = m.tuned_blocks(key, [(256,), (512,)],
                        lambda b: calls.append(b), ("block",))
    assert d2.block_plan == d1.block_plan
    assert d2.input("measured") is False and m.cache_hits == 1
    assert len(calls) == n   # no re-measurement
    # the record's hw field mirrors the key's hardware id
    assert m.cache.tuned(key.cache_key())["hw"] == "hw-t"


# ---------------------------------------------------------------------------
# CalibrationCache v3: one unified schema, v1/v2 migration
# ---------------------------------------------------------------------------

def _roundtrip(tmp_path, blob, name):
    path = os.path.join(tmp_path, name)
    with open(path, "w") as f:
        json.dump(blob, f)
    c = CalibrationCache(path)
    c.save()
    with open(path) as f:
        return c, json.load(f)


def test_v1_migrates_to_v3_roundtrip(tmp_path):
    c, saved = _roundtrip(tmp_path, {
        "version": 1,
        "t0": {"('t0', 'SequentialExecutor', 1)": 3.5e-5},
        "t_iter": {"('wl', 'a')": 2e-6}}, "v1.json")
    assert saved["version"] == SCHEMA_VERSION
    assert c.peek_t_iter(("wl", "a")) == pytest.approx(2e-6)
    # migrated entries carry measured provenance (they were measured
    # once; online status re-earns itself from live observations)
    assert c.provenance(("wl", "a")) == MEASURED
    c2 = CalibrationCache(os.path.join(tmp_path, "v1.json"))
    assert c2.peek_t_iter(("wl", "a")) == pytest.approx(2e-6)
    assert c2.t0(("t0", "SequentialExecutor", 1),
                 lambda: pytest.fail("must not re-measure")) \
        == pytest.approx(3.5e-5)


def test_v2_migrates_to_v3_roundtrip(tmp_path):
    tuned_key = "('pallas_block', 'k', 1024, 'float32', 'hw-a')"
    c, saved = _roundtrip(tmp_path, {
        "version": 2,
        "t0": {"('t0', 'X', 2)": 1e-5},
        "t_iter": {"('wl', 'b')": 4e-6},
        "tuned": {tuned_key: {"block": 256, "hw": "hw-a"}}}, "v2.json")
    assert saved["version"] == SCHEMA_VERSION
    assert "entries" in saved and "tuned" not in saved
    rec = c.tuned(("pallas_block", "k", 1024, "float32", "hw-a"))
    assert rec == {"block": 256, "hw": "hw-a"}
    # round-trip again through a fresh cache: values identical
    c3 = CalibrationCache(os.path.join(tmp_path, "v2.json"))
    assert c3.peek_t_iter(("wl", "b")) == pytest.approx(4e-6)
    assert c3.tuned(("pallas_block", "k", 1024, "float32", "hw-a")) == rec
    assert len(c3) == 3


def test_v3_preserves_provenance_on_disk(tmp_path):
    path = os.path.join(tmp_path, "v3.json")
    c = CalibrationCache(path)
    c.smooth_t_iter(("wl", "c"), 1e-6)
    c.note_provenance(("wl", "c"), ONLINE)
    blob = json.load(open(path))
    assert blob["version"] == SCHEMA_VERSION
    [entry] = [e for e in blob["entries"].values() if "t_iter" in e]
    assert entry["provenance"] == ONLINE
    c2 = CalibrationCache(path)
    assert c2.provenance(("wl", "c")) == ONLINE


def test_unknown_future_schema_ignored(tmp_path):
    path = os.path.join(tmp_path, "future.json")
    with open(path, "w") as f:
        json.dump({"version": SCHEMA_VERSION + 1,
                   "entries": {"'x'": {"t_iter": 1.0}}}, f)
    assert len(CalibrationCache(path)) == 0


# ---------------------------------------------------------------------------
# Customization-point defaults delegate to the engine's prior policy
# ---------------------------------------------------------------------------

def test_defaults_route_through_overhead_law():
    class FakeExec:
        def num_units(self):
            return 8

    # all units, equal chunks — and exactly the shared formula's numbers
    n = cp.processing_units_count(None, FakeExec(), 0.0, 10_000)
    assert n == default_cores_chunk(10_000, 8).n_cores == 8
    chunk = cp.get_chunk_size(None, FakeExec(), 0.0, 8, 10_000)
    assert chunk == default_cores_chunk(10_000, 8).chunk_elems == 1250
    # the default never opens more units than chunks
    assert cp.processing_units_count(None, FakeExec(), 0.0, 2) == 2


def test_acc_decide_routes_through_engine_trace():
    """AdaptiveCoreChunk is a front-end: each decide() lands exactly one
    overhead-law entry in the engine trace with the Overhead-Law record
    attached."""
    acc = AdaptiveCoreChunk(t0_override=1e-5)
    before = acc.model.decisions
    d = acc.decide(SequentialExecutor(), 1e-6, 50_000, key=("wl", "t"))
    assert isinstance(d, ol.AccDecision)
    assert acc.model.decisions == before + 1
    entry = acc.model.trace.entries("wl")[-1]
    assert entry.decision.acc == d
    assert entry.decision.policy == "overhead-law"
