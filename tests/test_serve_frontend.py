"""Serving front end: streaming, cancellation/slot lifecycle, deadline
enforcement, backpressure, adaptive admission, and the load generators."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SequentialExecutor, adaptive
from repro.core.acc import AdaptiveCoreChunk, StaticCoreChunk
from repro.data import make_batch
from repro.models import init_params
from repro.serve import (PromptTooLongError, QueueFullError, RequestState,
                         ServeFrontend, ServeScheduler, SLOModel,
                         bursty_trace, heavy_tailed_trace, materialize,
                         poisson_trace, trace_summary)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_sched(cfg, params, *, n_slots=2, max_len=48, acc=None,
               clock=None, **kw):
    if clock is not None:
        kw["clock"] = clock
    return ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=max_len,
        executor=adaptive(SequentialExecutor(),
                          acc or AdaptiveCoreChunk()), **kw)


class FakeClock:
    """Deterministic scheduler clock for deadline tests."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# typed submit errors
# ---------------------------------------------------------------------------

def test_prompt_too_long_is_typed(setup):
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1, max_len=16)
    long_prompt = jnp.arange(20, dtype=jnp.int32) % cfg.vocab_size
    with pytest.raises(PromptTooLongError) as ei:
        sched.submit(long_prompt, max_new_tokens=2)
    assert ei.value.prompt_len == 20 and ei.value.max_len == 16
    # subclasses ValueError: pre-existing callers keep catching it
    assert isinstance(ei.value, ValueError)


def test_frontend_rejects_long_prompt_without_dying(setup):
    """A bad request is the caller's structured error; the serve loop
    keeps serving everyone else."""
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1, max_len=16)
    long_prompt = jnp.arange(20, dtype=jnp.int32) % cfg.vocab_size
    ok_prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size

    async def go():
        async with ServeFrontend(sched) as fe:
            with pytest.raises(PromptTooLongError):
                await fe.submit(long_prompt, 2)
            stream = await fe.submit(ok_prompt, 3)
            toks = [t async for t in stream]
            return toks, stream.record.status

    toks, status = asyncio.run(go())
    assert len(toks) == 3 and status == "completed"


# ---------------------------------------------------------------------------
# cancellation and the slot lifecycle
# ---------------------------------------------------------------------------

def test_cancel_waiting_request(setup):
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size
    r_run = sched.submit(prompt, max_new_tokens=2)
    r_wait = sched.submit(prompt, max_new_tokens=2)
    sched.tick()
    assert sched.requests[r_wait].state is RequestState.WAITING
    assert sched.cancel(r_wait)
    assert sched.requests[r_wait].state is RequestState.CANCELLED
    assert not sched.cancel(r_wait)          # idempotent
    outs = sched.run_until_idle()
    assert len(outs[r_run]) == 2 and r_wait not in outs
    assert sched.pool.free_slots() == 1
    assert sched.cancelled == 1


def test_cancel_mid_prefill_releases_slot(setup):
    """Cancel while the prompt is partially prefilled: the slot returns
    to the pool with no reallocation, and its next occupant decodes
    exactly like a solo reference run."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 16, kind="prefill", seed=23)["tokens"]
    # Static chunks of 2: a 16-token prompt takes several ticks, so one
    # tick deterministically leaves it mid-prefill.
    sched = make_sched(cfg, params, n_slots=1, max_len=32,
                       acc=StaticCoreChunk(cores=1, chunks_per_core=8))
    r_victim = sched.submit(tokens[0], max_new_tokens=4)
    sched.tick()
    victim = sched.requests[r_victim]
    assert victim.state is RequestState.PREFILL
    assert victim.remaining_prefill > 0
    assert sched.cancel(r_victim)
    assert victim.slot is None and sched.pool.free_slots() == 1

    r_next = sched.submit(tokens[1][:10], max_new_tokens=4)
    outs = sched.run_until_idle()
    assert sched.pool.allocations == 1
    solo = make_sched(cfg, params, n_slots=1, max_len=32,
                      acc=StaticCoreChunk(cores=1, chunks_per_core=8))
    r_ref = solo.submit(tokens[1][:10], max_new_tokens=4)
    assert outs[r_next] == solo.run_until_idle()[r_ref]


def test_cancel_mid_fused_dispatch(setup):
    """Cancel with tokens already dispatched on the device: the dispatch
    drains without emitting them (out is frozen, pending_out returns to
    0), the slot is back in the pool with ``allocations==1``, and the
    surviving request's stream is byte-identical to an uncancelled run."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 10, kind="prefill", seed=29)["tokens"]
    spec = [(10, 8), (7, 12)]

    def submit_pair(sched):
        return [sched.submit(tokens[i][:p], max_new_tokens=n)
                for i, (p, n) in enumerate(spec)]

    ref_sched = make_sched(cfg, params, n_slots=2, max_len=32,
                           dispatch_depth=4)
    ref_sched.warmup()
    ref_ids = submit_pair(ref_sched)
    ref = ref_sched.run_until_idle()

    sched = make_sched(cfg, params, n_slots=2, max_len=32,
                       dispatch_depth=4)
    sched.warmup()
    r_keep, r_cancel = submit_pair(sched)
    victim = sched.requests[r_cancel]
    for _ in range(200):
        sched.tick()
        if victim.state is RequestState.DECODE and victim.pending_out > 0:
            break
    assert victim.pending_out > 0, "no in-flight dispatch to cancel into"
    frozen = list(victim.out)
    assert sched.cancel(r_cancel)
    assert sched.pool.free_slots() >= 1
    outs = sched.run_until_idle()

    assert victim.out == frozen           # dispatched tokens dropped
    assert victim.pending_out == 0        # ...but the drain balanced
    assert victim.state is RequestState.CANCELLED
    assert outs[r_keep] == ref[ref_ids[0]]
    assert sched.pool.allocations == 1
    assert sched.pool.free_slots() == 2


def test_frontend_cancel_stream(setup):
    """Streaming consumer cancels after two tokens: the stream ends, the
    record says cancelled (not an SLO miss), and the slot is free."""
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1, max_len=48)
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size

    async def go():
        async with ServeFrontend(sched) as fe:
            stream = await fe.submit(prompt, 24)
            got = []
            async for tok in stream:
                got.append(tok)
                if len(got) == 2:
                    await stream.cancel()
            return got, stream.record

    got, rec = asyncio.run(go())
    assert rec.status == "cancelled" and rec.missed is False
    assert len(got) < 24                  # generation genuinely stopped
    assert sched.pool.free_slots() == 1
    assert sched.pool.allocations == 1
    assert sched.requests[rec.rid].state is RequestState.CANCELLED


# ---------------------------------------------------------------------------
# deadline enforcement
# ---------------------------------------------------------------------------

def test_shed_expired_before_prefill(setup):
    """shed_expired: a request whose deadline passed while waiting is
    dropped before its prefill burns compute, and the TickRecord carries
    the miss and the queue depth."""
    cfg, params = setup
    clock = FakeClock()
    sched = make_sched(cfg, params, n_slots=1, clock=clock,
                       shed_expired=True)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size
    r_ok = sched.submit(prompt, max_new_tokens=2, deadline=100.0)
    r_dead = sched.submit(prompt, max_new_tokens=2, deadline=5.0)
    r_queued = sched.submit(prompt, max_new_tokens=2, deadline=50.0)
    clock.t = 10.0                        # r_dead's deadline passed
    rec = sched.tick()
    dead = sched.requests[r_dead]
    assert dead.state is RequestState.SHED
    assert dead.finished_at == 10.0
    assert rec.deadline_misses == 1
    assert rec.admitted == (r_queued,)    # EDF among the survivors
    assert rec.queue_depth == 1           # r_ok still waiting
    assert sched.shed == 1 and sched.deadline_misses == 1
    outs = sched.run_until_idle()
    assert sorted(outs) == sorted([r_ok, r_queued])


def test_late_completion_counts_as_miss(setup):
    """A request that finishes past its deadline is a miss (counted once,
    in the tick where its tokens landed)."""
    cfg, params = setup
    clock = FakeClock()
    sched = make_sched(cfg, params, n_slots=1, clock=clock)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size
    rid = sched.submit(prompt, max_new_tokens=2, deadline=5.0)
    clock.t = 10.0                        # already late, but admitted
    sched.run_until_idle()
    assert sched.requests[rid].state is RequestState.DONE
    assert sched.deadline_misses == 1
    assert sum(rec.deadline_misses for rec in sched.trace) == 1


def test_frontend_marks_late_completion_missed(setup):
    cfg, params = setup
    clock = FakeClock()
    sched = make_sched(cfg, params, n_slots=1, clock=clock)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size

    async def go():
        async with ServeFrontend(sched, enforce_deadlines=False) as fe:
            stream = await fe.submit(prompt, 2, deadline=5.0)
            clock.t = 10.0
            async for _ in stream:
                pass
            return stream.record, fe.stats()

    rec, stats = asyncio.run(go())
    assert rec.status == "completed" and rec.missed is True
    assert stats["completed"] == 1 and stats["completed_in_slo"] == 0
    assert stats["missed"] == 1


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_frontend_backpressure(setup):
    """The bounded queue rejects (wait=False) or suspends (wait=True)
    instead of queueing without limit."""
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1, max_len=48)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size

    async def go():
        fe = ServeFrontend(sched, max_queue=1)
        async with fe:
            streams = [await fe.submit(prompt, 2)]
            while fe.queue_depth() > 0:     # let the serve loop admit it
                await asyncio.sleep(0)
            streams.append(await fe.submit(prompt, 2))
            # queue bound 1 and one request already waiting: the next
            # non-waiting submit bounces (no await since the last one,
            # so the serve loop cannot have drained the queue).
            with pytest.raises(QueueFullError):
                await fe.submit(prompt, 2)
            assert fe.rejected == 1
            # wait=True parks until the queue drains, then succeeds
            streams.append(await fe.submit(prompt, 2, wait=True))
            for s in streams:
                async for _ in s:
                    pass
            return fe.stats()

    stats = asyncio.run(go())
    assert stats["completed"] == 3 and stats["rejected"] == 1


# ---------------------------------------------------------------------------
# streaming identity + adaptive admission
# ---------------------------------------------------------------------------

def test_streaming_tokens_match_batch_path(setup):
    """Streamed tokens are the same tokens run_until_idle returns —
    streaming changes delivery, never content."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 12, kind="prefill", seed=31)["tokens"]
    ref_sched = make_sched(cfg, params, n_slots=2, max_len=32)
    ref_ids = [ref_sched.submit(tokens[0], max_new_tokens=5),
               ref_sched.submit(tokens[1][:8], max_new_tokens=5)]
    ref = ref_sched.run_until_idle()

    sched = make_sched(cfg, params, n_slots=2, max_len=32)

    async def go():
        async with ServeFrontend(sched) as fe:
            s0 = await fe.submit(tokens[0], 5)
            s1 = await fe.submit(tokens[1][:8], 5)
            out = []
            for s in (s0, s1):
                out.append([t async for t in s])
            return out

    got = asyncio.run(go())
    assert got[0] == ref[ref_ids[0]]
    assert got[1] == ref[ref_ids[1]]


def test_burst_drain_spreads_inter_token_times(setup):
    """A fused dispatch drains k tokens in one _pump() call; their
    recorded emission times must spread over the dispatch interval,
    not collapse onto one stamp (the itl_p99_ms=0.0 bug: every
    inter-token gap inside a burst measured exactly zero)."""
    cfg, params = setup
    tokens = make_batch(cfg, 1, 10, kind="prefill", seed=33)["tokens"]
    n_new = 8
    sched = make_sched(cfg, params, n_slots=1, max_len=32,
                       dispatch_depth=4)    # fused: 4-token drain bursts

    async def go():
        async with ServeFrontend(sched) as fe:
            stream = await fe.submit(tokens[0], n_new)
            return [t async for t in stream], stream.record

    out, rec = asyncio.run(go())
    assert len(out) == n_new and len(rec.token_times) == n_new
    gaps = [b - a for a, b in zip(rec.token_times, rec.token_times[1:],
                                  strict=False)]
    assert all(g > 0 for g in gaps), gaps   # strictly increasing stamps
    # stamps stay causal: anchored after the first-token time
    assert rec.token_times[0] >= rec.first_token_at


def test_adaptive_admission_decisions_in_trace(setup):
    """admission='adaptive': every throttled admission round is a
    serve_admission engine decision with its inputs on the record."""
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=2, max_len=48,
                       admission="adaptive")
    sched.warmup()
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size
    for _ in range(5):
        sched.submit(prompt, max_new_tokens=2)
    outs = sched.run_until_idle()
    assert len(outs) == 5                 # throttling never starves
    entries = sched.decision_model().trace.entries("serve_admission")
    assert entries, "adaptive admission must go through the engine"
    for e in entries:
        inputs = dict(e.decision.inputs)
        assert "queue_depth" in inputs and "free_slots" in inputs
        assert 1 <= e.decision.cores <= 2
    # explain() renders them (the --explain-decisions surface)
    assert "serve_admission" in sched.decision_model().explain()


def test_adaptive_admission_urgency_override(setup):
    """A head-of-queue request inside two admission rounds of its
    deadline opens the width to every free slot."""
    cfg, params = setup
    clock = FakeClock(t=100.0)
    sched = make_sched(cfg, params, n_slots=2, max_len=48,
                       admission="adaptive", clock=clock)
    sched.warmup()
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size
    # Deadline exactly now: zero slack is inside any urgency bound, so
    # the width opens to every free slot regardless of the prior.
    sched.submit(prompt, max_new_tokens=2, deadline=100.0)
    sched.submit(prompt, max_new_tokens=2, deadline=100.0)
    rec = sched.tick()
    assert len(rec.admitted) == 2
    e = sched.decision_model().trace.entries("serve_admission")[-1]
    assert dict(e.decision.inputs)["urgent"] is True
    sched.run_until_idle()


# ---------------------------------------------------------------------------
# load generators
# ---------------------------------------------------------------------------

def test_traces_deterministic_and_shaped():
    for name, mk in (("poisson", lambda s: poisson_trace(
            200, rate_rps=50.0, seed=s)),
            ("bursty", lambda s: bursty_trace(
                200, base_rate_rps=10.0, burst_rate_rps=200.0, seed=s)),
            ("heavy", lambda s: heavy_tailed_trace(
                200, rate_rps=50.0, seed=s))):
        a, b, c = mk(0), mk(0), mk(1)
        assert a == b, f"{name}: same seed must replay identically"
        assert a != c, f"{name}: different seed must differ"
        assert len(a) == 200
        arr = [t.arrival_s for t in a]
        assert arr == sorted(arr) and arr[0] >= 0.0
        for t in a:
            assert t.prompt_len >= 1 and t.new_tokens >= 1
            assert t.deadline_s > t.arrival_s      # SLO is future-dated


def test_heavy_tail_is_heavy():
    trace = heavy_tailed_trace(2000, rate_rps=50.0, seed=3)
    s = trace_summary(trace)
    assert s["prompt_p99"] >= 3 * s["prompt_p50"]
    assert max(t.prompt_len for t in trace) <= 96   # clipped to geometry
    assert max(t.new_tokens for t in trace) <= 48


def test_slo_model_scales_with_length():
    slo = SLOModel(ttft_s=0.5, per_token_s=0.1)
    assert slo.deadline_offset(10) == pytest.approx(1.5)
    assert slo.deadline_offset(20) > slo.deadline_offset(10)
    trace = poisson_trace(10, rate_rps=100.0, seed=0, slo=None)
    assert all(t.deadline_s is None for t in trace)


def test_materialize_seeded_prompts():
    trace = poisson_trace(50, rate_rps=50.0, seed=2)
    m1 = materialize(trace, vocab=128, seed=2)
    m2 = materialize(trace, vocab=128, seed=2)
    for (_, p1), (_, p2) in zip(m1, m2, strict=True):
        np.testing.assert_array_equal(p1, p2)
        assert p1.dtype == np.int32
        assert p1.min() >= 0 and p1.max() < 128
    assert [p.shape[0] for _, p in m1] == [t.prompt_len for t in trace]
