"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs —
plus decode-vs-full consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data import make_batch
from repro.models import (forward, forward_cached, init_caches, init_params,
                          loss_fn)
from repro.optim import AdamWConfig, adamw
from repro.train import make_train_step

B, S = 2, 24


@pytest.fixture(scope="module", params=list(ARCH_NAMES))
def arch(request):
    cfg = get_config(request.param).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, B, S, kind="train", seed=1)
    return cfg, params, batch


def test_forward_shapes_and_finite(arch):
    cfg, params, batch = arch
    logits, aux = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


def test_one_train_step(arch):
    cfg, params, batch = arch
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), accum=1,
                                   remat=False))
    opt = adamw.init_state(params)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2), strict=True))
    assert delta > 0
    assert int(o2["step"]) == 1


def test_decode_matches_full_forward(arch):
    cfg, params, batch = arch
    feats = batch.get("frontend_feats")
    logits_full, _ = forward(params, batch, cfg)
    caches = init_caches(cfg, B, S)
    errs = []
    for t in range(S):
        lg, caches = forward_cached(params, batch["tokens"][:, t:t + 1],
                                    caches, t, cfg, frontend_feats=feats)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32)
            - logits_full[:, t].astype(jnp.float32)))))
    assert max(errs) < 2e-2, f"{cfg.name}: decode diverges {max(errs)}"


def test_remat_equals_no_remat(arch):
    cfg, params, batch = arch
    l1 = float(loss_fn(params, batch, cfg, remat=False))
    l2 = float(loss_fn(params, batch, cfg, remat=True))
    assert l1 == pytest.approx(l2, rel=1e-5)


def test_param_count_formula_matches_tree():
    """ArchConfig.param_count (used for MODEL_FLOPS) vs the real tree."""
    for name in ARCH_NAMES:
        cfg = get_config(name).reduced()
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        # formula ignores small norms/scalars; allow 5%
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, \
            (name, actual, predicted)


def test_moe_local_dispatch_matches_global():
    """Group-local MoE dispatch (the collective-eliminating §Perf variant)
    must be numerically identical to global dispatch when capacity is
    drop-free."""
    from repro.models import flags, moe

    cfg = get_config("grok-1-314b").reduced()
    p = moe.init(jax.random.PRNGKey(3), cfg)
    x = jnp.asarray(np.random.RandomState(1)
                    .randn(4, 8, cfg.d_model).astype(np.float32)) * 0.5
    o_g, aux_g = moe.apply(p, x, cfg)
    with flags.moe_dispatch_groups(4):
        o_l, aux_l = moe.apply(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_l),
                               rtol=2e-4, atol=2e-4)
    assert float(aux_g) == pytest.approx(float(aux_l), abs=1e-6)
