"""repro-lint: golden positive/negative micro-fixtures for RL001-RL006,
suppression round-trip, CLI exit codes, and the self-check that the
shipped tree is clean under the shipped rule set."""
import pathlib
import textwrap

import pytest

from repro.analysis.lint import LintConfig, lint_paths, load_file
from repro.analysis.lint.__main__ import main as lint_main
from repro.analysis.lint.engine import lint_sources

REPO = pathlib.Path(__file__).resolve().parent.parent


def run_lint(tmp_path, source, name="fixture.py", config=None):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    findings, suppressed = lint_sources([load_file(p)], config)
    return [f.code for f in findings], findings, suppressed


# --------------------------------------------------------------- RL001

RL001_POS = """
    import jax

    def step(params, caches):
        return caches

    fused = jax.jit(step, donate_argnums=(1,))

    def tick(params, caches):
        new_caches = fused(params, caches)
        return caches, new_caches
"""

RL001_NEG_REBIND = """
    import jax

    def step(params, caches):
        return caches

    fused = jax.jit(step, donate_argnums=(1,))

    def tick(params, caches):
        new_caches = fused(params, caches)
        caches = new_caches
        return caches, new_caches
"""

RL001_NEG_ADOPT = """
    import jax

    def step(params, caches):
        return caches

    fused = jax.jit(step, donate_argnums=(1,))

    def tick(params, pool):
        new_caches = fused(params, pool.caches)
        pool.adopt(new_caches)
        return pool.caches
"""


def test_rl001_use_after_donation(tmp_path):
    codes, findings, _ = run_lint(tmp_path, RL001_POS)
    assert codes == ["RL001"]
    assert "donated to 'fused'" in findings[0].message


def test_rl001_rebind_kills(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL001_NEG_REBIND)
    assert codes == []


def test_rl001_adopt_handoff_kills(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL001_NEG_ADOPT)
    assert codes == []


def test_rl001_donating_factory(tmp_path):
    codes, findings, _ = run_lint(tmp_path, """
        def build(self):
            fused = self._fused_step()
            out = fused(self.params, self.pool.caches)
            bad = self.pool.caches
            return out, bad
    """)
    assert codes == ["RL001"]
    assert "self.pool.caches" in findings[0].message


# --------------------------------------------------------------- RL002

RL002_POS = """
    import jax

    class Sched:
        def _tick_fused(self):
            return self._harvest()

        def _harvest(self):
            return jax.device_get(self.buf)
"""

RL002_NEG_COLD_PATH = """
    import jax

    class Sched:
        def _tick_fused(self):
            return 0

        def results(self):
            return jax.device_get(self.buf)
"""


def test_rl002_sync_reachable_from_root(tmp_path):
    codes, findings, _ = run_lint(tmp_path, RL002_POS)
    assert codes == ["RL002"]
    assert "_harvest" in findings[0].message
    assert "_tick_fused" in findings[0].message


def test_rl002_sync_off_hot_path_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL002_NEG_COLD_PATH)
    assert codes == []


def test_rl002_callback_and_property_edges(tmp_path):
    codes, findings, _ = run_lint(tmp_path, """
        import jax

        class Sched:
            def _tick_fused(self):
                self.executor.run(self._chunk)
                return self.width

            def _chunk(self):
                return float(jax.numpy.sum(self.buf))

            @property
            def width(self):
                return self.buf.item()
    """)
    assert sorted(codes) == ["RL002", "RL002"]
    msgs = " ".join(f.message for f in findings)
    assert "_chunk" in msgs and "width" in msgs


def test_rl002_shape_metadata_not_a_sync(tmp_path):
    codes, _, _ = run_lint(tmp_path, """
        class Sched:
            def _tick_fused(self):
                return int(self.tokens.shape[0]) + int(len(self.out))
    """)
    assert codes == []


# --------------------------------------------------------------- RL003

RL003_POS = """
    import jax

    def run(fns, xs):
        outs = []
        for f in fns:
            outs.append(jax.jit(f)(xs))
        return outs
"""

RL003_NEG = """
    import jax

    def run(f, chunks):
        step = jax.jit(f)
        return [step(c) for c in chunks]
"""


def test_rl003_jit_in_loop(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL003_POS)
    assert codes == ["RL003"]


def test_rl003_hoisted_jit_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL003_NEG)
    assert codes == []


def test_rl003_jit_in_comprehension(tmp_path):
    codes, _, _ = run_lint(tmp_path, """
        import jax

        def run(fns, x):
            return [jax.jit(f)(x) for f in fns]
    """)
    assert codes == ["RL003"]


# --------------------------------------------------------------- RL004

RL004_POS = """
    import jax

    class Loop:
        def run(self, x):
            def body(i, c):
                self.last = c
                return c + 1
            return jax.lax.fori_loop(0, 4, body, x)
"""

RL004_NEG = """
    import jax

    class Loop:
        def run(self, x):
            def body(i, c):
                nxt = c + 1
                return nxt
            out = jax.lax.fori_loop(0, 4, body, x)
            self.last = out
            return out
"""


def test_rl004_tracer_leak(tmp_path):
    codes, findings, _ = run_lint(tmp_path, RL004_POS)
    assert codes == ["RL004"]
    assert "self.last" in findings[0].message


def test_rl004_host_side_store_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL004_NEG)
    assert codes == []


def test_rl004_jitted_decorator_and_global(tmp_path):
    codes, _, _ = run_lint(tmp_path, """
        import jax

        LAST = None

        @jax.jit
        def step(x):
            global LAST
            LAST = x
            return x + 1
    """)
    assert codes == ["RL004"]


# --------------------------------------------------------------- RL005

RL005_POS = """
    import time

    async def pump():
        time.sleep(0.01)
"""

RL005_NEG = """
    import asyncio

    async def pump():
        await asyncio.sleep(0.01)
"""


def test_rl005_blocking_sleep(tmp_path):
    codes, findings, _ = run_lint(tmp_path, RL005_POS)
    assert codes == ["RL005"]
    assert "asyncio.sleep" in findings[0].message


def test_rl005_async_sleep_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL005_NEG)
    assert codes == []


def test_rl005_device_transfer_and_queue(tmp_path):
    codes, _, _ = run_lint(tmp_path, """
        import queue

        import jax

        inbox = queue.Queue()

        async def drain():
            item = inbox.get()
            return jax.device_get(item)
    """)
    assert sorted(codes) == ["RL005", "RL005"]


def test_rl005_asyncio_queue_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, """
        import asyncio

        inbox = asyncio.Queue()

        async def drain():
            return await inbox.get()
    """)
    assert codes == []


# --------------------------------------------------------------- RL006

RL006_POS_ID = """
    from repro.core.model import DecisionKey

    def make_key(obj):
        return DecisionKey("serve_tick", (id(obj),))
"""

RL006_POS_TAINT = """
    from repro.core.model import DecisionKey

    def make_key(obj):
        ident = id(obj)
        return DecisionKey("serve_tick", (ident,))
"""

RL006_POS_UNHASHABLE = """
    from repro.core.model import DecisionKey

    def make_key(shape):
        return DecisionKey("serve_tick", [shape])
"""

RL006_NEG = """
    from repro.core.model import DecisionKey

    def make_key(cfg):
        return DecisionKey("serve_tick", (cfg.name, cfg.d_model))
"""


def test_rl006_id_derived_key(tmp_path):
    for src in (RL006_POS_ID, RL006_POS_TAINT):
        codes, _, _ = run_lint(tmp_path, src)
        assert codes == ["RL006"]


def test_rl006_unhashable_component(tmp_path):
    codes, findings, _ = run_lint(tmp_path, RL006_POS_UNHASHABLE)
    assert codes == ["RL006"]
    assert "unhashable" in findings[0].message


def test_rl006_stable_key_ok(tmp_path):
    codes, _, _ = run_lint(tmp_path, RL006_NEG)
    assert codes == []


# ------------------------------------------------- suppression round-trip

def test_suppression_round_trip(tmp_path):
    flagged, _, sup0 = run_lint(tmp_path, RL003_POS)
    assert flagged == ["RL003"] and sup0 == 0
    suppressed_src = RL003_POS.replace(
        "outs.append(jax.jit(f)(xs))",
        "outs.append(jax.jit(f)(xs))  # repro-lint: disable=RL003")
    codes, _, suppressed = run_lint(tmp_path, suppressed_src)
    assert codes == []
    assert suppressed == 1


def test_suppression_is_per_code(tmp_path):
    # a disable= for a different rule does not mask the finding
    src = RL003_POS.replace(
        "outs.append(jax.jit(f)(xs))",
        "outs.append(jax.jit(f)(xs))  # repro-lint: disable=RL001")
    codes, _, suppressed = run_lint(tmp_path, src)
    assert codes == ["RL003"]
    assert suppressed == 0


# ----------------------------------------------------- CLI + select

def test_cli_exit_codes_and_output(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(RL003_POS))
    assert lint_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "RL003" in out and "bad.py" in out

    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert lint_main([str(clean)]) == 0


def test_cli_select(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(RL003_POS))
    assert lint_main([str(bad), "--select", "RL001", "--quiet"]) == 0
    assert lint_main([str(bad), "--select", "RL003", "--quiet"]) == 1


def test_cli_rejects_unknown_rule(tmp_path):
    with pytest.raises(SystemExit):
        lint_main([str(tmp_path), "--select", "RL999"])


def test_parse_error_is_a_finding(tmp_path):
    codes, findings, _ = run_lint(tmp_path, "def broken(:\n")
    assert codes == ["RL000"]


# ----------------------------------------------------- self-check: tree

def test_shipped_tree_is_clean():
    """`python -m repro.analysis.lint src tests benchmarks` exits 0 on
    the shipped tree — the exact invocation CI gates on."""
    findings, _ = lint_paths([REPO / "src", REPO / "tests",
                              REPO / "benchmarks"])
    assert findings == [], "\n".join(
        f"{f.path}:{f.line}: {f.code} {f.message}" for f in findings)


def test_shipped_tree_suppressions_are_sparse():
    """The sanctioned-sync suppressions stay a short, deliberate list —
    if this grows past a handful, the gate is being papered over.
    (PR 10 added the speculative drain's stats read and the timed
    dispatch's loop-round read — both inside the already-sanctioned
    periodic sync.)"""
    _, suppressed = lint_paths([REPO / "src"])
    assert suppressed <= 10


def test_default_config_encodes_serve_roots():
    cfg = LintConfig()
    assert "_tick_fused" in cfg.hot_roots
    assert "_pump" in cfg.hot_roots
    assert "decode_loop" in cfg.hot_modules
    assert cfg.donating_factories["make_fused_decode_step"] == (1,)
