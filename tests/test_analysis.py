"""Roofline analysis unit tests: HLO collective parsing + model flops."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import roofline
from repro.configs import get_config
from repro.configs.base import SHAPES

HLO = """
HloModule test
%add { ... }
%all-reduce.72 = f32[16,4096,1024]{2,1,0} all-reduce(%fusion.8), channel_id=89, replica_groups=[16,16]<=[256]
%all-gather.79 = bf16[1024,128]{1,0} all-gather(%cvt.24), channel_id=1, dimensions={0}
%ag-done = f32[8] all-gather-done(%x)
%all-to-all.3 = s8[64,256]{1,0} all-to-all(%q), channel_id=4
%collective-permute.1 = f32[2,2]{1,0} collective-permute(%p), channel_id=9
%reduce-scatter.5 = f32[128]{0} reduce-scatter(%g), channel_id=11
%not-a-collective = f32[10]{0} add(%a, %b)
"""


def test_collective_bytes_parser():
    out = roofline.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-reduce"] == 16 * 4096 * 1024 * 4
    assert b["all-gather"] == 1024 * 128 * 2
    assert b["all-to-all"] == 64 * 256 * 1
    assert b["collective-permute"] == 2 * 2 * 4
    assert b["reduce-scatter"] == 128 * 4
    assert b["total"] == sum(v for k, v in b.items()
                             if k not in ("total", "wire_total"))
    # ring wire model: all-reduce counts twice (RS + AG phases)
    assert b["wire_total"] == b["total"] + b["all-reduce"]
    assert out["counts"]["all-reduce"] == 1


def test_parser_ignores_done_ops_and_noise():
    out = roofline.collective_bytes(HLO)
    # all-gather-done must not double count
    assert out["counts"].get("all-gather") == 1


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen3-0.6b")
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    de = roofline.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert de == pytest.approx(2 * n * 128)


def test_model_flops_moe_uses_active():
    cfg = get_config("grok-1-314b")
    assert cfg.active_param_count() < cfg.param_count() * 0.5
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6 * cfg.active_param_count() * 4096 * 256)


def test_analyze_on_real_compiled():
    """End-to-end on a tiny real computation (1 device)."""
    cfg = get_config("qwen3-0.6b").reduced()
    f = jax.jit(lambda x: (x @ x).sum())
    comp = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rep = roofline.analyze(comp, cfg=cfg, shape=SHAPES["train_4k"],
                           mesh_name="t", chips=1)
    assert rep.flops_per_device > 0
    assert rep.compute_s > 0
    assert rep.dominant in ("compute", "memory", "collective")
    d = rep.to_dict()
    assert "roofline_fraction" in d and "step_time_s" in d
