"""Roofline analysis unit tests (HLO collective parsing + model flops)
and the HLO reshard auditor (analysis/hlo_audit.py): parsing, policy,
and the end-to-end gate demonstration on an emulated serving mesh."""
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_audit, roofline
from repro.configs import get_config
from repro.configs.base import SHAPES

FIXTURES = pathlib.Path(__file__).parent / "fixtures"
HLO = (FIXTURES / "collectives.hlo.txt").read_text()
LOOP_HLO = (FIXTURES / "fused_loop.hlo.txt").read_text()


def test_collective_bytes_parser():
    out = roofline.collective_bytes(HLO)
    b = out["bytes"]
    assert b["all-reduce"] == 16 * 4096 * 1024 * 4
    assert b["all-gather"] == 1024 * 128 * 2
    assert b["all-to-all"] == 64 * 256 * 1
    assert b["collective-permute"] == 2 * 2 * 4
    assert b["reduce-scatter"] == 128 * 4
    assert b["total"] == sum(v for k, v in b.items()
                             if k not in ("total", "wire_total"))
    # ring wire model: all-reduce counts twice (RS + AG phases)
    assert b["wire_total"] == b["total"] + b["all-reduce"]
    assert out["counts"]["all-reduce"] == 1


def test_parser_ignores_done_ops_and_noise():
    out = roofline.collective_bytes(HLO)
    # all-gather-done must not double count
    assert out["counts"].get("all-gather") == 1


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen3-0.6b")
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    de = roofline.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert de == pytest.approx(2 * n * 128)


def test_model_flops_moe_uses_active():
    cfg = get_config("grok-1-314b")
    assert cfg.active_param_count() < cfg.param_count() * 0.5
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6 * cfg.active_param_count() * 4096 * 256)


def test_analyze_on_real_compiled():
    """End-to-end on a tiny real computation (1 device)."""
    cfg = get_config("qwen3-0.6b").reduced()
    f = jax.jit(lambda x: (x @ x).sum())
    comp = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rep = roofline.analyze(comp, cfg=cfg, shape=SHAPES["train_4k"],
                           mesh_name="t", chips=1)
    assert rep.flops_per_device > 0
    assert rep.compute_s > 0
    assert rep.dominant in ("compute", "memory", "collective")
    d = rep.to_dict()
    assert "roofline_fraction" in d and "step_time_s" in d


# ---------------------------------------------------------------------------
# HLO reshard auditor (analysis/hlo_audit.py)
# ---------------------------------------------------------------------------

def test_audit_computation_split_and_body_closure():
    comps = hlo_audit.computations(LOOP_HLO)
    assert {"fused_computation.1", "body.2", "cond.3",
            "main.10"} <= set(comps)
    bodies = hlo_audit.loop_body_texts(LOOP_HLO)
    assert list(bodies) == ["body.2"]
    # the closure pulls in the fusion the body calls= ...
    assert "collective-permute.9" in bodies["body.2"]
    # ... but not the entry computation around the loop
    assert "all-gather.90" not in bodies["body.2"]


def test_audit_sharded_policy():
    """With model parallelism, the plan predicts all-reduce and tiny
    argmax all-gathers; the cache-pool gather and the permute (hidden
    inside a called fusion) are violations."""
    rep = hlo_audit.audit_hlo(
        LOOP_HLO, hlo_audit.AuditPolicy(model_parallel=2))
    assert rep.n_bodies == 1
    assert rep.counts() == {"all-reduce": 1, "all-gather": 2,
                            "collective-permute": 1}
    assert not rep.ok
    bad = {(op.kind, op.result_bytes) for op, _ in rep.violations}
    assert bad == {("all-gather", 4 * 2 * 32 * 16 * 4),
                   ("collective-permute", 4 * 2 * 4)}
    # the sanctioned ops are present but not violations
    assert rep.copy_count == 1 and rep.copy_bytes == 4 * 64 * 4


def test_audit_unsharded_rejects_all_collectives():
    rep = hlo_audit.audit_hlo(
        LOOP_HLO, hlo_audit.AuditPolicy(model_parallel=1))
    assert len(rep.violations) == 4
    assert all("unsharded" in reason for _, reason in rep.violations)


def test_audit_clean_single_device_body():
    clean = LOOP_HLO
    for op in ("all-reduce.3 = f32[4,64]{1,0} all-reduce",
               "all-gather.4 = f32[1,2]{1,0} all-gather",
               "all-gather.5 = f32[4,2,32,16]{3,2,1,0} all-gather",
               "collective-permute.9 = f32[4,2]{1,0} collective-permute"):
        name, rest = op.split(" = ")
        clean = clean.replace(
            op, name + " = " + rest.replace("-", "_ne_"))
    rep = hlo_audit.audit_hlo(clean,
                              hlo_audit.AuditPolicy(model_parallel=1))
    assert rep.ok and rep.counts() == {}
    assert rep.n_bodies == 1


def test_audit_report_serialises():
    rep = hlo_audit.audit_hlo(
        LOOP_HLO, hlo_audit.AuditPolicy(model_parallel=2))
    d = rep.to_dict()
    assert d["ok"] is False and d["n_loop_bodies"] == 1
    assert d["violations"][0]["kind"] in ("all-gather",
                                          "collective-permute")
    assert "reason" in d["violations"][0]
    assert "hlo-audit" in hlo_audit.format_report(rep)


AUDIT_GATE = """
from repro.analysis import hlo_audit

rc_clean = hlo_audit.main(["--mesh", "4,2"])
assert rc_clean == 0, f"clean mesh audit failed: rc={rc_clean}"
rc_bad = hlo_audit.main(["--mesh", "4,2", "--inject-reshard"])
assert rc_bad == 1, f"injected reshard not caught: rc={rc_bad}"
print("AUDIT_GATE_OK")
"""


def test_audit_gate_on_emulated_mesh(subproc):
    """The CI gate end to end, on the 4x2 host-emulated serving mesh:
    the live fused step audits clean; rebuilding it with the deliberate
    mid-loop reshard (decode_loop._inject_reshard) must fail the audit
    — the pool gathers are cache-row-sized, far over the argmax-lane
    threshold."""
    r = subproc(AUDIT_GATE)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "AUDIT_GATE_OK" in r.stdout
    assert "VIOLATION" in r.stdout          # the injected run printed it
    assert "all-gather" in r.stdout
