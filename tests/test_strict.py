"""Strict runtime mode (core/strict.py): poison-on-donate cache pools
and the hot-dispatch transfer guard.

The whole suite runs with ``REPRO_STRICT=1`` (tests/conftest.py), so
every serve/train test doubles as a strict-mode regression; these tests
pin the enforcement semantics themselves."""
import os

import jax
import pytest

from repro.configs import get_config
from repro.core import strict
from repro.models import init_params
from repro.serve import SlotKVCachePool


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_suite_runs_strict():
    assert os.environ.get("REPRO_STRICT") == "1"
    assert strict.enabled()


def test_poison_on_donate_then_adopt(setup):
    cfg, _ = setup
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    held = pool.caches                      # grab before the "dispatch"
    pool.mark_donated("test fused dispatch")
    with pytest.raises(strict.DonatedCacheError) as exc:
        _ = pool.caches
    assert "test fused dispatch" in str(exc.value)
    assert "RL001" in str(exc.value)        # points at the lint rule
    pool.adopt(held)                        # rebind clears the poison
    assert pool.caches is held
    assert pool.allocations == 1


def test_direct_assignment_clears_poison(setup):
    cfg, _ = setup
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    held = pool.caches
    pool.mark_donated("test dispatch")
    pool.caches = held                      # write_slot-style rebind
    assert pool.caches is held


def test_poison_inert_when_strict_off(setup, monkeypatch):
    cfg, _ = setup
    monkeypatch.setenv("REPRO_STRICT", "0")
    if strict._FORCED:                      # a prior enable() would win
        pytest.skip("strict force-enabled in this process")
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    pool.mark_donated("test dispatch")
    assert pool.caches is not None          # recorded but not enforced


def test_hot_dispatch_guard_allows_explicit_get(setup):
    """Inside the guard, the sanctioned syncs still work: explicit
    ``device_get`` and ``block_until_ready`` are not implicit
    transfers.  (The implicit-D2H *rejection* only materialises on
    accelerator backends — CPU reads are zero-copy and unguardable —
    so this pins the allowed side, which must hold everywhere.)"""
    import jax.numpy as jnp

    with strict.hot_dispatch_guard():
        y = jax.block_until_ready(jnp.arange(4) * 2)
        got = jax.device_get(y)
    assert got.tolist() == [0, 2, 4, 6]


def test_enable_forces_strict_in_subprocess(subproc):
    """``--strict`` path: strict.enable() wins over REPRO_STRICT=0."""
    r = subproc("""
import os
os.environ["REPRO_STRICT"] = "0"
from repro.core import strict
assert not strict.enabled()
strict.enable()
assert strict.enabled()
print("ok")
""", n_devices=1)
    assert r.returncode == 0, r.stderr
    assert "ok" in r.stdout
