"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref as R
from repro.kernels import tuning

RS = np.random.RandomState(0)


def arr(n, dtype):
    return jnp.asarray(RS.randn(n).astype(np.float32)).astype(dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("n", [128, 1000, 4096, 65536 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adjacent_difference(n, dtype):
    x = arr(n, dtype)
    out = K.adjacent_difference(x)
    ref = R.adjacent_difference_ref(x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("n", [128, 5000])
@pytest.mark.parametrize("iters", [1, 16, 64])
def test_artificial_work(n, iters):
    x = arr(n, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(K.artificial_work(x, iters=iters)),
        np.asarray(R.artificial_work_ref(x, iters)), rtol=1e-5)


@pytest.mark.parametrize("n", [128, 1000, 4096, 30000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reduce_sum(n, dtype):
    x = arr(n, dtype)
    np.testing.assert_allclose(float(K.reduce_sum(x)),
                               float(R.reduce_sum_ref(x)),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("n", [128, 1000, 8192])
def test_inclusive_scan(n):
    x = arr(n, jnp.float32)
    np.testing.assert_allclose(np.asarray(K.inclusive_scan(x)),
                               np.asarray(R.inclusive_scan_ref(x)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("rows,d", [(8, 128), (100, 256), (257, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    x = jnp.asarray(RS.randn(rows, d).astype(np.float32)).astype(dtype)
    g = jnp.asarray(RS.randn(d).astype(np.float32)).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(K.rmsnorm(x, g), np.float32),
        np.asarray(R.rmsnorm_ref(x, g), np.float32), **TOL[dtype])


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("sq,skv", [(64, 64), (40, 100), (128, 128)])
def test_flash_attention_gqa(hq, hkv, sq, skv):
    if sq > skv:
        pytest.skip("q longer than kv")
    q = jnp.asarray(RS.randn(2, hq, sq, 32).astype(np.float32))
    k = jnp.asarray(RS.randn(2, hkv, skv, 32).astype(np.float32))
    v = jnp.asarray(RS.randn(2, hkv, skv, 32).astype(np.float32))
    out = K.flash_attention(q, k, v, causal=True, block_q=16, block_kv=64)
    ref = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [1, 7, 64, 1000])
def test_flash_attention_swa(window):
    q = jnp.asarray(RS.randn(1, 2, 96, 32).astype(np.float32))
    k = jnp.asarray(RS.randn(1, 2, 96, 32).astype(np.float32))
    v = jnp.asarray(RS.randn(1, 2, 96, 32).astype(np.float32))
    out = K.flash_attention(q, k, v, causal=True, window=window,
                            block_q=32, block_kv=32)
    ref = R.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RS.randn(1, 2, 64, 64).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(RS.randn(1, 2, 64, 64).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(RS.randn(1, 2, 64, 64).astype(np.float32)).astype(jnp.bfloat16)
    out = K.flash_attention(q, k, v, causal=True, block_q=32, block_kv=64)
    ref = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_noncausal():
    q = jnp.asarray(RS.randn(1, 2, 48, 32).astype(np.float32))
    k = jnp.asarray(RS.randn(1, 2, 80, 32).astype(np.float32))
    v = jnp.asarray(RS.randn(1, 2, 80, 32).astype(np.float32))
    out = K.flash_attention(q, k, v, causal=False, block_q=16, block_kv=32)
    ref = R.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_tuning_plans():
    p = tuning.plan_1d(10**6, bytes_per_elem=4)
    assert p.block % tuning.LANE == 0
    assert p.grid >= 8 or p.padded <= tuning.LANE * tuning.SUBLANE * 8
    assert p.block * p.grid >= 10**6
    bq, bk = tuning.plan_attention(4096, 4096, 128)
    assert bq % tuning.SUBLANE == 0 and bk % tuning.LANE == 0
    # VMEM budget respected
    live = (2 * bq * 128 + 2 * bk * 128 + bq * bk) * 2 + bq * 128 * 4
    from repro.core.hardware import TPU_V5E

    assert live <= TPU_V5E.vmem_bytes * 0.5 / 2
