"""Paged KV cache: byte-identity against the contiguous pool, paged
flash-attention over permuted page tables, copy-on-write / refcount
invariants under random op interleavings, stale-page poisoning, and the
``serve_page_size`` / ``serve_prefill_interleave`` decision kinds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SequentialExecutor, adaptive, strict
from repro.core.acc import AdaptiveCoreChunk
from repro.data import make_batch
from repro.models import init_params
from repro.serve import RequestState, ServeScheduler
from repro.serve.kv_cache import PagedKVCachePool


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_sched(cfg, params, *, paged, depth="auto", n_slots=2,
               max_len=48, **kw):
    return ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=max_len,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=depth, paged=paged, **kw)


def run_spec(sched, tokens, spec):
    sched.warmup()
    rids = [sched.submit(tokens[i][:p], max_new_tokens=n)
            for i, (p, n) in enumerate(spec)]
    outs = sched.run_until_idle()
    return [outs[r] for r in rids]


# ---------------------------------------------------------------------------
# byte identity: paged fused decode vs the contiguous pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 4])
def test_paged_tokens_identical_to_contiguous(setup, depth):
    cfg, params = setup
    tokens = make_batch(cfg, 3, 14, kind="prefill", seed=11)["tokens"]
    spec = [(14, 9), (9, 3), (6, 7)]
    ref = run_spec(make_sched(cfg, params, paged=False, depth=depth),
                   tokens, spec)
    sched = make_sched(cfg, params, paged=True, depth=depth, page_size=8)
    got = run_spec(sched, tokens, spec)
    assert got == ref
    assert sched.pool.allocations == 1, "donation invariant broke"


def test_prefix_reuse_does_not_change_tokens(setup):
    """Identical prompts resubmitted: later requests map the first's
    pages read-only — the hit rate goes up, the tokens do not move (the
    end-to-end proof that shared prefix pages are never mutated)."""
    cfg, params = setup
    prompt = make_batch(cfg, 1, 23, kind="prefill", seed=3)["tokens"][0]
    ref_sched = make_sched(cfg, params, paged=False)
    ref_sched.warmup()
    r = ref_sched.submit(prompt, max_new_tokens=6)
    ref = ref_sched.run_until_idle()[r]

    sched = make_sched(cfg, params, paged=True, page_size=8)
    sched.warmup()
    outs = []
    for _ in range(3):
        rid = sched.submit(prompt, max_new_tokens=6)
        outs.append(sched.run_until_idle()[rid])
        sched.clear_finished()
    assert outs == [ref, ref, ref]
    stats = sched.pool.prefix_stats()
    assert stats["prefix_hits"] >= 2
    assert stats["prefill_tokens_avoided"] > 0


# ---------------------------------------------------------------------------
# kernel: paged attention over a permuted page table
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _paged_attention_case(seed, sq):
    """Randomly permuted page table, garbage in unused rows: the paged
    kernel must be byte-identical to the contiguous flash kernel with
    ``block_kv == page_size`` (same tile schedule, different DMA
    addressing)."""
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               paged_flash_attention_pallas)
    B, HQ, HKV, D, PS, MAX_LEN = 2, 2, 1, 8, 8, 32
    nblk = MAX_LEN // PS
    n_pages = 1 + B * nblk
    rng = np.random.RandomState(seed)
    kv_lens = rng.randint(sq, MAX_LEN + 1, size=B).astype(np.int32)
    q = jnp.asarray(rng.randn(B, HQ, sq, D), jnp.float32)
    k_full = rng.randn(B, HKV, MAX_LEN, D).astype(np.float32)
    v_full = rng.randn(B, HKV, MAX_LEN, D).astype(np.float32)
    pt = rng.permutation(np.arange(1, n_pages)) \
        .reshape(B, nblk).astype(np.int32)
    # Flat token-major stores; rows past each lane's kv_len hold finite
    # garbage (the pool's unwritten-page state) that must not leak.
    k_pages = np.full((n_pages * PS, HKV, D), 7.5e4, np.float32)
    v_pages = np.full((n_pages * PS, HKV, D), 7.5e4, np.float32)
    k_pages[:PS] = v_pages[:PS] = 0.0
    for b in range(B):
        for j in range(nblk):
            lo, hi = j * PS, min((j + 1) * PS, int(kv_lens[b]))
            if hi <= lo:
                continue
            rows = slice(pt[b, j] * PS, pt[b, j] * PS + (hi - lo))
            k_pages[rows] = k_full[b, :, lo:hi].transpose(1, 0, 2)
            v_pages[rows] = v_full[b, :, lo:hi].transpose(1, 0, 2)
    got = paged_flash_attention_pallas(
        q, jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(pt), jnp.asarray(kv_lens), page_size=PS)
    for b in range(B):
        length = int(kv_lens[b])
        kb = jnp.asarray(k_full[b:b + 1]).at[:, :, length:].set(0.0)
        vb = jnp.asarray(v_full[b:b + 1]).at[:, :, length:].set(0.0)
        ref = flash_attention_pallas(
            q[b:b + 1], kb, vb, causal=True, kv_len=length,
            block_q=sq, block_kv=PS)
        assert jnp.all(got[b:b + 1] == ref), (b, seed, sq)


@pytest.mark.parametrize("sq", [1, 4])
def test_paged_attention_matches_contiguous(sq):
    for seed in (0, 17, 2**31 - 5):
        _paged_attention_case(seed, sq)


# ---------------------------------------------------------------------------
# refcount / CoW invariants under random interleavings
# ---------------------------------------------------------------------------

def _rows(cfg, rng, seg):
    """A batch-of-1 prefill-shaped row pytree covering ``seg`` tokens."""
    h, d = cfg.n_kv_heads, cfg.head_dim_
    return [{"k": jnp.asarray(rng.randn(1, h, seg, d), jnp.float32),
             "v": jnp.asarray(rng.randn(1, h, seg, d), jnp.float32)}
            for _ in cfg.layer_kinds()]


def _check_refcounts(pool):
    """Every page's refcount equals the references the host actually
    holds: page-table entries plus prefix-cache entries (page 0 is
    pinned by construction and never enters the free list)."""
    expected = [0] * pool.n_pages
    expected[0] = 1
    for slot in range(pool.n_slots):
        for pid in pool.page_tables[slot]:
            if pid:
                expected[pid] += 1
    for entry in pool._prefix.values():
        expected[entry.page] += 1
    assert pool.page_refs == expected, (pool.page_refs, expected)
    free = set(pool._free_pages)
    assert 0 not in free
    for pid in range(1, pool.n_pages):
        assert (pool.page_refs[pid] == 0) == (pid in free), pid
    # Memory is bounded by pages, not by slots: one device allocation,
    # live pages within the fixed pool.
    assert pool.allocations == 1
    assert pool.pages_in_use() <= pool.n_pages - 1


def _cow_case(cfg, ops, seed):
    rng = np.random.RandomState(seed)
    pool = PagedKVCachePool(cfg, 3, 32, page_size=8)
    base = tuple(int(t) for t in rng.randint(0, cfg.vocab_size, 20))
    prompts = [base, base[:16] + tuple((t + 1) % cfg.vocab_size
                                       for t in base[16:]), base[:9]]
    snapshots = {}      # prefix key -> layer-0 K rows at registration
    live = {}           # slot -> prompt tokens

    def snapshot(pid):
        ps = pool.page_size
        return np.asarray(pool.caches[0]["k"][pid * ps:(pid + 1) * ps])

    for op, which, arg in ops:
        if op == 0 and pool.free_slots():          # admit with prefix
            toks = prompts[which]
            slot, reused = pool.acquire_with_prefix(f"r{arg}", toks)
            assert reused < len(toks)
            live[slot] = toks
        elif op == 1 and live:                     # prefill + publish
            slot = sorted(live)[which % len(live)]
            toks = live[slot]
            start = pool.positions[slot]
            if start < len(toks):
                pool.ensure_writable(slot, start, len(toks))
                pool.write_slot(slot, _rows(cfg, rng, len(toks) - start),
                                start, len(toks))
                pool.positions[slot] = len(toks)
                pool.register_prefix(slot, toks)
                for j in range(-(-len(toks) // pool.page_size)):
                    end = min((j + 1) * pool.page_size, len(toks))
                    key = toks[:end]
                    if key in pool._prefix and key not in snapshots:
                        snapshots[key] = snapshot(pool._prefix[key].page)
        elif op == 2 and live:                     # decode one token
            slot = sorted(live)[which % len(live)]
            pos = pool.positions[slot]
            if pos < pool.max_len:
                pool.ensure_writable(slot, pos, pos + 1)
                # Post-CoW exclusivity: every page under the write is
                # now referenced once — shared content cannot be hit.
                for j in range(pos // pool.page_size,
                               -(-(pos + 1) // pool.page_size)):
                    pid = pool.page_tables[slot][j]
                    assert pool.page_refs[pid] == 1
                pool.write_slot(slot, _rows(cfg, rng, 1), pos, pos + 1)
                pool.positions[slot] = pos + 1
        elif op == 3 and live:                     # fork CoW
            src = sorted(live)[which % len(live)]
            slot = pool.fork(src, f"f{arg}")
            if slot is not None:
                live[slot] = live[src]
        elif op == 4 and live:                     # release
            slot = sorted(live)[which % len(live)]
            pool.release(slot)
            del live[slot]
        _check_refcounts(pool)

    # Registered prefix pages were never mutated, whatever interleaving
    # of admits, writes, forks and releases ran above.
    for key, snap in snapshots.items():
        entry = pool._prefix.get(key)
        if entry is None:
            continue        # evicted for space — nothing left to check
        np.testing.assert_array_equal(snapshot(entry.page), snap, str(key))


def test_cow_refcount_invariants(setup):
    """Fixed-seed random interleavings of admit / prefill+publish /
    decode-write / fork / release (the hypothesis sweep below explores
    further when the library is present)."""
    cfg, _ = setup
    for seed in (0, 7, 91):
        rng = np.random.RandomState(seed * 31 + 5)
        ops = [(int(rng.randint(0, 5)), int(rng.randint(0, 3)),
                int(rng.randint(0, 2**16))) for _ in range(22)]
        _cow_case(cfg, ops, seed)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1), sq=st.sampled_from([1, 4]))
    @settings(max_examples=10, deadline=None)
    def test_paged_attention_matches_contiguous_property(seed, sq):
        _paged_attention_case(seed, sq)

    @given(ops=st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2),
                                  st.integers(0, 2**16)),
                        min_size=1, max_size=25),
           seed=st.integers(0, 2**16))
    @settings(max_examples=12, deadline=None)
    def test_cow_refcount_invariants_property(setup, ops, seed):
        cfg, _ = setup
        _cow_case(cfg, ops, seed)


# ---------------------------------------------------------------------------
# strict mode: freed pages poison until re-acquired
# ---------------------------------------------------------------------------

def test_stale_page_raises_under_strict(setup):
    cfg, _ = setup
    pool = PagedKVCachePool(cfg, 2, 32, page_size=8)
    s1 = pool.acquire("a")
    s2 = pool.acquire("b")
    pool.ensure_writable(s1, 0, 8)
    pool.ensure_writable(s2, 0, 8)
    freed = pool.page_tables[s2][0]
    pool.release(s2)                 # page freed -> poisoned
    assert freed in pool._poisoned
    pool.page_tables[s1][1] = freed  # simulate a stale-table bug
    with pytest.raises(strict.StalePageError):
        pool.page_table_array()
    # Re-acquisition clears the poison: the page is valid again.
    pool.page_tables[s1][1] = 0
    pool.ensure_writable(s1, 8, 16)
    assert pool.page_tables[s1][1] not in pool._poisoned
    pool.page_table_array()


def test_cow_source_pages_survive_release(setup):
    """Releasing a slot whose pages the prefix cache still references
    must NOT free them (refcount, not ownership, decides)."""
    cfg, _ = setup
    pool = PagedKVCachePool(cfg, 2, 32, page_size=8)
    toks = tuple(range(16))
    slot, reused = pool.acquire_with_prefix("a", toks)
    assert reused == 0
    pool.ensure_writable(slot, 0, 16)
    pool.positions[slot] = 16
    pool.register_prefix(slot, toks)
    pages = [pool.page_tables[slot][j] for j in range(2)]
    pool.release(slot)
    for pid in pages:
        assert pool.page_refs[pid] == 1      # cache still holds them
        assert pid not in pool._poisoned
    slot2, reused2 = pool.acquire_with_prefix("b", toks + (1, 2))
    assert reused2 == 16
    assert [pool.page_tables[slot2][j] for j in range(2)] == pages


# ---------------------------------------------------------------------------
# the two new decision kinds
# ---------------------------------------------------------------------------

def test_page_size_and_interleave_decisions(setup):
    cfg, params = setup
    # depth=1 keeps r1 decoding one token per tick, so the second
    # request's prefill demonstrably lands while a decode lane is live.
    sched = make_sched(cfg, params, paged=True, depth=1, n_slots=2,
                       max_len=48)
    sched.warmup()
    model = sched.decision_model()
    assert model.trace.entries("serve_page_size"), \
        "page geometry was not decided through the ExecutionModel"
    prompt = make_batch(cfg, 1, 12, kind="prefill", seed=5)["tokens"][0]
    r1 = sched.submit(prompt, max_new_tokens=16)
    # Tick until r1 is decoding, then land a second prefill on top: the
    # interleave decision must gate how many chunks ride the tick.
    for _ in range(20):
        sched.tick()
        if sched.requests[r1].state is RequestState.DECODE:
            break
    sched.submit(prompt[:8], max_new_tokens=4)
    for _ in range(40):
        if not sched.pending:
            break
        sched.tick()
    sched.results()
    entries = model.trace.entries("serve_prefill_interleave")
    assert entries, "no serve_prefill_interleave decisions were traced"
    prov = {e.decision.provenance for e in
            model.trace.entries("serve_page_size")}
    assert prov, prov
