"""Chunked gated linear attention: chunked == naive == recurrent, plus
hypothesis sweeps over shapes."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="shape sweeps need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import gla

RS = np.random.RandomState(7)


def make(b, l, h, n, p, scale=0.3):
    q = jnp.asarray(RS.randn(b, l, h, n).astype(np.float32)) * scale
    k = jnp.asarray(RS.randn(b, l, h, n).astype(np.float32)) * scale
    v = jnp.asarray(RS.randn(b, l, h, p).astype(np.float32))
    ld = -jnp.abs(jnp.asarray(RS.randn(b, l, h).astype(np.float32))) * 0.5
    g = jnp.asarray(RS.randn(b, l, h).astype(np.float32)) * 0.3
    return q, k, v, ld, g


@given(b=st.integers(1, 3), nc=st.integers(1, 4), h=st.integers(1, 3),
       n=st.sampled_from([4, 8]), p=st.sampled_from([4, 16]),
       chunk=st.sampled_from([8, 16, 32]))
@settings(max_examples=25, deadline=None)
def test_chunked_equals_reference(b, nc, h, n, p, chunk):
    l = nc * chunk
    q, k, v, ld, g = make(b, l, h, n, p)
    y, _ = gla.chunked_gla(q, k, v, ld, g, chunk=chunk)
    yref = gla.gla_reference(q, k, v, ld, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=3e-4, atol=3e-4)


def test_recurrent_equals_chunked():
    q, k, v, ld, g = make(2, 64, 2, 8, 8)
    y, s = gla.chunked_gla(q, k, v, ld, g, chunk=16)
    state = jnp.zeros((2, 2, 8, 8))
    ys = []
    for t in range(64):
        yt, state = gla.gla_step(q[:, t], k[:, t], v[:, t], ld[:, t],
                                 g[:, t], state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s),
                               rtol=3e-4, atol=3e-4)


def test_state_continuation():
    q, k, v, ld, g = make(1, 96, 2, 4, 4)
    y_full, s_full = gla.chunked_gla(q, k, v, ld, g, chunk=16)
    y1, s1 = gla.chunked_gla(q[:, :48], k[:, :48], v[:, :48], ld[:, :48],
                             g[:, :48], chunk=16)
    y2, s2 = gla.chunked_gla(q[:, 48:], k[:, 48:], v[:, 48:], ld[:, 48:],
                             g[:, 48:], chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=3e-4, atol=3e-4)


def test_no_gain_defaults_to_zero():
    q, k, v, ld, _ = make(1, 32, 1, 4, 4)
    y1, _ = gla.chunked_gla(q, k, v, ld, None, chunk=16)
    y2, _ = gla.chunked_gla(q, k, v, ld, jnp.zeros((1, 32, 1)), chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_unroll_flag_equivalence():
    from repro.models import flags

    q, k, v, ld, g = make(1, 64, 2, 4, 8)
    y1, s1 = gla.chunked_gla(q, k, v, ld, g, chunk=16)
    with flags.unroll_for_accounting():
        y2, s2 = gla.chunked_gla(q, k, v, ld, g, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
