"""Host-path tests for the parallel algorithm suite, across policies."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms as alg
from repro.core import (AdaptiveCoreChunk, HostParallelExecutor,
                        StaticCoreChunk, par, seq)


@pytest.fixture(scope="module")
def host():
    ex = HostParallelExecutor(max_workers=4)
    yield ex
    ex.shutdown()


def policies(host):
    return [
        ("seq", seq),
        ("par-static", par.on(host).with_(StaticCoreChunk(4, 2))),
        ("par-acc", par.on(host).with_(AdaptiveCoreChunk(t0_override=1e-5))),
    ]


@pytest.fixture(params=["seq", "par-static", "par-acc"])
def policy(request, host):
    return dict(policies(host))[request.param]


@pytest.fixture(scope="module")
def x():
    return jnp.asarray(np.random.RandomState(0).randn(4097).astype(np.float32))


def test_transform(policy, x):
    out = alg.transform(policy, x, lambda c: c * 2 + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2 + 1,
                               rtol=1e-6)


def test_transform_binary(policy, x):
    y = jnp.ones_like(x)
    out = alg.transform(policy, x, lambda a, b: a * b + a, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2, rtol=1e-6)


def test_copy_fill_generate(policy, x):
    np.testing.assert_array_equal(np.asarray(alg.copy(policy, x)),
                                  np.asarray(x))
    f = alg.fill(policy, x, 3.5)
    assert np.all(np.asarray(f) == 3.5)
    g = alg.generate(policy, 100, lambda i: i * i)
    np.testing.assert_array_equal(np.asarray(g),
                                  (np.arange(100) ** 2).astype(np.float32))


def test_reduce(policy, x):
    np.testing.assert_allclose(float(alg.reduce(policy, x, jnp.add)),
                               np.sum(np.asarray(x), dtype=np.float32),
                               rtol=1e-4)
    assert float(alg.reduce(policy, x, jnp.maximum)) == np.max(np.asarray(x))
    assert float(alg.reduce(policy, x, jnp.minimum)) == np.min(np.asarray(x))


def test_transform_reduce_count_quantifiers(policy, x):
    n = int(alg.count_if(policy, x, lambda c: c > 0))
    assert n == int(np.sum(np.asarray(x) > 0))
    assert bool(alg.all_of(policy, x, lambda c: c > -100))
    assert bool(alg.any_of(policy, x, lambda c: c > 2))
    assert bool(alg.none_of(policy, x, lambda c: c > 100))


def test_min_max_element(policy, x):
    v, i = alg.min_element(policy, x)
    xs = np.asarray(x)
    assert float(v) == xs.min() and xs[int(i)] == xs.min()
    v, i = alg.max_element(policy, x)
    assert float(v) == xs.max() and xs[int(i)] == xs.max()


def test_scans(policy, x):
    s = alg.inclusive_scan(policy, x)
    np.testing.assert_allclose(np.asarray(s), np.cumsum(np.asarray(x)),
                               rtol=1e-3, atol=1e-3)
    e = alg.exclusive_scan(policy, x, 0.0)
    assert float(e[0]) == 0.0
    np.testing.assert_allclose(np.asarray(e)[1:],
                               np.cumsum(np.asarray(x))[:-1],
                               rtol=1e-3, atol=1e-3)


def test_adjacent_difference(policy, x):
    d = alg.adjacent_difference(policy, x)
    xs = np.asarray(x)
    ref = np.concatenate([xs[:1], np.diff(xs)])
    np.testing.assert_allclose(np.asarray(d), ref, rtol=1e-5, atol=1e-6)


def test_stencil3(policy, x):
    out = alg.stencil3(policy, x)
    xs = np.asarray(x)
    ref = xs.copy()
    ref[1:-1] = xs[:-2] - 2 * xs[1:-1] + xs[2:]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)


def test_artificial_work(policy):
    x = jnp.ones((513,), jnp.float32)
    out = alg.artificial_work(policy, x, iters=8)
    assert out.shape == (513,)
    assert np.all(np.isfinite(np.asarray(out)))
    # matches the reference chain
    from repro.kernels.ref import artificial_work_ref

    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(artificial_work_ref(x, 8)),
                               rtol=1e-6)


@pytest.mark.parametrize("n", [1, 2, 3, 7, 129])
def test_edge_sizes(host, n):
    pol = par.on(host).with_(StaticCoreChunk(4, 2))
    x = jnp.arange(n, dtype=jnp.float32)
    d = alg.adjacent_difference(pol, x)
    xs = np.asarray(x)
    ref = np.concatenate([xs[:1], np.diff(xs)])
    np.testing.assert_allclose(np.asarray(d), ref)
    s = alg.inclusive_scan(pol, x)
    np.testing.assert_allclose(np.asarray(s), np.cumsum(xs), rtol=1e-5)
