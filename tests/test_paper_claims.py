"""Validation of the paper's experimental claims against the calibrated
machine model (EXPERIMENTS.md §Paper-validation).

The container has 1 CPU core, so Figures 1-4 (40-core Skylake / 48-core
EPYC wall-clock) are reproduced on the calibrated SimMachine; the claims
tested here are the paper's qualitative + quantitative statements.
"""
from repro.core import (ADJACENT_DIFFERENCE, AMD_EPYC_48C, EPYC_48,
                        INTEL_SKYLAKE_40C, SKYLAKE_40, artificial_work,
                        t_iter_analytic)
from repro.core import overhead_law as ol

SIZES = [2 ** k for k in range(10, 25, 2)]
T_ITER_MEM = t_iter_analytic(ADJACENT_DIFFERENCE, INTEL_SKYLAKE_40C)
T_ITER_CPU = t_iter_analytic(artificial_work(2048), INTEL_SKYLAKE_40C)
MEM_SAT = 10  # cores that saturate socket bandwidth (≈10x cap, Fig 2)


def acc_speedup(m, t_iter, n, sat=None):
    # acc calibrates T0 with the empty-task benchmark at full width
    d = ol.decide(t_iter=t_iter, n_elements=n, t0=m.t0_for(m.cores),
                  max_cores=m.cores)
    return (t_iter * n) / m.run_decision(d, saturation_cores=sat)


def static_speedup(m, t_iter, n, cores, c=4, sat=None):
    return m.speedup(t_iter=t_iter, count=n, n_cores=cores,
                     chunks_per_core=c, saturation_cores=sat)


def test_claim_small_inputs_prefer_fewer_cores():
    """Fig 2: for small arrays, fewer cores win; for large, more win."""
    m = SKYLAKE_40
    small, large = 2 ** 10, 2 ** 24
    s_small_2 = static_speedup(m, T_ITER_MEM, small, 2, sat=MEM_SAT)
    s_small_40 = static_speedup(m, T_ITER_MEM, small, 40, sat=MEM_SAT)
    assert s_small_2 > s_small_40
    s_large_2 = static_speedup(m, T_ITER_MEM, large, 2, sat=MEM_SAT)
    s_large_40 = static_speedup(m, T_ITER_MEM, large, 40, sat=MEM_SAT)
    assert s_large_40 > s_large_2


def test_claim_acc_improves_overall_and_tracks_envelope_at_scale():
    """Section 6 claim (1), stated as the figures support it:
    (a) acc is never slower than sequential at ANY size (statics with
        many cores tank at small sizes — the slowdowns acc avoids),
    (b) acc matches/beats the best static config at scale,
    (c) every fixed parallel config has a catastrophic region (worst-case
        ratio vs acc < 0.9 somewhere) — only acc is safe everywhere.
    The conservative crossover region (Eq. 7 with the single full-width
    T0) is documented in EXPERIMENTS.md §Paper-validation."""
    m = SKYLAKE_40
    accs, statics = [], {c: [] for c in (2, 4, 8, 16, 32, 40)}
    for n in SIZES:
        sa = acc_speedup(m, T_ITER_MEM, n, sat=MEM_SAT)
        assert sa >= 0.999, (n, sa)                      # (a)
        accs.append(sa)
        for c in statics:
            statics[c].append(static_speedup(m, T_ITER_MEM, n, c,
                                             sat=MEM_SAT))
    best_at_scale = max(s[-1] for s in statics.values())
    assert accs[-1] >= best_at_scale * 0.95              # (b)
    for c, vals in statics.items():                      # (c)
        worst = min(v / a for v, a in zip(vals, accs, strict=True))
        assert worst < 0.9, (c, worst)


def test_claim_c8_best_chunking_under_noise():
    """Fig 1: C=8 chunks/core beats C=1 and C=4 for large inputs (load
    balancing under jitter), and excessive chunking hurts."""
    m = SKYLAKE_40
    n = 2 ** 24
    s = {c: m.speedup(t_iter=T_ITER_MEM, count=n, n_cores=40,
                      chunks_per_core=c, saturation_cores=MEM_SAT)
         for c in (1, 4, 8)}
    assert s[8] >= s[1]
    assert s[8] >= s[4] * 0.98
    # excessive chunking: per-task overhead dominates once chunks shrink
    # to O(t_task) of work (visible at smaller inputs, paper Section 5)
    n_small = 2 ** 18
    s8 = m.speedup(t_iter=T_ITER_MEM, count=n_small, n_cores=40,
                   chunks_per_core=8, saturation_cores=MEM_SAT)
    s512 = m.speedup(t_iter=T_ITER_MEM, count=n_small, n_cores=40,
                     chunks_per_core=512, saturation_cores=MEM_SAT)
    assert s8 > s512


def test_claim_compute_bound_parallelizes_earlier():
    """Figs 3/4 vs Fig 2: the compute-bound body starts benefiting from
    parallelism at smaller inputs than the memory-bound one."""
    m = SKYLAKE_40

    def crossover(t_iter, sat=None):
        for n in sorted(SIZES):
            if acc_speedup(m, t_iter, n, sat=sat) > 1.5:
                return n
        return SIZES[-1] * 2

    assert crossover(T_ITER_CPU) < crossover(T_ITER_MEM, sat=MEM_SAT)


def test_claim_compute_bound_speedup_magnitudes():
    """~38x on 40 cores (Intel) and ~46x on 48 (AMD) for compute-bound;
    memory-bound saturates far lower (~10x reported)."""
    n = 2 ** 24
    s_intel = acc_speedup(SKYLAKE_40, T_ITER_CPU, n)
    assert 30 <= s_intel <= 40          # paper: up to 38x on 40 cores
    s_amd = acc_speedup(EPYC_48, t_iter_analytic(artificial_work(2048),
                                                 AMD_EPYC_48C), n)
    assert 36 <= s_amd <= 48            # paper: up to 46x on 48 cores
    # memory-bound saturates the socket bandwidth: paper reports ~10x.
    s_mem = acc_speedup(SKYLAKE_40, T_ITER_MEM, n, sat=MEM_SAT)
    assert 8 <= s_mem <= 12
    assert s_mem < s_intel


def test_claim_acc_avoids_small_workload_slowdown():
    """Section 5: "not only will this avoid slowdowns when loops are too
    small or quick to benefit from parallelism"."""
    m = SKYLAKE_40
    n = 256
    assert acc_speedup(m, T_ITER_MEM, n) >= 0.999  # never slower than seq
    assert static_speedup(m, T_ITER_MEM, n, 40) < 0.5  # static-40 tanks


def test_t0_measured_on_this_host_is_sane():
    """The real (measured) empty-task benchmark on this container."""
    from repro.core import HostParallelExecutor
    from repro.core.calibration import measure_t0_empty_task

    ex = HostParallelExecutor(max_workers=2)
    t0 = measure_t0_empty_task(ex, repeats=8)
    ex.shutdown()
    assert 1e-7 < t0 < 5e-2  # dispatch overhead is real and finite
