"""Measured Pallas block autotuner: candidate legality (property-style
sweeps), winner persistence/round-trip, hardware invalidation, and the
satellite fixes (plan_1d VMEM clamp, feedback size attribution, mesh
compat)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.core.calibration import CalibrationCache
from repro.core.feedback import OnlineFeedback, tag_workload
from repro.core.hardware import TPU_V5E
from repro.kernels import ref as R
from repro.kernels import tuning
from repro.kernels.autotune import (KernelTuner, attention_live_bytes,
                                    candidates_1d, candidates_attention,
                                    max_block_1d, shape_bucket)

RS = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# candidate generation: tile alignment + VMEM budget (property sweeps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096, 65536 + 3, 10**6])
@pytest.mark.parametrize("bytes_per_elem", [1, 2, 4])
@pytest.mark.parametrize("arrays_in_vmem", [1, 2, 3])
def test_candidates_1d_legal(n, bytes_per_elem, arrays_in_vmem):
    cands = candidates_1d(n, bytes_per_elem=bytes_per_elem,
                          arrays_in_vmem=arrays_in_vmem)
    cap = max_block_1d(bytes_per_elem=bytes_per_elem,
                       arrays_in_vmem=arrays_in_vmem)
    budget = TPU_V5E.vmem_bytes * 0.25 / (2.0 * arrays_in_vmem)
    assert cands, (n, bytes_per_elem)
    assert len(set(cands)) == len(cands)
    for b in cands:
        assert b % tuning.LANE == 0, (n, b)
        assert b <= cap
        # either inside the budget, or the single smallest legal tile
        assert b * bytes_per_elem <= budget or b == tuning.LANE
        # never wider than the padded problem
        assert b <= ((n + tuning.LANE - 1) // tuning.LANE) * tuning.LANE
    # the analytic prior leads the candidate list
    assert cands[0] == min(
        tuning.plan_1d(n, bytes_per_elem=bytes_per_elem,
                       arrays_in_vmem=arrays_in_vmem).block,
        ((max(n, 1) + tuning.LANE - 1) // tuning.LANE) * tuning.LANE)


@pytest.mark.parametrize("align", [tuning.SUBLANE, tuning.LANE])
@pytest.mark.parametrize("prior", [8, 100, 4096])
def test_candidates_1d_alignment_override(align, prior):
    for b in candidates_1d(5000, align=align, prior=prior):
        assert b % align == 0 and b >= align


@pytest.mark.parametrize("sq,skv,d", [(8, 128, 32), (40, 100, 64),
                                      (512, 512, 64), (4096, 4096, 128),
                                      (64, 8192, 128)])
@pytest.mark.parametrize("bytes_per_elem", [2, 4])
def test_candidates_attention_legal(sq, skv, d, bytes_per_elem):
    budget = TPU_V5E.vmem_bytes * 0.5 / 2.0
    cands = candidates_attention(sq, skv, d, bytes_per_elem=bytes_per_elem)
    assert cands
    assert len(set(cands)) == len(cands)
    for bq, bk in cands:
        assert bq % tuning.SUBLANE == 0 and bk % tuning.LANE == 0
        assert attention_live_bytes(bq, bk, d, bytes_per_elem) <= budget
        assert bq <= ((sq + tuning.SUBLANE - 1) // tuning.SUBLANE) \
            * tuning.SUBLANE
        assert bk <= ((skv + tuning.LANE - 1) // tuning.LANE) * tuning.LANE


def test_shape_bucket():
    assert [shape_bucket(n) for n in (1, 2, 3, 1000, 1024, 1025)] \
        == [1, 2, 4, 1024, 1024, 2048]


# ---------------------------------------------------------------------------
# satellite: plan_1d respects a small VMEM budget (clamp ordering)
# ---------------------------------------------------------------------------

def test_plan_1d_small_budget_respects_vmem():
    tiny = dataclasses.replace(TPU_V5E, vmem_bytes=512 * 1024)
    for bytes_per_elem, arrays in [(4, 2), (4, 8), (8, 4)]:
        p = tuning.plan_1d(10**6, bytes_per_elem=bytes_per_elem,
                           arrays_in_vmem=arrays, hw=tiny)
        budget = tiny.vmem_bytes * 0.25 / (2.0 * arrays)
        assert p.block % tuning.LANE == 0
        assert p.block * bytes_per_elem <= max(budget,
                                               tuning.LANE * bytes_per_elem)
        assert p.padded >= 10**6


def test_plan_1d_normal_budget_unchanged():
    p = tuning.plan_1d(10**6, bytes_per_elem=4)
    assert p.block >= tuning.LANE * tuning.SUBLANE
    assert p.padded >= 10**6


# ---------------------------------------------------------------------------
# winner persistence + hardware invalidation
# ---------------------------------------------------------------------------

def _searching_tuner(path, hardware="hw-a"):
    return KernelTuner(CalibrationCache(path), repeats=1, hardware=hardware)


def test_winner_roundtrip_and_hw_invalidation(tmp_path):
    path = os.path.join(tmp_path, "cal.json")
    calls = []

    def run(block):
        calls.append(block)

    t1 = _searching_tuner(path)
    p1 = t1.plan_1d("k1", 5000, run, dtype="float32")
    assert t1.searches == 1 and calls  # measured every candidate
    assert p1.block % tuning.LANE == 0
    assert p1.padded >= 5000

    # same tuner, same bucket: answered from memory, no new measurements
    n_calls = len(calls)
    p1b = t1.plan_1d("k1", 5000, run, dtype="float32")
    assert len(calls) == n_calls and t1.cache_hits == 1
    assert p1b.block == p1.block

    # fresh cache over the same file: winner round-trips from disk
    t2 = _searching_tuner(path)
    p2 = t2.plan_1d("k1", 5000, run, dtype="float32")
    assert t2.searches == 0 and t2.cache_hits == 1
    assert len(calls) == n_calls
    assert p2.block == p1.block

    # a different hardware key invalidates the stored winner (keys
    # separately: hw-b must not inherit blocks measured on hw-a)
    t3 = _searching_tuner(path, hardware="hw-b")
    t3.plan_1d("k1", 5000, run, dtype="float32")
    assert t3.searches == 1 and len(calls) > n_calls
    # ... its re-measured record now serves hw-b processes
    t4 = _searching_tuner(path, hardware="hw-b")
    n_calls = len(calls)
    t4.plan_1d("k1", 5000, run, dtype="float32")
    assert t4.searches == 0 and len(calls) == n_calls
    # ... and hw-a's winner coexists (machines sharing one store must
    # not alternately overwrite each other)
    t5 = _searching_tuner(path)
    t5.plan_1d("k1", 5000, run, dtype="float32")
    assert t5.searches == 0 and len(calls) == n_calls


def test_distinct_keys_search_separately(tmp_path):
    path = os.path.join(tmp_path, "cal.json")
    t = _searching_tuner(path)

    def run(*_):
        pass

    t.plan_1d("k1", 5000, run, dtype="float32")
    t.plan_1d("k1", 5000, run, dtype="bfloat16")       # dtype in key
    t.plan_1d("k2", 5000, run, dtype="float32")        # kernel in key
    t.plan_1d("k1", 50000, run, dtype="float32")       # bucket in key
    t.plan_1d("k1", 4097, run, dtype="float32")        # same bucket as 5000
    assert t.searches == 4 and t.cache_hits == 1


def test_attention_winner_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "cal.json")
    t1 = _searching_tuner(path)
    bq, bk = t1.plan_attention("fa", 64, 128, 32, lambda q, k: None)
    assert t1.searches == 1
    assert bq % tuning.SUBLANE == 0 and bk % tuning.LANE == 0
    t2 = _searching_tuner(path)
    assert t2.plan_attention("fa", 64, 128, 32, lambda q, k: None) == (bq, bk)
    assert t2.searches == 0 and t2.cache_hits == 1


def test_attention_cached_winner_capped_to_sequence(tmp_path):
    """A winner stored by a bucket-mate with longer sequences must be
    capped to the current call's padded lengths on reuse."""
    t = _searching_tuner(os.path.join(tmp_path, "cal.json"))
    run = lambda q, k: None  # noqa: E731
    t.plan_attention("fa", 1024, 1024, 32, run)      # bucket 1024
    bq, bk = t.plan_attention("fa", 513, 513, 32, run)  # same bucket
    assert t.searches == 1 and t.cache_hits == 1
    assert bq <= 520 and bk <= 640  # round_up(513, 8) / round_up(513, 128)


def test_attention_variant_keys_separately(tmp_path):
    """A winner measured under one masking config (causal/window) must
    not be reused for another — the work per tile differs."""
    t = _searching_tuner(os.path.join(tmp_path, "cal.json"))
    run = lambda q, k: None  # noqa: E731
    t.plan_attention("fa", 64, 128, 32, run, variant=(True, None))
    t.plan_attention("fa", 64, 128, 32, run, variant=(False, None))
    t.plan_attention("fa", 64, 128, 32, run, variant=(True, 64))
    t.plan_attention("fa", 64, 128, 32, run, variant=(True, None))
    assert t.searches == 3 and t.cache_hits == 1


def test_attention_key_matches_v2_on_disk_order(tmp_path):
    """Winners persisted by the pre-unification (schema v2) release used
    (ns, kernel, bsq, bskv, d, dtype, variant, hw) tuples; the engine's
    DecisionKey must keep that exact identity or every stored
    flash-attention winner would silently re-measure."""
    t = _searching_tuner(os.path.join(tmp_path, "cal.json"))
    legacy_key = ("pallas_block", "fa", 64, 128, 32, "bfloat16",
                  repr(()), t.hardware)
    t.cache.set_tuned(legacy_key, {"block_q": 16, "block_kv": 128,
                                   "hw": t.hardware})
    bq, bk = t.plan_attention("fa", 64, 128, 32,
                              lambda q, k: pytest.fail("must not measure"))
    assert (bq, bk) == (16, 128)
    assert t.searches == 0 and t.cache_hits == 1


def test_illegal_persisted_block_triggers_remeasure(tmp_path):
    """A record with a non-positive block (torn write, buggy peer) must
    fall through to re-measurement, not crash plan math."""
    path = os.path.join(tmp_path, "cal.json")
    t = _searching_tuner(path)
    key = ("pallas_block", "k1", 8192, "float32", t.hardware)
    t.cache.set_tuned(key, {"block": 0, "hw": t.hardware})
    p = t.plan_1d("k1", 5000, lambda b: None, dtype="float32")
    assert t.searches == 1 and p.block > 0


def test_plan_argument_on_pallas_entry_points():
    """The externally-chosen-blocks entry points the autotuner feeds."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.reduce_scan import (inclusive_scan_pallas,
                                           reduce_sum_pallas)
    from repro.kernels.rmsnorm import rmsnorm_pallas

    plan = tuning.BlockPlan(block=128, grid=2, padded=256)
    x = jnp.asarray(RS.randn(256).astype(np.float32))
    np.testing.assert_allclose(float(reduce_sum_pallas(x, plan=plan)),
                               float(R.reduce_sum_ref(x)), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(inclusive_scan_pallas(x, plan=plan)),
                               np.asarray(R.inclusive_scan_ref(x)),
                               rtol=1e-4, atol=1e-3)
    xr = jnp.asarray(RS.randn(16, 128).astype(np.float32))
    g = jnp.ones((128,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm_pallas(xr, g, plan=tuning.BlockPlan(8, 2, 16))),
        np.asarray(R.rmsnorm_ref(xr, g)), rtol=1e-5, atol=1e-5)
    q = jnp.asarray(RS.randn(1, 2, 32, 32).astype(np.float32))
    k = jnp.asarray(RS.randn(1, 2, 128, 32).astype(np.float32))
    v = jnp.asarray(RS.randn(1, 2, 128, 32).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(flash_attention_pallas(q, k, v, causal=False,
                                          plan=(16, 128))),
        np.asarray(R.attention_ref(q, k, v, causal=False)),
        rtol=2e-4, atol=2e-4)


def test_schema_v1_files_still_load(tmp_path):
    """Schema bumps (v2 tuned table, v3 unified entries) must not
    discard a user's existing v1 t0/t_iter calibrations — old files
    load, and the first save migrates them to the current version."""
    import json

    from repro.core.calibration import SCHEMA_VERSION

    path = os.path.join(tmp_path, "cal.json")
    with open(path, "w") as f:
        json.dump({"version": 1, "t0": {"'a'": 1e-5},
                   "t_iter": {"'b'": 2e-6}}, f)
    c = CalibrationCache(path)
    assert c.peek_t_iter("b") == pytest.approx(2e-6)
    assert len(c) == 2
    c.set_tuned(("k",), {"block": 128})   # autosaves as current schema
    with open(path) as f:
        assert json.load(f)["version"] == SCHEMA_VERSION


def test_schema_roundtrip_through_save_load(tmp_path):
    path = os.path.join(tmp_path, "cal.json")
    c = CalibrationCache(path)
    c.set_tuned(("pallas_block", "k", 1024, "float32"),
                {"block": 256, "hw": "hw-a", "seconds": 1e-3})
    c.t_iter("w", lambda: 2e-6)   # scalar stores coexist with records
    c2 = CalibrationCache(path)
    rec = c2.tuned(("pallas_block", "k", 1024, "float32"))
    assert rec is not None and rec["block"] == 256 and rec["hw"] == "hw-a"
    assert c2.peek_t_iter("w") == pytest.approx(2e-6)
    assert len(c2) == 2


# ---------------------------------------------------------------------------
# tuned kernels stay correct (winner plans produce oracle outputs)
# ---------------------------------------------------------------------------

def test_tuned_ops_match_oracles(tmp_path):
    tuner = KernelTuner(CalibrationCache(os.path.join(tmp_path, "c.json")),
                        repeats=1)
    x = jnp.asarray(RS.randn(3000).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(K.adjacent_difference(x, tuner=tuner)),
        np.asarray(R.adjacent_difference_ref(x)), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        float(K.reduce_sum(x, tuner=tuner)), float(R.reduce_sum_ref(x)),
        rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(K.inclusive_scan(x, tuner=tuner)),
        np.asarray(R.inclusive_scan_ref(x)), rtol=1e-4, atol=1e-3)

    xr = jnp.asarray(RS.randn(100, 256).astype(np.float32))
    g = jnp.asarray(RS.randn(256).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(K.rmsnorm(xr, g, tuner=tuner)),
        np.asarray(R.rmsnorm_ref(xr, g)), rtol=1e-5, atol=1e-5)

    q = jnp.asarray(RS.randn(1, 2, 40, 32).astype(np.float32))
    k = jnp.asarray(RS.randn(1, 2, 100, 32).astype(np.float32))
    v = jnp.asarray(RS.randn(1, 2, 100, 32).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(K.flash_attention(q, k, v, causal=True, tuner=tuner)),
        np.asarray(R.attention_ref(q, k, v, causal=True)),
        rtol=2e-4, atol=2e-4)
    assert tuner.searches == 5


def test_measurement_is_eager_mid_trace(tmp_path):
    """Consumers resolve plans while tracing inside an outer jit (the
    scheduler's compiled steps, the train step): the harness must make
    the probes concrete and eager there, or it would wall-clock trace
    staging instead of kernel execution."""
    t = _searching_tuner(os.path.join(tmp_path, "cal.json"))
    concrete = []

    def run(block):
        concrete.append(not isinstance(jnp.zeros((block,)),
                                       jax.core.Tracer))

    def traced(y):
        t.plan_1d("probe", 1000, run, dtype="float32")
        return y * 2

    jax.jit(traced)(jnp.ones(3))
    assert t.searches == 1
    assert concrete and all(concrete)


def test_rmsnorm_pallas_grad_matches_reference():
    """The custom VJP (Pallas forward, closed-form backward) that the
    --kernel-autotune train path relies on."""
    from repro.kernels import ops as kops

    x = jnp.asarray(RS.randn(50, 128).astype(np.float32))
    g = jnp.asarray(RS.randn(128).astype(np.float32))

    def ref(x, g, eps=1e-6):
        xf = x.astype(jnp.float32)
        r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * r) * g

    vp, (dxp, dgp) = jax.value_and_grad(
        lambda a, b: jnp.sum(kops.rmsnorm(a, b) ** 2), argnums=(0, 1))(x, g)
    vr, (dxr, dgr) = jax.value_and_grad(
        lambda a, b: jnp.sum(ref(a, b) ** 2), argnums=(0, 1))(x, g)
    assert float(vp) == pytest.approx(float(vr), rel=1e-5)
    np.testing.assert_allclose(np.asarray(dxp), np.asarray(dxr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dgp), np.asarray(dgr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# satellite: feedback skips observations with unknown element counts
# ---------------------------------------------------------------------------

def test_timed_chunk_fn_skips_unknown_size():
    fb = OnlineFeedback()
    seen = []
    fn = tag_workload(lambda c: seen.append(c), "wk")
    timed = fb.timed_chunk_fn(fn)

    class Sized:
        size = 64

    class Unsized:
        pass

    timed(Unsized())          # passes through, no observation
    assert fb.count("wk") == 0 and fb.t_iter("wk") is None
    timed(Sized())            # real size: observed and smoothed
    assert fb.count("wk") == 1
    assert fb.observations[-1].elems == 64
    assert fb.t_iter("wk") is not None
    assert len(seen) == 2     # both calls executed the wrapped fn


# ---------------------------------------------------------------------------
# satellite: jax-0.4.37 mesh compat wrapper
# ---------------------------------------------------------------------------

def test_make_mesh_compat_current_jax():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("data",))
    assert mesh.shape["data"] == len(jax.devices())


# ---------------------------------------------------------------------------
# scheduler opt-in: tuned serving produces the baseline tokens
# ---------------------------------------------------------------------------

def test_scheduler_kernel_tuner_same_tokens(tmp_path):
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve.scheduler import ServeScheduler

    cfg = get_config("qwen3-0.6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray(RS.randint(0, cfg.vocab_size, size=10), jnp.int32)

    def run(sched):
        sched.submit(prompt, max_new_tokens=3)
        return sched.run_until_idle()

    base = run(ServeScheduler(cfg, params, n_slots=1, max_len=16))
    tuner = KernelTuner(CalibrationCache(os.path.join(tmp_path, "c.json")),
                        repeats=1)
    tuned = run(ServeScheduler(cfg, params, n_slots=1, max_len=16,
                               kernel_tuner=tuner))
    assert list(base.values()) == list(tuned.values())
    assert tuner.searches > 0   # the tuned path actually engaged
