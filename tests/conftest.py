import os
import subprocess
import sys

import pytest

# Smoke tests and benches see the real (single) device; ONLY the dry-run
# forces 512. Keep any inherited flag out.
os.environ.pop("XLA_FLAGS", None)

# The whole suite runs under strict mode: donated cache pools poison on
# read-after-donation and the serve tick / train step disallow implicit
# device->host transfers (see src/repro/core/strict.py).  setdefault so
# REPRO_STRICT=0 can still switch it off for a local bisect.
os.environ.setdefault("REPRO_STRICT", "1")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 560) -> subprocess.CompletedProcess:
    """Run a python snippet in a subprocess with N fake CPU devices
    (multi-device paths can't run in-process: jax locks device count)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture
def subproc():
    return run_with_devices
