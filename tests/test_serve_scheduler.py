"""The adaptive serving runtime: scheduler, slot pool, online feedback,
calibration persistence, and prefill segmentation edge cases."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SequentialExecutor, adaptive
from repro.core.acc import AdaptiveCoreChunk, StaticCoreChunk
from repro.core.calibration import SCHEMA_VERSION, CalibrationCache
from repro.core.executor import Chunk, HostParallelExecutor
from repro.core.feedback import OnlineFeedback, tag_workload
from repro.core.future import when_all
from repro.data import make_batch
from repro.models import init_params
from repro.serve import (RequestState, ServeEngine, ServeScheduler,
                         prefill_segments)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_sched(cfg, params, *, n_slots=2, max_len=48, acc=None, clock=None):
    kwargs = {} if clock is None else {"clock": clock}
    return ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=max_len,
        executor=adaptive(SequentialExecutor(),
                          acc or AdaptiveCoreChunk()), **kwargs)


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------

def test_admission_fifo_and_deadline_order(setup):
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=2)
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size
    # Arrivals 2, 0, 1 (explicit timestamps); no deadlines -> FIFO by
    # arrival, not by submission call order.
    r_late = sched.submit(prompt, max_new_tokens=2, arrival=2.0)
    r_first = sched.submit(prompt, max_new_tokens=2, arrival=0.0)
    r_mid = sched.submit(prompt, max_new_tokens=2, arrival=1.0)
    rec = sched.tick()
    assert rec.admitted == (r_first, r_mid)   # two slots, earliest two
    assert r_late not in rec.admitted         # latest arrival queued
    sched.run_until_idle()

    # A tight deadline jumps the arrival queue (EDF).
    sched2 = make_sched(cfg, params, n_slots=1)
    r_a = sched2.submit(prompt, max_new_tokens=2, arrival=0.0)
    r_urgent = sched2.submit(prompt, max_new_tokens=2, arrival=5.0,
                             deadline=1.0)
    rec = sched2.tick()
    assert rec.admitted == (r_urgent,)
    assert sched2.requests[r_a].state is RequestState.WAITING


def test_slot_exhaustion_queues_then_admits(setup):
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=2)
    prompt = jnp.arange(6, dtype=jnp.int32) % cfg.vocab_size
    rids = [sched.submit(prompt, max_new_tokens=2) for _ in range(3)]
    rec0 = sched.tick()
    # Pool exhausted: first two admitted, third queued (never dropped).
    assert rec0.admitted == tuple(rids[:2])
    assert sched.requests[rids[2]].state is RequestState.WAITING
    outs = sched.run_until_idle()
    assert sorted(outs) == sorted(rids)
    assert all(len(outs[r]) == 2 for r in rids)
    # The straggler was admitted only after a slot freed up.
    admit_tick = {r: rec.tick for rec in sched.trace for r in rec.admitted}
    finish_tick = {r: rec.tick for rec in sched.trace for r in rec.finished}
    assert admit_tick[rids[2]] >= min(finish_tick[r] for r in rids[:2])


# ---------------------------------------------------------------------------
# interleave determinism + concurrent mixed-length requests
# ---------------------------------------------------------------------------

def test_interleave_deterministic_with_sequential_executor(setup):
    cfg, params = setup
    tokens = make_batch(cfg, 2, 14, kind="prefill", seed=5)["tokens"]

    def run():
        sched = make_sched(cfg, params, n_slots=2, clock=lambda: 0.0)
        sched.submit(tokens[0], max_new_tokens=4, arrival=0.0)
        sched.submit(tokens[1][:9], max_new_tokens=3, arrival=0.0)
        outs = sched.run_until_idle()
        return outs, sched.trace

    outs1, trace1 = run()
    outs2, trace2 = run()
    assert outs1 == outs2
    assert trace1 == trace2          # tick-for-tick identical schedule
    # and the schedule genuinely interleaves: some tick both prefills a
    # chunk and decodes a running request
    assert any(rec.prefill_ops and rec.decoded for rec in trace1)


def test_mixed_length_requests_share_pool_without_realloc(setup):
    """Acceptance: two requests of different prompt lengths complete
    concurrently through the slot pool with no cache reallocation."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 16, kind="prefill", seed=7)["tokens"]
    long_p, short_p = tokens[0], tokens[1][:5]

    sched = make_sched(cfg, params, n_slots=2)
    r_long = sched.submit(long_p, max_new_tokens=6)
    r_short = sched.submit(short_p, max_new_tokens=6)
    outs = sched.run_until_idle()
    assert len(outs[r_long]) == 6 and len(outs[r_short]) == 6
    # one lm.init_caches for the pool's whole lifetime
    assert sched.pool.allocations == 1
    assert sched.pool.free_slots() == 2
    # both requests were in flight simultaneously (same tick decoded both)
    assert any(set(rec.decoded) >= {r_long, r_short} for rec in sched.trace)

    # per-request correctness: each equals the single-request reference
    for rid, prompt in ((r_long, long_p), (r_short, short_p)):
        solo = make_sched(cfg, params, n_slots=1)
        r = solo.submit(prompt, max_new_tokens=6)
        assert solo.run_until_idle()[r] == outs[rid]


def test_scheduler_matches_legacy_batch_generate(setup):
    cfg, params = setup
    prompts = make_batch(cfg, 2, 12, kind="prefill", seed=3)["tokens"]
    legacy = ServeEngine(cfg, params, batch=2, max_len=40)
    ref = np.asarray(legacy._generate_legacy(prompts, 5))
    engine = ServeEngine(cfg, params, batch=2, max_len=40)
    out = np.asarray(engine.generate(prompts, 5))   # scheduler path
    np.testing.assert_array_equal(out, ref)


def test_static_policy_runs_and_chunks_small(setup):
    cfg, params = setup
    tokens = make_batch(cfg, 1, 16, kind="prefill", seed=1)["tokens"]
    sched = ServeScheduler(
        cfg, params, n_slots=1, max_len=32,
        executor=adaptive(SequentialExecutor(),
                          StaticCoreChunk(cores=1, chunks_per_core=8)))
    rid = sched.submit(tokens[0], max_new_tokens=2)
    outs = sched.run_until_idle()
    assert len(outs[rid]) == 2
    # static split: the 16-token prompt went in pieces, not one chunk
    assert len([op for rec in sched.trace for op in rec.prefill_ops]) > 1


# ---------------------------------------------------------------------------
# online feedback
# ---------------------------------------------------------------------------

def test_feedback_smoothing_converges_on_drifting_t_iter():
    cache = CalibrationCache()
    fb = OnlineFeedback(cache, alpha=0.25)
    key = ("serve_prefill", "drift-test")
    # calibrated world: 1 us/elem; drifted world: 5 us/elem
    fb.observe(key, 1000, 1000 * 1e-6)
    assert cache.peek_t_iter(key) == pytest.approx(1e-6)
    for _ in range(40):
        fb.observe(key, 1000, 1000 * 5e-6)
    assert cache.peek_t_iter(key) == pytest.approx(5e-6, rel=1e-3)
    # and a single outlier cannot yank the estimate away
    fb.observe(key, 1000, 1000 * 500e-6)
    assert cache.peek_t_iter(key) < 130e-6


def test_adaptive_executor_records_bulk_timings():
    acc = AdaptiveCoreChunk(t0_override=1e-6)
    ex = adaptive(SequentialExecutor(), acc)

    def work(chunk):
        return chunk.size

    tag_workload(work, ("wl", "bulk"))
    when_all(ex.bulk_async_execute(
        work, [Chunk(0, 64), Chunk(64, 64)])).result()
    assert acc.cache.peek_t_iter(("wl", "bulk")) is not None
    assert ex.feedback.count(("wl", "bulk")) == 2
    # ... and the observation feeds the next decision's t_iter
    from repro.core.cost_model import WorkloadProfile

    t = acc.measure_iteration(ex, WorkloadProfile(1.0, 1.0), 128,
                              key=("wl", "bulk"))
    assert t == acc.cache.peek_t_iter(("wl", "bulk"))


def test_adaptive_executor_times_tagged_continuations():
    acc = AdaptiveCoreChunk(t0_override=1e-6)
    ex = adaptive(SequentialExecutor(), acc)
    from repro.core import Future

    def cont(value):
        return value + 1

    tag_workload(cont, ("wl", "then"), elems=32)
    assert ex.then_execute(cont, Future.ready(1)).result() == 2
    assert acc.cache.peek_t_iter(("wl", "then")) is not None


def test_scheduler_decisions_track_observed_drift(setup):
    """After ticks ran, the decision t_iter is the smoothed observation,
    not the analytic roofline seed."""
    cfg, params = setup
    sched = make_sched(cfg, params, n_slots=1, max_len=32)
    sched.warmup()   # cold (compiling) calls are deliberately untimed
    rid = sched.submit(jnp.arange(10, dtype=jnp.int32), max_new_tokens=2)
    sched.run_until_idle()
    assert len(sched.results()[rid]) == 2
    observed = sched.acc.cache.peek_t_iter(sched.prefill_key)
    assert observed is not None and observed > 0
    t = sched.acc.measure_iteration(sched.executor, sched.prefill_profile,
                                    100, key=sched.prefill_key)
    assert t == observed


# ---------------------------------------------------------------------------
# calibration persistence
# ---------------------------------------------------------------------------

def test_calibration_cache_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "cal.json")
    c1 = CalibrationCache(path=path)
    c1.t0(("t0", "SequentialExecutor", 1), lambda: 3.5e-5)
    c1.smooth_t_iter(("serve_prefill", "qwen"), 2e-6)
    # autosaved on every update
    c2 = CalibrationCache(path=path)
    assert c2.t0(("t0", "SequentialExecutor", 1),
                 lambda: pytest.fail("must not re-measure")) == 3.5e-5
    assert c2.peek_t_iter(("serve_prefill", "qwen")) == pytest.approx(2e-6)

    blob = json.loads(open(path).read())
    assert blob["version"] == SCHEMA_VERSION

    # a stale schema version is ignored, not misread
    blob["version"] = SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(blob, f)
    c3 = CalibrationCache(path=path)
    assert len(c3) == 0


def test_calibration_t0_key_stable_across_instances(tmp_path):
    """The t0 key no longer bakes in id(executor): a persisted entry is
    reused by a fresh, identical executor in a new 'process'."""
    path = str(tmp_path / "cal.json")
    acc1 = AdaptiveCoreChunk(cache=CalibrationCache(path=path))
    t0_first = acc1.calibrate_t0(SequentialExecutor())
    acc2 = AdaptiveCoreChunk(cache=CalibrationCache(path=path))
    t0_second = acc2.calibrate_t0(SequentialExecutor())
    assert t0_second == t0_first     # loaded, not re-measured


# ---------------------------------------------------------------------------
# prefill segmentation edge cases
# ---------------------------------------------------------------------------

def test_prefill_segments_tile_exactly():
    for s, chunk, pos, window in [(17, 5, 0, None), (40, 24, 0, 16),
                                  (1, 100, 3, 4), (33, 7, 13, 8),
                                  (64, 64, 0, 16)]:
        segs = prefill_segments(s, chunk, pos=pos, window=window)
        assert sum(step for _, step in segs) == s
        assert [start for start, _ in segs] == \
            list(np.cumsum([0] + [st for _, st in segs[:-1]]))
        if window:
            p = pos
            for _, step in segs:
                assert step <= window - p % window
                p += step


def test_prefill_segments_window_zero_means_no_window():
    # window=0 must not divide-by-zero nor clamp (regression)
    assert prefill_segments(10, 4, window=0) == [(0, 4), (4, 4), (8, 2)]
    assert prefill_segments(10, 4, window=None) == [(0, 4), (4, 4), (8, 2)]


def test_prefill_segments_pos_on_window_boundary():
    # pos exactly on a boundary gets a full-window first step
    assert prefill_segments(8, 8, pos=16, window=8)[0] == (0, 8)
    # one short of the boundary gets a 1-token step first
    assert prefill_segments(8, 8, pos=15, window=8)[0] == (0, 1)


def test_prefill_segments_validation():
    with pytest.raises(ValueError):
        prefill_segments(-1, 4)
    assert prefill_segments(0, 4) == []
    assert prefill_segments(5, 0) == [(i, 1) for i in range(5)]  # floor 1


def test_engine_windowed_prefill_uses_shared_segments(setup):
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch=1, max_len=64)
    segs = eng._prefill_segments(40, 24)
    assert sum(st for _, st in segs) == 40
    assert all(st <= (eng.window or 40) for _, st in segs)


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

def test_slot_pool_free_list_and_double_release(setup):
    from repro.serve import SlotKVCachePool

    cfg, _ = setup
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    a = pool.acquire("a")
    b = pool.acquire("b")
    assert {a, b} == {0, 1} and pool.acquire("c") is None
    pool.release(a)
    assert pool.free_slots() == 1
    with pytest.raises(ValueError):
        pool.release(a)
    assert pool.acquire("d") == a
    assert pool.allocations == 1


def test_slot_pool_advance_overflow_is_typed(setup):
    """advance() past max_len must raise SlotOverflowError (a silent
    wraparound writes into other slots' cache rows mid-fused-dispatch)."""
    from repro.serve import SlotKVCachePool, SlotOverflowError

    cfg, _ = setup
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    slot = pool.acquire("a")
    assert pool.advance(slot, 16) == 16         # exactly full is fine
    with pytest.raises(SlotOverflowError) as exc:
        pool.advance(slot, 1)
    assert exc.value.slot == slot
    assert exc.value.pos == 17 and exc.value.max_len == 16
    assert isinstance(exc.value, ValueError)    # old callers still catch
    assert pool.positions[slot] == 16           # overshoot not applied
    with pytest.raises(ValueError):
        pool.advance(slot, -1)


def test_slot_pool_adopt_rejects_layout_mismatch(setup):
    """adopt() is a blind rebind after a donated step — a tree from a
    step with different geometry must be rejected, not adopted."""
    from repro.serve import CacheLayoutError, SlotKVCachePool

    cfg, _ = setup
    pool = SlotKVCachePool(cfg, n_slots=2, max_len=16)
    other = SlotKVCachePool(cfg, n_slots=4, max_len=16)   # wrong n_slots
    with pytest.raises(CacheLayoutError):
        pool.adopt(other.caches)
    short = SlotKVCachePool(cfg, n_slots=2, max_len=8)    # wrong max_len
    with pytest.raises(CacheLayoutError):
        pool.adopt(short.caches)
    pool.adopt(pool.caches)                               # matching: fine
    assert pool.allocations == 1


# ---------------------------------------------------------------------------
# fused decode loop (serve/decode_loop.py)
# ---------------------------------------------------------------------------

def _run_wave(sched, tokens, spec):
    """Submit (prompt_prefix_len, max_new) requests, drain, return outs."""
    rids = [sched.submit(tokens[i][:plen], max_new_tokens=n)
            for i, (plen, n) in enumerate(spec)]
    outs = sched.run_until_idle()
    return [outs[r] for r in rids]


@pytest.mark.parametrize("depth", [1, 2, 4, 8])
def test_fused_decode_token_identity(setup, depth):
    """Fused decode at any depth emits byte-identical output to the
    legacy per-tick path — including a request completing mid-loop
    (max_new smaller than the dispatch depth) and slot reuse after its
    early exit (3 requests through 2 slots)."""
    cfg, params = setup
    tokens = make_batch(cfg, 3, 14, kind="prefill", seed=11)["tokens"]
    spec = [(14, 9), (9, 3), (6, 7)]   # 3-token request exits mid-loop
    ref = _run_wave(make_sched(cfg, params, n_slots=2), tokens, spec)
    sched = ServeScheduler(
        cfg, params, n_slots=2, max_len=48,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=depth)
    got = _run_wave(sched, tokens, spec)
    assert got == ref
    # every dispatched token was drained, every budget exactly honoured
    assert all(r.pending_out == 0 and r.finished_at is not None
               for r in sched.requests.values())
    assert sched.decode_dispatches < sum(n for _, n in spec)


def test_fused_auto_depth_identity_and_trace(setup):
    """dispatch_depth='auto': identical tokens, serve_dispatch_depth
    decisions in the engine trace, and online provenance once the loop
    has timed a real dispatch (warmup keeps the cold compile out)."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 12, kind="prefill", seed=13)["tokens"]
    spec = [(12, 8), (7, 8)]
    ref = _run_wave(make_sched(cfg, params, n_slots=2), tokens, spec)
    sched = ServeScheduler(
        cfg, params, n_slots=2, max_len=48,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth="auto")
    sched.warmup()
    assert _run_wave(sched, tokens, spec) == ref
    entries = sched.decision_model().trace.entries("serve_dispatch_depth")
    assert entries, "auto depth must be decided through the engine"
    assert all(e.decision.chunk >= 1 for e in entries)
    assert entries[-1].decision.provenance in ("measured", "online")
    # host round-trips stay sub-one-per-token on the fused path
    gen = sum(n for _, n in spec)
    assert sched.host_roundtrips < gen


def test_fused_donation_safety_across_waves(setup):
    """No use-after-donate on the slot pool: the same scheduler serves
    two waves (slot release + reacquire between fused dispatches), the
    pool is never reallocated, and outputs match the legacy path both
    times."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 10, kind="prefill", seed=17)["tokens"]
    legacy = make_sched(cfg, params, n_slots=2)
    sched = ServeScheduler(
        cfg, params, n_slots=2, max_len=32,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=4)
    sched.warmup()
    for _ in range(2):
        spec = [(10, 5), (6, 4)]
        assert _run_wave(sched, tokens, spec) == \
            _run_wave(legacy, tokens, spec)
        sched.clear_finished()
        legacy.clear_finished()
    # one lm.init_caches ever, donation notwithstanding
    assert sched.pool.allocations == 1
    assert sched.pool.free_slots() == 2


def test_fused_tickrecords_and_positions(setup):
    """Dispatch accounting is host-authoritative: positions advance by
    <= depth at dispatch time and the TickRecord carries the decided
    depth."""
    cfg, params = setup
    tokens = make_batch(cfg, 1, 8, kind="prefill", seed=19)["tokens"]
    sched = ServeScheduler(
        cfg, params, n_slots=1, max_len=32,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=3)
    rid = sched.submit(tokens[0], max_new_tokens=7)
    outs = sched.run_until_idle()
    assert len(outs[rid]) == 7
    dec_ticks = [rec for rec in sched.trace if rec.decoded]
    assert dec_ticks and all(rec.depth == 3 for rec in dec_ticks)
    # 6 decode tokens (first comes from prefill) at depth 3 -> 2 dispatches
    assert sched.decode_dispatches == 2
    assert sched.decode_tokens == 6


def test_scheduler_on_host_parallel_executor(setup):
    """Prefill chunks may run on pool threads; cache writes stay on the
    scheduler thread — results must match the sequential schedule."""
    cfg, params = setup
    tokens = make_batch(cfg, 2, 10, kind="prefill", seed=9)["tokens"]
    ref_sched = make_sched(cfg, params, n_slots=2, max_len=32)
    r0 = ref_sched.submit(tokens[0], max_new_tokens=3)
    r1 = ref_sched.submit(tokens[1], max_new_tokens=3)
    ref = ref_sched.run_until_idle()
    with HostParallelExecutor(max_workers=2) as ex:
        sched = ServeScheduler(cfg, params, n_slots=2, max_len=32,
                               executor=adaptive(ex))
        s0 = sched.submit(tokens[0], max_new_tokens=3)
        s1 = sched.submit(tokens[1], max_new_tokens=3)
        outs = sched.run_until_idle()
    assert outs[s0] == ref[r0] and outs[s1] == ref[r1]
