"""Unit tests for the paper's Section 3 equations."""
import math

import pytest

from repro.core import overhead_law as ol


def test_predicted_time_eq1():
    # T_N = T1/N + T0 for N > 1; sequential pays no overhead
    assert ol.predicted_time(1.0, 4, 0.1) == pytest.approx(0.35)
    assert ol.predicted_time(1.0, 1, 0.1) == 1.0


def test_speedup_eq3():
    # S = T1 / (T1/N + T0)
    assert ol.speedup(1.0, 10, 0.0) == pytest.approx(10.0)
    assert ol.speedup(1.0, 10, 0.1) == pytest.approx(1.0 / 0.2)


def test_overhead_law_differs_from_amdahl():
    # Amdahl with serial fraction s: S -> 1/s as N -> inf (finite).
    # Overhead law: S -> T1/T0 as N -> inf — also finite but the paper's
    # point is the *constant* overhead, paid only when parallel.
    t1, t0 = 1.0, 0.01
    s_inf = ol.speedup(t1, 10**9, t0)
    assert s_inf == pytest.approx(t1 / t0, rel=1e-3)


def test_parallel_fraction_eq4():
    assert ol.parallel_fraction(19.0, 1.0) == pytest.approx(0.95)


def test_t_opt_is_19_t0_at_95():
    # the paper's headline constant
    assert ol.t_opt(1e-5, 0.95) == pytest.approx(19e-5)


def test_eq7_matches_eq8():
    # N = (1-E)/E * T1/T0  ==  T1 / T_opt
    t1, t0, eff = 0.123, 4.2e-6, 0.95
    assert ol.optimal_cores(t1, t0, eff) == pytest.approx(
        t1 / ol.t_opt(t0, eff))


def test_efficiency_at_optimal_cores():
    # running at exactly N from Eq. 7 yields exactly the target efficiency
    t1, t0, eff = 1.0, 1e-4, 0.95
    n = ol.optimal_cores(t1, t0, eff)
    assert ol.efficiency(t1, n, t0) == pytest.approx(eff, rel=1e-6)


def test_chunk_size_eq10():
    assert ol.chunk_size(1_000_000, 40, 8) == math.ceil(1_000_000 / 320)
    assert ol.chunk_size(10, 40, 8) == 1


def test_decide_small_workload_sequential():
    d = ol.decide(t_iter=1e-9, n_elements=100, t0=1e-5, max_cores=40)
    assert d.n_cores == 1 and not d.parallel
    assert d.chunk_elems == 100


def test_decide_large_workload_all_cores():
    d = ol.decide(t_iter=1e-8, n_elements=10_000_000, t0=1e-5, max_cores=40)
    assert d.n_cores == 40
    assert d.n_chunks >= 8 * 40 * 0.9  # ~C chunks per core
    assert d.predicted_efficiency > 0.95


def test_decide_clamps_to_max_cores():
    d = ol.decide(t_iter=1.0, n_elements=10**6, t0=1e-6, max_cores=8)
    assert d.n_cores == 8
    assert d.n_cores_unclamped > 8


def test_decide_chunk_floor_t_m():
    # chunks must carry at least T_m = T_opt / C of work
    t0, eff, c = 1e-4, 0.95, 8
    d = ol.decide(t_iter=1e-7, n_elements=10**6, t0=t0, max_cores=1000,
                  eff=eff, chunks_per_core=c)
    t_m = ol.t_opt(t0, eff) / c
    if d.n_chunks > 1:
        assert d.chunk_elems * d.t_iter >= t_m * 0.999


def test_decide_validates_inputs():
    with pytest.raises(ValueError):
        ol.decide(t_iter=1e-9, n_elements=0, t0=1e-5, max_cores=4)
    with pytest.raises(ValueError):
        ol.t_opt(1e-5, 1.5)
