"""Self-speculative fused decoding: byte-identity against the
non-speculative fused path at every depth (contiguous, paged, auto,
and — via ``test_multidevice``-style subprocesses — the 4x2 mesh), KV
rollback invariants under random accept/reject interleavings, the
attention-only architecture guard, and the ``serve_spec_depth``
decision kind (analytic prior → online acceptance EMA, collapse
backoff, one-rung hysteresis).

Plain tests run everywhere; the hypothesis sweep over rollback
interleavings skips when the library is missing — same convention as
tests/test_serve_paged.py."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SequentialExecutor, adaptive
from repro.core.acc import AdaptiveCoreChunk
from repro.core.calibration import CalibrationCache
from repro.core.model import ANALYTIC, ONLINE, ExecutionModel
from repro.models import init_params
from repro.serve import ServeScheduler
from repro.serve.decode_loop import make_spec_decode_step

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_sched(cfg, params, *, speculate=None, paged=False, depth=4,
               n_slots=3, max_len=64, **kw):
    if paged:
        kw.setdefault("page_size", 8)
    return ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=max_len,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=depth, paged=paged, speculate=speculate, **kw)


def _mixed_prompts(cfg, seed=0):
    """Prompts spanning the acceptance spectrum: a repeated motif (the
    prompt-lookup drafter's best case), pure noise (its worst), and a
    short motif tail — so every identity run exercises full accepts,
    full rejects, and partial-prefix accepts in one pool."""
    rng = np.random.RandomState(seed)
    motif = [7, 3, 11, 5]
    return [(motif * 5)[:14],
            [int(t) for t in rng.randint(0, cfg.vocab_size, 9)],
            (motif * 3)[:6]]


def run_spec(sched, prompts, budgets):
    sched.warmup()
    rids = [sched.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets, strict=True)]
    outs = sched.run_until_idle()
    return [outs[r] for r in rids]


# ---------------------------------------------------------------------------
# byte identity: speculative vs non-speculative fused decode
# ---------------------------------------------------------------------------

# Ragged budgets force mid-loop completion: lanes exhaust their budget
# at different rounds, and a verify that overshoots a lane's remaining
# budget must clamp its emit rather than leak extra tokens.
BUDGETS = (9, 3, 7)


@pytest.mark.parametrize("d", [2, 4, 8])
def test_spec_tokens_identical_to_nonspec(setup, d):
    cfg, params = setup
    prompts = _mixed_prompts(cfg)
    ref = run_spec(make_sched(cfg, params, speculate=None),
                   prompts, BUDGETS)
    sched = make_sched(cfg, params, speculate=d)
    got = run_spec(sched, prompts, BUDGETS)
    assert got == ref, f"depth {d} moved a token"
    assert sched.pool.allocations == 1, "donation invariant broke"
    stats = sched.spec_stats()
    assert stats["enabled"] and stats["depth"] == d
    assert stats["verifies"] > 0
    # Prefill emits each request's first token; every later token rides
    # a speculative verify round.
    assert stats["emitted"] == sum(BUDGETS) - len(BUDGETS)


def test_spec_auto_tokens_identical(setup):
    """`speculate='auto'` may change the *width* mid-run (that is its
    job) but never the tokens."""
    cfg, params = setup
    prompts = _mixed_prompts(cfg)
    ref = run_spec(make_sched(cfg, params, speculate=None),
                   prompts, BUDGETS)
    sched = make_sched(cfg, params, speculate="auto")
    got = run_spec(sched, prompts, BUDGETS)
    assert got == ref
    assert sched.decision_model().trace.entries("serve_spec_depth")


def test_paged_spec_tokens_identical(setup):
    """Speculation over the paged pool: page-table indirection plus the
    draft/verify/rollback loop vs the contiguous non-speculative
    reference, including prefix reuse — a shared prefix page must be
    CoW'd out before the speculative window can scribble on it."""
    cfg, params = setup
    prompts = _mixed_prompts(cfg)
    ref = run_spec(make_sched(cfg, params, speculate=None),
                   prompts, BUDGETS)
    sched = make_sched(cfg, params, speculate=4, paged=True)
    got = run_spec(sched, prompts, BUDGETS)
    assert got == ref
    assert sched.pool.allocations == 1

    # Resubmit the motif prompt: the second pass maps the registered
    # prefix pages read-only, then speculative decode writes past (and
    # eventually into) them — tokens must not move and the shared page
    # must survive with its refcount intact.
    sched.clear_finished()
    rid = sched.submit(prompts[0], max_new_tokens=BUDGETS[0])
    outs = sched.run_until_idle()
    assert outs[rid] == ref[0]
    assert sched.pool.prefix_stats()["prefix_hits"] >= 1
    pool = sched.pool
    for slot in range(pool.n_slots):
        for pid in pool.page_tables[slot]:
            assert pool.page_refs[pid] >= 1


MESH_SPEC_SERVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.configs import get_config
from repro.core.acc import AdaptiveCoreChunk
from repro.core.adaptive import adaptive
from repro.core.executor import SequentialExecutor
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.serve import ServeScheduler

# Speculative decode on the 4x2 serving mesh must produce byte-identical
# tokens to the single-device non-speculative fused path: the wider
# verify, history-ring shift and masked rollback are replica-local and
# may not move a single argmax.
cfg = get_config("qwen3-0.6b").reduced()
params = lm.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(0)
motif = [7, 3, 11, 5]
prompts = [(motif * 5)[:14],
           [int(t) for t in rng.randint(0, cfg.vocab_size, 9)],
           (motif * 3)[:6]]
budgets = (9, 3, 7)

def run(speculate, mesh=None, n_slots=3):
    sched = ServeScheduler(
        cfg, params, n_slots=n_slots, max_len=64,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=4, mesh=mesh, speculate=speculate)
    sched.warmup()
    rids = [sched.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets, strict=True)]
    outs = sched.run_until_idle()
    assert sched.pool.allocations == 1, "donation invariant broke"
    return [outs[r] for r in rids], sched

ref, _ = run(None)
mesh = make_serve_mesh(4, 2)
for d in (2, 4):
    got, sched = run(d, mesh=mesh, n_slots=4)
    assert got == ref, (d, got, ref)
    assert sched.spec_stats()["verifies"] > 0
got, sched = run("auto", mesh=mesh, n_slots=4)
assert got == ref, ("auto", got, ref)
assert sched.decision_model().trace.entries("serve_spec_depth")
print("MESH_SPEC_SERVE_OK")
"""


def test_mesh_spec_serve(subproc):
    r = subproc(MESH_SPEC_SERVE, n_devices=8)
    assert r.returncode == 0, \
        f"mesh spec serve failed:\n{r.stdout}\n{r.stderr[-3000:]}"
    assert "MESH_SPEC_SERVE_OK" in r.stdout


def test_spec_requires_attention_only(setup):
    cfg, _ = setup
    with pytest.raises(ValueError, match="full attention"):
        make_spec_decode_step(cfg, depth=2, window=8)
    rec = get_config("xlstm-350m")
    with pytest.raises(ValueError, match="attention-only"):
        make_spec_decode_step(rec, depth=2)


# ---------------------------------------------------------------------------
# KV rollback invariants under random accept/reject interleavings
# ---------------------------------------------------------------------------

def _random_prompts(cfg, seed):
    """Random mixtures of motif repeats and noise per lane: the bigram
    drafter then produces arbitrary interleavings of full accepts,
    partial accepts and rejects across lanes and rounds."""
    rng = np.random.RandomState(seed)
    prompts = []
    for _ in range(3):
        toks = []
        motif = [int(t) for t in rng.randint(0, cfg.vocab_size,
                                             rng.randint(2, 5))]
        while len(toks) < 6 + rng.randint(0, 10):
            if rng.rand() < 0.6:
                toks.extend(motif)
            else:
                toks.extend(int(t) for t in
                            rng.randint(0, cfg.vocab_size, 2))
        prompts.append(toks[:15])
    return prompts


def _rollback_case(cfg, params, seed, depth):
    """Run speculative and non-speculative pools tick-aligned and stop
    mid-decode: emitted tokens AND the live KV region ``[:pos]`` of
    every slot must be byte-identical — i.e. a rejected draft's cache
    write never survives anywhere the causal mask can read.  (Stale
    entries at ``>= pos`` are exactly the rollback slack the next
    verify window overwrites; they are not compared.)"""
    prompts = _random_prompts(cfg, seed)

    def run(spec):
        sched = make_sched(cfg, params, speculate=spec)
        sched.warmup()
        for p in prompts:
            sched.submit(p, max_new_tokens=40)
        for _ in range(8):
            sched.tick()
        return sched

    ref, got = run(None), run(depth)
    assert got.pool.positions == ref.pool.positions, seed
    for li, (rc, sc) in enumerate(zip(ref.pool.caches, got.pool.caches, strict=True)):
        if rc is None:
            continue
        for key in ("k", "v"):
            r, s = np.asarray(rc[key]), np.asarray(sc[key])
            for slot in range(r.shape[0]):
                p = ref.pool.positions[slot]
                assert np.array_equal(r[slot][:, :p], s[slot][:, :p]), \
                    (seed, li, key, slot)


def test_kv_rollback_invariants(setup):
    cfg, params = setup
    for seed, depth in ((0, 4), (13, 8), (91, 2)):
        _rollback_case(cfg, params, seed, depth)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1), depth=st.sampled_from([2, 4]))
    @settings(max_examples=6, deadline=None)
    def test_kv_rollback_invariants_property(setup, seed, depth):
        cfg, params = setup
        _rollback_case(cfg, params, seed, depth)


# ---------------------------------------------------------------------------
# the serve_spec_depth decision kind
# ---------------------------------------------------------------------------

def test_spec_depth_analytic_prior():
    """At the seed acceptance (0.5) and default width cost (0.25) the
    Overhead-Law score E(d,a)/cost(d) peaks at d=2 — speculation turns
    on, conservatively, before any evidence exists."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    dec = m.spec_depth("k", candidates=(1, 2, 4, 8), accept_rate=0.5)
    assert dec.chunk == 2
    assert dec.provenance == ANALYTIC
    inputs = dict(dec.inputs)
    assert inputs["backoff"] is False
    scores = dict(inputs["scores"])
    assert scores[2] > scores[1] and scores[2] > scores[4]


def test_spec_depth_collapse_backoff():
    """Acceptance under ``min_accept`` forces depth 1 outright — no
    hysteresis ladder on the way down, drafting noise must stop taxing
    the steady state immediately."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    dec = m.spec_depth("k", candidates=(1, 2, 4, 8), accept_rate=0.01,
                       current=8)
    assert dec.chunk == 1
    assert dict(dec.inputs)["backoff"] is True


def test_spec_depth_one_rung_hysteresis():
    """Acceptance measured at depth 2 is censored at one accepted draft:
    a saturated reading (a≈1) must widen one candidate rung, not vault
    to the argmax."""
    m = ExecutionModel(CalibrationCache(), hardware="test")
    up = m.spec_depth("k", candidates=(1, 2, 4, 8), accept_rate=0.94,
                      current=2)
    assert up.chunk == 4
    assert dict(up.inputs)["unclamped"] == 8
    down = m.spec_depth("k", candidates=(1, 2, 4, 8), accept_rate=0.3,
                        current=8)
    assert down.chunk == 4          # argmax is 2; one rung down from 8
    assert dict(down.inputs)["unclamped"] == 2
    stay = m.spec_depth("k", candidates=(1, 2, 4, 8), accept_rate=0.5,
                        current=2)
    assert stay.chunk == 2
    assert "unclamped" not in dict(stay.inputs)


def test_spec_depth_provenance_analytic_to_online(setup):
    """Under ``speculate='auto'`` the first decision rides the analytic
    prior; once drains feed the ``serve_spec_accept`` EMA the decisions
    must report online provenance — and on a motif-heavy workload the
    observed acceptance must be visibly non-zero."""
    cfg, params = setup
    sched = make_sched(cfg, params, speculate="auto", n_slots=2)
    sched.warmup()
    motif = [7, 3, 11, 5] * 4
    ticks = []
    for _ in range(4):
        for _ in range(2):
            sched.submit(motif[:12], max_new_tokens=12)
        sched.run_until_idle()
        ticks.extend(sched.trace)       # clear_finished drops the trace
        sched.clear_finished()
    entries = sched.decision_model().trace.entries("serve_spec_depth")
    assert entries, "auto mode traced no serve_spec_depth decisions"
    prov = [e.decision.provenance for e in entries]
    assert prov[0] == ANALYTIC
    assert ONLINE in prov, prov
    stats = sched.spec_stats()
    assert stats["acceptance_rate"] > 0.0
    assert stats["tokens_per_verify"] >= 1.0
    # Variable accepted-token accounting: the tick records carry the
    # actual dispatched token totals, not lanes × depth.
    spec_ticks = [r for r in ticks if r.spec_depth >= 2]
    assert spec_ticks, "no tick ever dispatched speculatively"
    assert sum(r.dispatched_tokens for r in spec_ticks) \
        == stats["emitted"]


def test_spec_depth_online_backoff_and_climb(setup):
    """Drive the drain-time EMA directly: collapsed acceptance must
    park the next decision at depth 1, and recovered acceptance must
    climb back one rung at a time (1 → 2, never 1 → 8)."""
    cfg, params = setup
    sched = make_sched(cfg, params, speculate="auto", n_slots=2)
    sched.warmup()
    model = sched.decision_model()
    for _ in range(30):
        model.observe(sched.spec_accept_key, 10, 0.01 * 10)
    assert sched._decide_spec_depth() == 1
    sched._spec_depth = 1
    for _ in range(200):
        model.observe(sched.spec_accept_key, 10, 0.9 * 10)
    assert sched._decide_spec_depth() == 2
    sched._spec_depth = 2
    assert sched._decide_spec_depth() == 4
