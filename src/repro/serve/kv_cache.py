"""Slot-based KV/SSM cache pool for continuous batching.

The old engine held one monolithic cache per *batch* — every request in a
batch had to start and stop together, and a new batch meant a fresh
``lm.init_caches`` allocation.  The pool instead allocates the per-layer
caches **once**, with the leading batch dimension reinterpreted as
``n_slots`` fixed-size slots.  A request acquires a slot from the
free-list on admission, carries its own position inside the slot, and
releases the slot when it finishes — so requests of different lengths
join and leave the running batch with no cache reallocation (asserted by
``allocations``, which counts device-buffer allocations and must stay at
1 for the pool's lifetime).

Layer cache layout (from ``lm.init_caches``):
  * attention:      {"k","v"} of shape (n_slots, H_kv, S, D) — full or
    ring-buffer (SWA) along S;
  * mamba2 / xLSTM: recurrent state arrays with leading dim n_slots;
  * cross_attn:     None (KV recomputed from frontend feats — the
    scheduler does not serve cross-attention requests).

Rows are functionally updated (``.at[slot].set``); XLA reuses the
buffers, and the pool arrays never change shape — the property that lets
one compiled decode step serve every mix of active requests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import strict
from ..models import lm


def _tree_map(fn, tree):
    """tree_map that keeps ``None`` layer entries (cross_attn) in place."""
    return jax.tree.map(fn, tree, is_leaf=lambda x: x is None)


def _maybe(fn):
    return lambda x: None if x is None else fn(x)


class SlotOverflowError(ValueError):
    """A lane's position would pass its slot's ``max_len`` storage —
    cache writes past that point land in another slot's rows (silent
    wraparound through the ring/dynamic-slice indexing).  The scheduler
    budgets every fused dispatch against the slot's remaining room, so
    raising here means that accounting broke; typed so the serve loop
    can turn it into a structured failure instead of corrupt output."""

    def __init__(self, slot: int, pos: int, max_len: int):
        self.slot = slot
        self.pos = pos
        self.max_len = max_len
        super().__init__(
            f"slot {slot} advanced past max_len: {pos} > {max_len}")


class CacheLayoutError(ValueError):
    """An adopted cache tree does not match the pool's layout.  The
    fused decode step *donates* the pool, so adopt() is a blind rebind —
    a step built for different geometry (other slot count, other arch,
    other dtype) would silently become the pool and corrupt every later
    slot read.  Checked structurally (shapes/dtypes, no device sync)."""


def _layout(tree) -> tuple:
    """Hashable (shape, dtype) signature of a cache tree — what adopt()
    compares; flattening a few dozen array stubs is host microseconds."""
    return tuple((tuple(x.shape), str(x.dtype))
                 for x in jax.tree.leaves(tree))


class SlotKVCachePool:
    """Fixed-size cache slots with a free-list and per-slot positions."""

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 window: int | None = None, dtype=None, mesh=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self._donated_to: str | None = None
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.window = window if window is not None else cfg.attn_window
        self.caches = lm.init_caches(cfg, n_slots, max_len,
                                     window=self.window, dtype=dtype)
        # Mesh-sharded placement: the slot (batch) dim splits into
        # data-parallel groups, KV heads over 'model' (the same
        # launch/sharding cache_specs rules the dry-run path uses).  The
        # device_put happens once, before serving — placement of the one
        # allocation, not a reallocation.
        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from ..launch import sharding as sharding_lib

            self.shardings = sharding_lib.to_shardings(
                mesh, sharding_lib.cache_specs(cfg, mesh, n_slots, max_len))
            self.caches = jax.device_put(self.caches, self.shardings)
        self._layout_sig = _layout(self.caches)
        self.allocations = 1            # init_caches calls ever made
        self._free = list(range(n_slots - 1, -1, -1))
        self.positions = [0] * n_slots  # tokens cached per slot (host side)
        self.owner: list[Any] = [None] * n_slots
        self._write_jit = None

    # -- donation poison (strict mode) ---------------------------------------
    @property
    def caches(self):
        """The per-layer cache pytree.  Under strict mode
        (``core.strict``), reading this between a donating dispatch
        (``mark_donated``) and the matching ``adopt()`` raises
        ``DonatedCacheError``: the arrays' device buffers are already
        aliased into the dispatch's outputs."""
        if self._donated_to is not None and strict.enabled():
            raise strict.DonatedCacheError(self._donated_to)
        return self._caches

    @caches.setter
    def caches(self, tree) -> None:
        self._caches = tree
        self._donated_to = None

    def mark_donated(self, consumer: str) -> None:
        """Poison ``caches`` until the next rebind (``adopt()`` or a
        direct assignment).  The scheduler calls this immediately after
        handing the pool to a ``donate_argnums`` dispatch.  Costs one
        string store; the read-side check only fires under strict mode."""
        self._donated_to = consumer

    # -- free-list -----------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self, owner: Any = None) -> int | None:
        """Claim a slot for ``owner``; None when the pool is exhausted.
        The slot's recurrent state is zeroed (ring/full KV rows need no
        wipe — attention masks by position — but SSM states carry over)."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.owner[slot] = owner
        self.positions[slot] = 0
        self._zero_slot_states(slot)
        return slot

    def release(self, slot: int) -> None:
        if self.owner[slot] is None and slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        self.owner[slot] = None
        self.positions[slot] = 0
        self._free.append(slot)

    def _zero_slot_states(self, slot: int) -> None:
        """Zero the non-attention (recurrent) state rows of ``slot``."""

        def zero_row(kind: str, cache):
            if cache is None or kind in ("attn", "shared_attn"):
                return cache
            return _tree_map(_maybe(lambda x: x.at[slot].set(0)), cache)

        self.caches = [zero_row(kind, c) for kind, c in
                       zip(self.cfg.layer_kinds(), self.caches,
                           strict=True)]

    # -- slot I/O ------------------------------------------------------------
    def read_slot(self, slot: int):
        """The slot's caches as a batch-of-1 pytree (device views)."""
        return _tree_map(_maybe(lambda x: x[slot:slot + 1]), self.caches)

    def write_slot(self, slot: int, row_caches) -> None:
        """Write a batch-of-1 cache pytree back into ``slot``.

        Goes through one jitted update with the pool donated, so XLA
        aliases the output into the existing buffers — an eager
        ``.at[slot].set`` would copy every layer's full pool array per
        chunk.  ``slot`` rides in as a traced scalar (one compile total).
        """
        if self._write_jit is None:
            def write(caches, row, s):
                return jax.tree.map(
                    lambda c, n: c if c is None else
                    jax.lax.dynamic_update_slice(
                        c, n.astype(c.dtype),
                        (s,) + (0,) * (c.ndim - 1)),
                    caches, row, is_leaf=lambda x: x is None)

            # Explicit out_shardings on the mesh path: donation aliasing
            # requires output placement to equal the input's, and pinning
            # it stops GSPMD from ever resharding the pool mid-serve.
            self._write_jit = jax.jit(write, donate_argnums=0,
                                      out_shardings=self.shardings)
        self.caches = self._write_jit(self.caches, row_caches,
                                      jnp.int32(slot))

    def adopt(self, new_caches) -> None:
        """Rebind the pool to ``new_caches`` — the output of a step that
        **donated** the current pool (the fused decode loop,
        serve/decode_loop.py, like ``write_slot`` above).  The old
        arrays' buffers were aliased into the new ones by XLA; after
        this call the previous ``self.caches`` must never be touched
        again.  No allocation happens: ``allocations`` stays wherever
        it is (the invariant the donation tests pin at 1).

        Raises ``CacheLayoutError`` when the adopted tree's shapes or
        dtypes differ from the pool's — the step that produced it was
        built for different geometry, and rebinding would corrupt every
        later slot read."""
        if _layout(new_caches) != self._layout_sig:
            raise CacheLayoutError(
                f"adopted cache tree does not match the pool layout "
                f"(n_slots={self.n_slots}, max_len={self.max_len}, "
                f"arch={self.cfg.name})")
        self.caches = new_caches

    def advance(self, slot: int, n: int) -> int:
        """Advance ``slot``'s position by ``n`` cached tokens (the fused
        decode path moves a slot by up to ``k`` per dispatch).  The
        caller must have budgeted ``n`` against ``max_len``; raises
        ``SlotOverflowError`` on overshoot — cache writes past the
        slot's storage would wrap into other slots' rows."""
        if n < 0:
            raise ValueError(f"negative advance: {n}")
        pos = self.positions[slot] + n
        if pos > self.max_len:
            raise SlotOverflowError(slot, pos, self.max_len)
        self.positions[slot] = pos
        return pos

    def positions_array(self) -> jax.Array:
        """Per-slot positions as an (n_slots,) int32 device array (free
        slots report 0; their decode lanes are ignored)."""
        return jnp.asarray(
            [min(p, self.max_len - 1) for p in self.positions], jnp.int32)
