"""Slot-based KV/SSM cache pool for continuous batching.

The old engine held one monolithic cache per *batch* — every request in a
batch had to start and stop together, and a new batch meant a fresh
``lm.init_caches`` allocation.  The pool instead allocates the per-layer
caches **once**, with the leading batch dimension reinterpreted as
``n_slots`` fixed-size slots.  A request acquires a slot from the
free-list on admission, carries its own position inside the slot, and
releases the slot when it finishes — so requests of different lengths
join and leave the running batch with no cache reallocation (asserted by
``allocations``, which counts device-buffer allocations and must stay at
1 for the pool's lifetime).

Layer cache layout (from ``lm.init_caches``):
  * attention:      {"k","v"} of shape (n_slots, H_kv, S, D) — full or
    ring-buffer (SWA) along S;
  * mamba2 / xLSTM: recurrent state arrays with leading dim n_slots;
  * cross_attn:     None (KV recomputed from frontend feats — the
    scheduler does not serve cross-attention requests).

Rows are functionally updated (``.at[slot].set``); XLA reuses the
buffers, and the pool arrays never change shape — the property that lets
one compiled decode step serve every mix of active requests.

``PagedKVCachePool`` (below) is the paged alternative: attention KV
lives in fixed-size *pages* indexed through a per-slot ``int32`` page
table, so a request's HBM footprint grows with its sequence instead of
being ``max_len`` up front, and identical prompt prefixes share pages
copy-on-write through a token-keyed prefix cache.  Same donation /
poison / adopt discipline, same ``allocations`` invariant.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core import strict
from ..models import lm

_ATTN_KINDS = ("attn", "shared_attn")


def _tree_map(fn, tree):
    """tree_map that keeps ``None`` layer entries (cross_attn) in place."""
    return jax.tree.map(fn, tree, is_leaf=lambda x: x is None)


def _maybe(fn):
    return lambda x: None if x is None else fn(x)


class SlotOverflowError(ValueError):
    """A lane's position would pass its slot's ``max_len`` storage —
    cache writes past that point land in another slot's rows (silent
    wraparound through the ring/dynamic-slice indexing).  The scheduler
    budgets every fused dispatch against the slot's remaining room, so
    raising here means that accounting broke; typed so the serve loop
    can turn it into a structured failure instead of corrupt output."""

    def __init__(self, slot: int, pos: int, max_len: int):
        self.slot = slot
        self.pos = pos
        self.max_len = max_len
        super().__init__(
            f"slot {slot} advanced past max_len: {pos} > {max_len}")


class CacheLayoutError(ValueError):
    """An adopted cache tree does not match the pool's layout.  The
    fused decode step *donates* the pool, so adopt() is a blind rebind —
    a step built for different geometry (other slot count, other arch,
    other dtype) would silently become the pool and corrupt every later
    slot read.  Checked structurally (shapes/dtypes, no device sync)."""


def _layout(tree) -> tuple:
    """Hashable (shape, dtype) signature of a cache tree — what adopt()
    compares; flattening a few dozen array stubs is host microseconds."""
    return tuple((tuple(x.shape), str(x.dtype))
                 for x in jax.tree.leaves(tree))


class SlotKVCachePool:
    """Fixed-size cache slots with a free-list and per-slot positions."""

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 window: int | None = None, dtype=None, mesh=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self._donated_to: str | None = None
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.window = window if window is not None else cfg.attn_window
        self.caches = lm.init_caches(cfg, n_slots, max_len,
                                     window=self.window, dtype=dtype)
        # Mesh-sharded placement: the slot (batch) dim splits into
        # data-parallel groups, KV heads over 'model' (the same
        # launch/sharding cache_specs rules the dry-run path uses).  The
        # device_put happens once, before serving — placement of the one
        # allocation, not a reallocation.
        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from ..launch import sharding as sharding_lib

            self.shardings = sharding_lib.to_shardings(
                mesh, sharding_lib.cache_specs(cfg, mesh, n_slots, max_len))
            self.caches = jax.device_put(self.caches, self.shardings)
        self._layout_sig = _layout(self.caches)
        self.allocations = 1            # init_caches calls ever made
        self._free = list(range(n_slots - 1, -1, -1))
        self.positions = [0] * n_slots  # tokens cached per slot (host side)
        self.owner: list[Any] = [None] * n_slots
        self._write_jit = None

    # -- donation poison (strict mode) ---------------------------------------
    @property
    def caches(self):
        """The per-layer cache pytree.  Under strict mode
        (``core.strict``), reading this between a donating dispatch
        (``mark_donated``) and the matching ``adopt()`` raises
        ``DonatedCacheError``: the arrays' device buffers are already
        aliased into the dispatch's outputs."""
        if self._donated_to is not None and strict.enabled():
            raise strict.DonatedCacheError(self._donated_to)
        return self._caches

    @caches.setter
    def caches(self, tree) -> None:
        self._caches = tree
        self._donated_to = None

    def mark_donated(self, consumer: str) -> None:
        """Poison ``caches`` until the next rebind (``adopt()`` or a
        direct assignment).  The scheduler calls this immediately after
        handing the pool to a ``donate_argnums`` dispatch.  Costs one
        string store; the read-side check only fires under strict mode."""
        self._donated_to = consumer

    # -- free-list -----------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self, owner: Any = None) -> int | None:
        """Claim a slot for ``owner``; None when the pool is exhausted.
        The slot's recurrent state is zeroed (ring/full KV rows need no
        wipe — attention masks by position — but SSM states carry over)."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.owner[slot] = owner
        self.positions[slot] = 0
        self._zero_slot_states(slot)
        return slot

    def release(self, slot: int) -> None:
        if self.owner[slot] is None and slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        self.owner[slot] = None
        self.positions[slot] = 0
        self._free.append(slot)

    def _zero_slot_states(self, slot: int) -> None:
        """Zero the non-attention (recurrent) state rows of ``slot``."""

        def zero_row(kind: str, cache):
            if cache is None or kind in ("attn", "shared_attn"):
                return cache
            return _tree_map(_maybe(lambda x: x.at[slot].set(0)), cache)

        self.caches = [zero_row(kind, c) for kind, c in
                       zip(self.cfg.layer_kinds(), self.caches,
                           strict=True)]

    # -- slot I/O ------------------------------------------------------------
    def read_slot(self, slot: int):
        """The slot's caches as a batch-of-1 pytree (device views)."""
        return _tree_map(_maybe(lambda x: x[slot:slot + 1]), self.caches)

    def write_slot(self, slot: int, row_caches) -> None:
        """Write a batch-of-1 cache pytree back into ``slot``.

        Goes through one jitted update with the pool donated, so XLA
        aliases the output into the existing buffers — an eager
        ``.at[slot].set`` would copy every layer's full pool array per
        chunk.  ``slot`` rides in as a traced scalar (one compile total).
        """
        if self._write_jit is None:
            def write(caches, row, s):
                return jax.tree.map(
                    lambda c, n: c if c is None else
                    jax.lax.dynamic_update_slice(
                        c, n.astype(c.dtype),
                        (s,) + (0,) * (c.ndim - 1)),
                    caches, row, is_leaf=lambda x: x is None)

            # Explicit out_shardings on the mesh path: donation aliasing
            # requires output placement to equal the input's, and pinning
            # it stops GSPMD from ever resharding the pool mid-serve.
            self._write_jit = jax.jit(write, donate_argnums=0,
                                      out_shardings=self.shardings)
        self.caches = self._write_jit(self.caches, row_caches,
                                      jnp.int32(slot))

    def adopt(self, new_caches) -> None:
        """Rebind the pool to ``new_caches`` — the output of a step that
        **donated** the current pool (the fused decode loop,
        serve/decode_loop.py, like ``write_slot`` above).  The old
        arrays' buffers were aliased into the new ones by XLA; after
        this call the previous ``self.caches`` must never be touched
        again.  No allocation happens: ``allocations`` stays wherever
        it is (the invariant the donation tests pin at 1).

        Raises ``CacheLayoutError`` when the adopted tree's shapes or
        dtypes differ from the pool's — the step that produced it was
        built for different geometry, and rebinding would corrupt every
        later slot read."""
        if _layout(new_caches) != self._layout_sig:
            raise CacheLayoutError(
                f"adopted cache tree does not match the pool layout "
                f"(n_slots={self.n_slots}, max_len={self.max_len}, "
                f"arch={self.cfg.name})")
        self.caches = new_caches

    def advance(self, slot: int, n: int) -> int:
        """Advance ``slot``'s position by ``n`` cached tokens (the fused
        decode path moves a slot by up to ``k`` per dispatch).  The
        caller must have budgeted ``n`` against ``max_len``; raises
        ``SlotOverflowError`` on overshoot — cache writes past the
        slot's storage would wrap into other slots' rows."""
        if n < 0:
            raise ValueError(f"negative advance: {n}")
        pos = self.positions[slot] + n
        if pos > self.max_len:
            raise SlotOverflowError(slot, pos, self.max_len)
        self.positions[slot] = pos
        return pos

    def rollback(self, slot: int, n: int) -> int:
        """Roll ``slot``'s position back by ``n`` tokens (speculative
        decode: drafts past the accept point are rejected).  Rows at and
        past the rolled-back position become dead storage — the causal
        mask never attends a position at or past the query's own, and
        the next decode write starts at the rolled-back position and
        covers the stale extent — so no device work is needed, only the
        position bookkeeping."""
        if n < 0:
            raise ValueError(f"negative rollback: {n}")
        if n > self.positions[slot]:
            raise ValueError(
                f"rollback of {n} past slot {slot}'s position "
                f"{self.positions[slot]}")
        self.positions[slot] -= n
        return self.positions[slot]

    def positions_array(self) -> jax.Array:
        """Per-slot positions as an (n_slots,) int32 device array (free
        slots report 0; their decode lanes are ignored)."""
        return jnp.asarray(
            [min(p, self.max_len - 1) for p in self.positions], jnp.int32)


class PagePoolExhaustedError(RuntimeError):
    """Every page is referenced by a live slot and nothing in the prefix
    cache is evictable: an ``ensure_writable`` could not be honoured.
    With the default sizing (``n_slots * pages_per_slot`` pages plus
    slack) this cannot happen for slot writes — it means the pool was
    constructed deliberately undersized, or refcounts leaked."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        super().__init__(
            f"page pool exhausted: all {n_pages} pages are referenced "
            f"by live slots (prefix cache already evicted)")


@dataclasses.dataclass
class _PrefixEntry:
    """One cached prompt block: the page holding the KV of tokens
    ``[len(key) - n_tokens, len(key))`` of the prefix spelled by ``key``
    (the dict key is the full token tuple up to and including this
    block, so a lookup hit *is* the content check — no hash-collision
    re-derivation needed)."""

    page: int
    n_tokens: int            # tokens of the block this page holds
    full: bool               # page-aligned block (ps tokens) or partial
    last_used: int = 0       # LRU clock for eviction


class PagedKVCachePool:
    """Paged KV pool: fixed-size pages behind per-slot page tables, with
    copy-on-write prefix sharing.

    Layout per attention layer: one flat token-major page store
    ``{"k","v"}`` of shape ``(n_pages * page_size, H_kv, D)`` — page
    ``p`` owns rows ``[p*ps, (p+1)*ps)``.  A slot's logical row is the
    gather of its table's pages (``decode_loop.make_paged_decode_step``
    and ``read_slot`` build that contiguous view), so the model code
    underneath is byte-identical to the contiguous pool: same
    ``lm.forward_cached``, same masked attention, garbage past a lane's
    position masked to exactly zero either way.  Recurrent layer state
    (SSM/xLSTM) has no sequence axis to page — it stays slot-major,
    exactly as in ``SlotKVCachePool``.

    Page 0 is a permanently-allocated scratch page: unmapped table
    entries point at it, and the fused decode step routes inactive
    lanes' writes there, so a single scatter per layer serves every mix
    of active lanes without dynamic shapes.

    Copy-on-write protocol (all host-side bookkeeping, device work only
    for the actual page copies):

    * every page has a refcount (slot tables + prefix-cache entries);
    * ``ensure_writable(slot, lo, hi)`` must precede any device
      write into ``[lo, hi)`` — it allocates unmapped pages and
      copy-on-writes shared ones (one donated ``dynamic_update_slice``
      per copied page);
    * ``register_prefix`` publishes a freshly-prefilled prompt's pages
      into the token-keyed prefix cache (including the partial tail
      page, which is what makes CoW fire on the very next decode
      write); ``acquire_with_prefix`` maps a later matching prompt's
      cached pages read-only and reports how many prefill tokens that
      avoided;
    * a page whose refcount hits zero goes back to the free list and is
      *poisoned* until re-acquired: under strict mode every table the
      pool hands out is validated first, and a stale mapping raises
      ``strict.StalePageError`` instead of silently gathering rows a
      new owner may already be writing.

    Donation discipline is the contiguous pool's: ``caches`` poisons
    between ``mark_donated`` and ``adopt``, ``allocations`` stays at 1
    for the pool's lifetime (pages are *mapped*, never reallocated — the
    invariant is now bounded by pages, not slots)."""

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 page_size: int = 16, n_pages: int | None = None,
                 window: int | None = None, dtype=None, mesh=None,
                 prefix_cache: bool = True):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        window = window if window is not None else cfg.attn_window
        if window is not None and window > 0:
            raise ValueError(
                "PagedKVCachePool does not support ring-buffer (SWA) "
                "windows: a wrapped write would straddle pages shared "
                "read-only; use SlotKVCachePool for windowed archs")
        self._donated_to: str | None = None
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.window = None
        self.page_size = ps = max(int(page_size), 1)
        self.pages_per_slot = -(-max_len // ps)          # ceil
        # Default sizing: full residency for every slot, plus one
        # pages-per-slot worth of slack so prefix-cache entries survive
        # a full pool, plus the scratch page.
        self.n_pages = int(n_pages) if n_pages is not None else \
            1 + n_slots * self.pages_per_slot + self.pages_per_slot
        if self.n_pages < 2:
            raise ValueError(f"n_pages must be >= 2, got {self.n_pages}")
        self.kinds = tuple(cfg.layer_kinds())
        # One init_caches call fixes every layer's geometry; attention
        # entries are then re-laid as flat page stores (the transient
        # slot-major attn arrays are dropped on the spot).
        tmp = lm.init_caches(cfg, n_slots, max_len, window=None,
                             dtype=dtype)

        def _page_store(c):
            if c is None:
                return None
            n, h, _, d = c.shape    # (n_slots, H_kv, S, D)
            return jnp.zeros((self.n_pages * ps, h, d), c.dtype)

        self.caches = [
            _tree_map(_page_store, c) if kind in _ATTN_KINDS else c
            for kind, c in zip(self.kinds, tmp, strict=True)]
        del tmp
        self.mesh = mesh
        self.shardings = None
        if mesh is not None:
            from ..launch import sharding as sharding_lib

            self.shardings = sharding_lib.to_shardings(
                mesh, sharding_lib.paged_cache_specs(
                    cfg, mesh, n_slots, max_len))
            self.caches = jax.device_put(self.caches, self.shardings)
        self._layout_sig = _layout(self.caches)
        self.allocations = 1            # init_caches calls ever made
        self._free = list(range(n_slots - 1, -1, -1))
        self.positions = [0] * n_slots
        self.owner: list[Any] = [None] * n_slots
        # Page bookkeeping (all host-side).  Page 0 is scratch: refcount
        # pinned at 1 so it can never be allocated or freed.
        self.page_refs = [0] * self.n_pages
        self.page_refs[0] = 1
        self._free_pages = list(range(self.n_pages - 1, 0, -1))
        self._poisoned: set[int] = set()
        self.page_tables = [[0] * self.pages_per_slot
                            for _ in range(n_slots)]
        # Prefix cache: token-tuple → page entry (see _PrefixEntry).
        # ``_partials[key]`` lists the lengths of registered partial
        # tails extending the full-block chain ``key``.  Reuse is only
        # sound when *every* layer keys its state by position: a
        # recurrent layer's state is a running reduction over all
        # tokens, so skipping a reused prefix would skip its updates —
        # mixed archs keep paged layout but always prefill in full.
        self.prefix_cache = bool(prefix_cache) and \
            all(k in _ATTN_KINDS for k in self.kinds)
        self._prefix: dict[tuple, _PrefixEntry] = {}
        self._partials: dict[tuple, list[int]] = {}
        self._lru = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefill_tokens_avoided = 0
        self.cow_copies = 0
        self.prefix_evictions = 0
        self._write_jits: dict[int, Any] = {}
        self._read_jit = None
        self._copy_jit = None

    # -- donation poison (strict mode) ---------------------------------------
    @property
    def caches(self):
        """The per-layer cache pytree (page stores for attention,
        slot-major state for recurrent layers).  Poisons between
        ``mark_donated`` and ``adopt`` exactly like the slot pool."""
        if self._donated_to is not None and strict.enabled():
            raise strict.DonatedCacheError(self._donated_to)
        return self._caches

    @caches.setter
    def caches(self, tree) -> None:
        self._caches = tree
        self._donated_to = None

    def mark_donated(self, consumer: str) -> None:
        self._donated_to = consumer

    def adopt(self, new_caches) -> None:
        """Rebind after a donating dispatch (see
        ``SlotKVCachePool.adopt``); raises ``CacheLayoutError`` on a
        tree built for different page geometry."""
        if _layout(new_caches) != self._layout_sig:
            raise CacheLayoutError(
                f"adopted cache tree does not match the paged pool "
                f"layout (n_pages={self.n_pages}, "
                f"page_size={self.page_size}, arch={self.cfg.name})")
        self.caches = new_caches

    # -- slot lifecycle ------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def free_pages(self) -> int:
        return len(self._free_pages)

    def pages_in_use(self) -> int:
        """Pages currently referenced (tables + prefix cache), scratch
        excluded."""
        return self.n_pages - 1 - len(self._free_pages)

    def acquire(self, owner: Any = None) -> int | None:
        """Claim a slot (no prefix lookup); None when exhausted."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.owner[slot] = owner
        self.positions[slot] = 0
        self._zero_slot_states(slot)
        return slot

    def acquire_with_prefix(self, owner: Any,
                            prompt) -> tuple[int | None, int]:
        """Claim a slot and map any cached prefix of ``prompt`` into its
        page table read-only.  Returns ``(slot, reused)`` where
        ``reused`` is the number of prompt tokens whose KV is already
        resident (the caller starts prefill there).  Reuse is capped at
        ``len(prompt) - 1``: the last prompt token is always recomputed
        so the first-token logits exist."""
        slot = self.acquire(owner)
        if slot is None:
            return None, 0
        if not self.prefix_cache or prompt is None:
            return slot, 0
        toks = tuple(int(t) for t in prompt)
        self.prefix_lookups += 1
        ps = self.page_size
        cap = len(toks) - 1
        reused, j = 0, 0
        key: tuple = ()
        while (j + 1) * ps <= cap:
            cand = toks[:(j + 1) * ps]
            entry = self._prefix.get(cand)
            if entry is None:
                break
            self._map_shared(slot, j, entry)
            key, reused = cand, (j + 1) * ps
            j += 1
        # Longest registered partial tail extending the matched chain
        # (this is the block whose later extension is what CoW protects).
        for plen in sorted(self._partials.get(key, ()), reverse=True):
            # A tail page reaching past ``cap`` is still mappable — its
            # content for positions < cap is identical by key match; the
            # recomputed last token CoW-copies it before any write.
            if min(plen, cap) <= reused:
                continue
            entry = self._prefix.get(toks[:plen])
            if entry is not None:
                self._map_shared(slot, j, entry)
                reused = min(plen, cap)
                break
        if reused:
            self.prefix_hits += 1
            self.prefill_tokens_avoided += reused
            self.positions[slot] = reused
        return slot, reused

    def _map_shared(self, slot: int, j: int, entry: _PrefixEntry) -> None:
        self.page_refs[entry.page] += 1
        self.page_tables[slot][j] = entry.page
        self._lru += 1
        entry.last_used = self._lru

    def fork(self, src: int, owner: Any = None) -> int | None:
        """Clone ``src`` into a fresh slot sharing every mapped page
        copy-on-write (refcounts bumped; first divergent write on either
        side triggers the copy).  Recurrent state is copied eagerly — it
        has no page indirection to share.  None when no slot is free."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.owner[slot] = owner
        self.positions[slot] = self.positions[src]
        for j, pid in enumerate(self.page_tables[src]):
            if pid:
                self.page_refs[pid] += 1
            self.page_tables[slot][j] = pid

        def copy_row(kind: str, cache):
            if cache is None or kind in _ATTN_KINDS:
                return cache
            return _tree_map(_maybe(lambda x: x.at[slot].set(x[src])),
                             cache)

        self.caches = [copy_row(kind, c) for kind, c in
                       zip(self.kinds, self.caches, strict=True)]
        return slot

    def release(self, slot: int) -> None:
        if self.owner[slot] is None and slot in self._free:
            raise ValueError(f"slot {slot} double-released")
        self.owner[slot] = None
        self.positions[slot] = 0
        for j, pid in enumerate(self.page_tables[slot]):
            if pid:
                self._unref_page(pid)
            self.page_tables[slot][j] = 0
        self._free.append(slot)

    def _zero_slot_states(self, slot: int) -> None:
        def zero_row(kind: str, cache):
            if cache is None or kind in _ATTN_KINDS:
                return cache
            return _tree_map(_maybe(lambda x: x.at[slot].set(0)), cache)

        self.caches = [zero_row(kind, c) for kind, c in
                       zip(self.kinds, self.caches, strict=True)]

    # -- page allocation / refcounts -----------------------------------------
    def _unref_page(self, pid: int) -> None:
        self.page_refs[pid] -= 1
        if self.page_refs[pid] <= 0:
            # Freed: poisoned until re-acquired (strict.StalePageError).
            self.page_refs[pid] = 0
            self._poisoned.add(pid)
            self._free_pages.append(pid)

    def _alloc_page(self) -> int:
        if not self._free_pages:
            self._evict_for_space()
        if not self._free_pages:
            raise PagePoolExhaustedError(self.n_pages)
        pid = self._free_pages.pop()
        self._poisoned.discard(pid)
        self.page_refs[pid] = 1
        return pid

    def _evict_for_space(self) -> None:
        """Drop least-recently-used prefix entries whose page nobody
        else references until a page frees up (called only when the
        free list is empty)."""
        evictable = sorted(
            (e.last_used, key) for key, e in self._prefix.items()
            if self.page_refs[e.page] == 1)
        for _, key in evictable:
            self._drop_entry(key)
            self.prefix_evictions += 1
            if self._free_pages:
                return

    def _drop_entry(self, key: tuple) -> None:
        entry = self._prefix.pop(key)
        if not entry.full:
            chain = key[:len(key) - entry.n_tokens]
            lens = self._partials.get(chain)
            if lens is not None:
                lens.remove(len(key))
                if not lens:
                    del self._partials[chain]
        self._unref_page(entry.page)

    def ensure_writable(self, slot: int, lo: int, hi: int) -> bool:
        """Make pages covering ``[lo, hi)`` of ``slot`` exclusively
        writable: allocate unmapped entries, copy-on-write shared ones.
        Must precede every device write (prefill scatter, decode
        dispatch).  Returns True when the page table changed (the caller
        re-uploads it)."""
        if hi <= lo:
            return False
        if hi > self.max_len:
            raise SlotOverflowError(slot, hi, self.max_len)
        ps = self.page_size
        table = self.page_tables[slot]
        changed = False
        for j in range(lo // ps, -(-hi // ps)):
            pid = table[j]
            if pid == 0:
                table[j] = self._alloc_page()
                changed = True
            elif self.page_refs[pid] > 1:
                fresh = self._alloc_page()
                self._copy_page(pid, fresh)
                self.page_refs[pid] -= 1
                table[j] = fresh
                self.cow_copies += 1
                changed = True
        return changed

    def _copy_page(self, src: int, dst: int) -> None:
        """Device copy of one page in every attention layer's store
        (donated jit, traced page ids: one compile total)."""
        if self._copy_jit is None:
            ps, kinds = self.page_size, self.kinds

            def copy(caches, src_s, dst_s):
                def per_layer(kind, c):
                    if kind not in _ATTN_KINDS or c is None:
                        return c
                    return _tree_map(_maybe(lambda x: (
                        jax.lax.dynamic_update_slice(
                            x, jax.lax.dynamic_slice(
                                x, (src_s * ps, 0, 0),
                                (ps,) + x.shape[1:]),
                            (dst_s * ps, 0, 0)))), c)

                return [per_layer(kind, c)
                        for kind, c in zip(kinds, caches, strict=True)]

            self._copy_jit = jax.jit(copy, donate_argnums=0,
                                     out_shardings=self.shardings)
        self.caches = self._copy_jit(self.caches, jnp.int32(src),
                                     jnp.int32(dst))

    # -- prefix cache --------------------------------------------------------
    def register_prefix(self, slot: int, tokens) -> int:
        """Publish ``slot``'s freshly-prefilled prompt pages into the
        prefix cache (full blocks plus the partial tail).  The cache
        takes a reference on each page, so the slot's own next write
        into the tail page copy-on-writes it — cached content is never
        mutated.  Returns the number of new entries."""
        if not self.prefix_cache:
            return 0
        toks = tuple(int(t) for t in tokens)
        ps = self.page_size
        table = self.page_tables[slot]
        added = 0
        for j in range(-(-len(toks) // ps)):
            end = min((j + 1) * ps, len(toks))
            key = toks[:end]
            if key in self._prefix:
                self._lru += 1
                self._prefix[key].last_used = self._lru
                continue
            pid = table[j]
            if pid == 0:        # nothing prefilled here (shouldn't be)
                continue
            n_tok = end - j * ps
            full = n_tok == ps
            self.page_refs[pid] += 1
            self._lru += 1
            self._prefix[key] = _PrefixEntry(
                page=pid, n_tokens=n_tok, full=full, last_used=self._lru)
            if not full:
                self._partials.setdefault(toks[:j * ps], []).append(end)
            added += 1
        return added

    # -- stale-page validation (strict mode) ---------------------------------
    def _validate_tables(self) -> None:
        """Raise ``strict.StalePageError`` if any live slot's table maps
        a freed page — the paged analogue of the donated-buffer read."""
        for slot in range(self.n_slots):
            if self.owner[slot] is None:
                continue
            for pid in self.page_tables[slot]:
                if pid and (pid in self._poisoned
                            or self.page_refs[pid] <= 0):
                    raise strict.StalePageError(slot, pid)

    # -- slot I/O ------------------------------------------------------------
    def _slot_indices(self, slot: int) -> np.ndarray:
        """Flat page-store row index of every logical position of
        ``slot`` (length ``max_len``; unmapped entries resolve to the
        scratch page, whose garbage the attention mask zeroes out)."""
        ps = self.page_size
        table = np.fromiter(self.page_tables[slot], np.int32)
        idx = (table[:, None] * ps
               + np.arange(ps, dtype=np.int32)[None, :]).reshape(-1)
        return idx[:self.max_len]

    def page_table_array(self) -> jax.Array:
        """Every slot's page table as an (n_slots, pages_per_slot) int32
        device array — the fused decode step's gather indirection.
        Validated against freed pages first (strict mode)."""
        if strict.enabled():
            self._validate_tables()
        return jnp.asarray(self.page_tables, jnp.int32)

    def read_slot(self, slot: int):
        """The slot's caches as a contiguous batch-of-1 pytree: pages
        gathered through the table for attention layers (shape-identical
        to ``SlotKVCachePool.read_slot``, so the same compiled prefill
        serves both pools), slot rows for recurrent state."""
        if strict.enabled():
            self._validate_tables()
        if self._read_jit is None:
            kinds = self.kinds

            def read(caches, idx, slot_s):
                def per_layer(kind, c):
                    if c is None:
                        return None
                    if kind in _ATTN_KINDS:
                        return _tree_map(_maybe(
                            lambda x: x[idx].transpose(1, 0, 2)[None]), c)
                    return _tree_map(_maybe(
                        lambda x: jax.lax.dynamic_slice(
                            x, (slot_s,) + (0,) * (x.ndim - 1),
                            (1,) + x.shape[1:])), c)

                return [per_layer(kind, c)
                        for kind, c in zip(kinds, caches, strict=True)]

            self._read_jit = jax.jit(read)
        return self._read_jit(self.caches,
                              jnp.asarray(self._slot_indices(slot)),
                              jnp.int32(slot))

    def write_slot(self, slot: int, row_caches, lo: int = 0,
                   hi: int | None = None) -> None:
        """Scatter ``row_caches`` (a batch-of-1 contiguous view, as
        returned by the prefill step) back into the slot's pages for
        logical positions ``[lo, hi)``; recurrent state is written
        whole.  The caller must have ``ensure_writable``-d the range —
        shared prefix pages outside it are never touched.  Donated jit,
        one compile per distinct segment length (the prefill bucket
        set)."""
        hi = self.max_len if hi is None else hi
        seg = hi - lo
        if seg <= 0:
            return
        if hi > self.max_len:
            raise SlotOverflowError(slot, hi, self.max_len)
        fn = self._write_jits.get(seg)
        if fn is None:
            kinds = self.kinds

            def write(caches, row, idx, start_s, slot_s):
                def per_layer(kind, c, r):
                    if c is None:
                        return None
                    if kind in _ATTN_KINDS:
                        def scatter(x, n):
                            piece = jax.lax.dynamic_slice_in_dim(
                                n[0], start_s, seg, axis=1)
                            return x.at[idx].set(
                                piece.transpose(1, 0, 2).astype(x.dtype))

                        return jax.tree.map(scatter, c, r,
                                            is_leaf=lambda x: x is None)
                    return jax.tree.map(
                        lambda x, n: x if x is None else
                        jax.lax.dynamic_update_slice(
                            x, n.astype(x.dtype),
                            (slot_s,) + (0,) * (x.ndim - 1)),
                        c, r, is_leaf=lambda x: x is None)

                return [per_layer(kind, c, r) for kind, c, r in
                        zip(kinds, caches, row, strict=True)]

            fn = jax.jit(write, donate_argnums=0,
                         out_shardings=self.shardings)
            self._write_jits[seg] = fn
        idx = self._slot_indices(slot)[lo:hi]
        self.caches = fn(self.caches, row_caches, jnp.asarray(idx),
                         jnp.int32(lo), jnp.int32(slot))

    def advance(self, slot: int, n: int) -> int:
        """Advance the slot's position (see ``SlotKVCachePool.advance``)."""
        if n < 0:
            raise ValueError(f"negative advance: {n}")
        pos = self.positions[slot] + n
        if pos > self.max_len:
            raise SlotOverflowError(slot, pos, self.max_len)
        self.positions[slot] = pos
        return pos

    def rollback(self, slot: int, n: int) -> int:
        """Roll ``slot``'s position back by ``n`` tokens — page-refcount
        safe by construction: the dispatch's ``ensure_writable`` covered
        the whole speculative window before any device write, so every
        page touching the rolled-back range is exclusively owned by this
        slot (refcount 1) and *stays mapped* — its stale rows are dead
        storage the causal mask never reads and the next decode write
        overwrites.  No page is freed or unmapped here: unmapping would
        strand the window's allocation work, and freeing a page that a
        concurrent prefix registration might share is exactly the
        use-after-free class this pool's strict-mode validation hunts.
        Raises if a shared page covers the range (the caller skipped
        ``ensure_writable`` — a hard bug, not a recoverable state)."""
        if n < 0:
            raise ValueError(f"negative rollback: {n}")
        pos = self.positions[slot]
        if n > pos:
            raise ValueError(
                f"rollback of {n} past slot {slot}'s position {pos}")
        ps = self.page_size
        table = self.page_tables[slot]
        for j in range((pos - n) // ps, -(-pos // ps)):
            pid = table[j]
            if pid and self.page_refs[pid] > 1:
                raise ValueError(
                    f"rollback range [{pos - n}, {pos}) of slot {slot} "
                    f"touches shared page {pid} (refcount "
                    f"{self.page_refs[pid]}): the dispatch skipped "
                    f"ensure_writable over its speculative window")
        self.positions[slot] = pos - n
        return self.positions[slot]

    def positions_array(self) -> jax.Array:
        return jnp.asarray(
            [min(p, self.max_len - 1) for p in self.positions], jnp.int32)

    def prefix_stats(self) -> dict:
        """Prefix-cache effectiveness counters (what BENCH_load.json
        reports)."""
        return {
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": self.prefix_hits / self.prefix_lookups
            if self.prefix_lookups else 0.0,
            "prefill_tokens_avoided": self.prefill_tokens_avoided,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.prefix_evictions,
            "prefix_entries": len(self._prefix),
            "pages_in_use": self.pages_in_use(),
            "n_pages": self.n_pages,
            "page_size": self.page_size,
        }

    def reset_prefix_stats(self) -> None:
        """Zero the effectiveness counters (cached entries stay live) —
        the load harness calls this after its untimed prewarm so the
        reported hit rate covers only the replayed trace."""
        self.prefix_lookups = self.prefix_hits = 0
        self.prefill_tokens_avoided = 0
        self.cow_copies = self.prefix_evictions = 0
