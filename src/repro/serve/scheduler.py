"""Request-level continuous-batching scheduler driven by the acc model.

The paper's executor answers "how many cores, what chunk size?" for a
parallel loop from measured ``T0`` / ``t_iter``.  Re-read for serving,
the same decision is "how many requests advance this tick, what prefill
chunk?": the *workload* is the set of currently queued tokens (remaining
prefill plus one decode token per running request), and
``AdaptiveCoreChunk.decide`` over its ``WorkloadProfile`` yields

* ``n_cores``     → how many requests' prefills advance per tick
  (devices↔batching — Eq. 7's "leave units free" becomes "don't open
  more concurrent prefills than the queue can keep efficient");
* ``chunk_elems`` → the prefill chunk size per tick (Eq. 10 with the
  T_m floor), snapped to a small bucket set so compiled shapes are
  bounded.

Timings of every prefill chunk and decode step flow back through the
executor telemetry (core/feedback.py) into the calibration cache, so the
decisions track observed drift instead of a one-shot calibration — the
continuous adaptation HPX's Smart Executors argue for.

Mechanics:

* Requests wait in an arrival queue (earliest-deadline-first, FIFO among
  equal deadlines), are admitted when a cache slot frees up
  (serve/kv_cache.py), prefill chunk-by-chunk, then decode greedily.
* Decode runs **one compiled step for the whole slot pool** regardless of
  which slots are active: per-slot positions ride in as an array, lanes
  are vmapped, and inactive lanes' cache writes are masked out — so
  requests of any length mix in one batch with zero recompilation and
  zero cache reallocation.
* With ``dispatch_depth`` set, decode runs through the **fused
  on-device loop** (serve/decode_loop.py): up to ``k`` tokens per
  dispatch with donated cache buffers, the host pipelined one dispatch
  ahead of the device and emitted tokens drained asynchronously — the
  per-token ``block_until_ready`` + ``device_get`` of the per-tick path
  disappears.  ``k`` is an ExecutionModel decision
  (``serve_dispatch_depth``): the measured host overhead per tick is
  the Overhead Law's T0, the measured device time per token its
  t_iter, and the depth is the chunk that amortises one to the other.
* With ``mesh`` set (launch/mesh.make_serve_mesh), the whole serving
  path runs sharded over a device mesh: weights tensor-parallel over
  'model' within each replica, the slot pool's batch dim data-parallel
  across replicas, and the global active-lane count capped by a
  ``serve_mesh_batch`` engine decision — per-device batch width is the
  paper's cores question at mesh scale
  (``global_batch = n_replicas * per_device_batch``).
* Everything is deterministic under ``SequentialExecutor`` (tick trace is
  a pure function of arrivals), which is what the tests pin down; the
  fused path emits token-identical output (greedy decode over the same
  per-lane step — see decode_loop.make_lane_step).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import itertools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import overhead_law, strict
from ..core.acc import AdaptiveCoreChunk
from ..core.executor import Chunk, SequentialExecutor
from ..core.feedback import tag_workload
from ..core.future import Future, when_all
from ..core.model import (DEFAULT_SPEC_ACCEPT, DecisionKey, ExecutionModel,
                          decision_overhead_s, hardware_key)
from ..core.properties import params_of
from ..models import flags, lm
from ..train.autotune import serve_profiles
from .decode_loop import (DEFAULT_MAX_DEPTH, DEFAULT_SPEC_HISTORY,
                          SPEC_DEPTH_CANDIDATES, _check_spec_arch,
                          make_fused_decode_step, make_lane_step,
                          make_paged_decode_step, make_paged_spec_decode_step,
                          make_spec_decode_step, masked_merge)
from .kv_cache import PagedKVCachePool, SlotKVCachePool

DEFAULT_PAGE_CANDIDATES = (8, 16, 32, 64)

DEFAULT_CHUNK_BUCKETS = (8, 16, 32, 64, 128, 256)

# Under ``speculate="auto"``, depth 1 would be absorbing: no spec
# dispatches run, so the acceptance EMA can never move and the decision
# can never climb back.  Every this-many dispatches while parked at
# depth 1, one window runs at width 2 as an exploration probe — it
# refreshes the acceptance EMA at a bounded tax (one wider verify per
# SPEC_PROBE_EVERY windows) and is byte-identical like any spec step.
SPEC_PROBE_EVERY = 16


class PromptTooLongError(ValueError):
    """A submitted prompt does not fit a cache slot.  Typed (the front
    end turns it into a structured per-request rejection instead of a
    serve-loop crash); subclasses ``ValueError`` so pre-existing callers
    that caught the bare error keep working."""

    def __init__(self, prompt_len: int, max_len: int):
        self.prompt_len = prompt_len
        self.max_len = max_len
        super().__init__(
            f"prompt of {prompt_len} tokens does not fit a "
            f"max_len={max_len} slot")


def percentile(xs, p: float) -> float:
    """Latency-report percentile; NaN on empty (shared by the launch CLI
    and the throughput benchmark so their numbers can't diverge)."""
    import numpy as np

    return float(np.percentile(np.asarray(xs), p)) if len(xs) else \
        float("nan")


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    CANCELLED = "cancelled"    # caller withdrew the request mid-flight
    SHED = "shed"              # deadline expired before prefill: dropped


# States a request never leaves (its slot, if any, is back in the pool).
TERMINAL_STATES = (RequestState.DONE, RequestState.CANCELLED,
                   RequestState.SHED)


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle bookkeeping."""

    rid: int
    tokens: jax.Array               # (S,) int32 prompt
    max_new_tokens: int
    arrival: float
    deadline: float | None = None
    state: RequestState = RequestState.WAITING
    slot: int | None = None
    prefilled: int = 0              # prompt tokens already in the cache
    out: list[int] = dataclasses.field(default_factory=list)
    # Tokens dispatched to the device but not yet drained to ``out``
    # (fused decode path): the scheduling budget counts them, the
    # emitted output gains them only when their buffer is harvested.
    pending_out: int = 0
    first_token_at: float | None = None
    finished_at: float | None = None
    # Host-side prompt tokens, captured at submit() time (outside the
    # strict-mode transfer guard) — the paged pool's prefix-cache key.
    host_tokens: tuple | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def remaining_prefill(self) -> int:
        return self.prompt_len - self.prefilled


@dataclasses.dataclass(frozen=True)
class TickRecord:
    """What one scheduler tick did (the determinism tests compare these)."""

    tick: int
    admitted: tuple[int, ...]
    prefill_ops: tuple[tuple[int, int], ...]   # (rid, tokens advanced)
    decoded: tuple[int, ...]
    finished: tuple[int, ...]
    queued_tokens: int
    n_cores: int
    chunk: int
    depth: int = 0       # fused dispatch depth (0: per-tick decode path)
    # SLO accounting (the deterministic trace tests assert these):
    # deadline misses charged to this tick (sheds + late finishes) and
    # the waiting-queue depth left after this tick's admission.
    deadline_misses: int = 0
    queue_depth: int = 0
    # Variable tokens-per-dispatch accounting: ``depth`` is the decided
    # per-lane budget, but under speculation a loop round emits a
    # variable accepted-token count, so the total tokens this tick's
    # dispatch carried is recorded explicitly instead of being inferred
    # as lanes × depth.  ``spec_depth`` is the speculation width the
    # dispatch ran with (0: speculation off).
    dispatched_tokens: int = 0
    spec_depth: int = 0


class ServeScheduler:
    """Continuous batching over a slot pool, acc-decided per tick."""

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int,
                 max_len: int, window: int | None = None,
                 executor=None, acc: AdaptiveCoreChunk | None = None,
                 chunk_buckets: Sequence[int] = DEFAULT_CHUNK_BUCKETS,
                 clock: Callable[[], float] = time.monotonic,
                 kernel_tuner=None,
                 dispatch_depth: int | str | None = None,
                 max_dispatch_depth: int = DEFAULT_MAX_DEPTH,
                 pipeline: int = 2, sync_every: int = 8,
                 admission: str = "greedy",
                 shed_expired: bool = False, mesh=None,
                 paged: bool = False, page_size: int | str = "auto",
                 prefill_interleave: int | str = "auto",
                 speculate: int | str | None = None,
                 max_spec_depth: int = 8,
                 spec_history: int = DEFAULT_SPEC_HISTORY):
        kinds = set(cfg.layer_kinds())
        if "cross_attn" in kinds:
            raise ValueError(
                "ServeScheduler does not serve cross-attention archs "
                "(per-request frontend feats); use ServeEngine's legacy "
                "batch path")
        self.cfg = cfg
        self.params = params
        self.window = window if window is not None else cfg.attn_window
        if self.window is not None and self.window <= 0:
            self.window = None
        self.max_len = max_len
        self.executor = executor if executor is not None \
            else SequentialExecutor()
        self.acc = acc or params_of(self.executor) or AdaptiveCoreChunk()
        # Measured Pallas blocks for the compiled prefill/decode steps
        # (kernels/autotune.KernelTuner); None = analytic/jnp paths.  The
        # tuner runs at jit-trace time, so each compiled shape pays at
        # most one candidate search — and none when the winner is already
        # persisted in the calibration store.
        self.kernel_tuner = kernel_tuner
        # Mesh-sharded serving (launch/mesh + launch/sharding): weights
        # go tensor-parallel over 'model' within each replica (serving
        # drops 'data' from the weight rules — full TP copy per
        # replica), the slot pool's batch dim splits into data-parallel
        # groups, and every compiled step (prefill, decode, the fused
        # loop) partitions over the committed input placements.
        self.mesh = mesh
        self.n_replicas = 1
        self.mesh_desc = None
        if mesh is not None:
            from ..launch import mesh as mesh_lib
            from ..launch import sharding as sharding_lib

            self.n_replicas = mesh_lib.n_data_replicas(mesh)
            if n_slots % self.n_replicas:
                raise ValueError(
                    f"n_slots={n_slots} must divide into "
                    f"{self.n_replicas} data-parallel replicas "
                    f"(mesh {dict(mesh.shape)})")
            self.mesh_desc = "x".join(
                str(mesh.shape[a]) for a in mesh.axis_names)
            pshard, _ = sharding_lib.serve_shardings(
                cfg, mesh, params, n_slots, max_len)
            self.params = jax.device_put(params, pshard)
        self.slots_per_replica = n_slots // self.n_replicas
        self.clock = clock
        self.chunk_buckets = tuple(sorted(set(int(b) for b in chunk_buckets
                                              if b > 0))) or (max_len,)
        # Padding prefill chunks to a bucket is only sound when every
        # layer masks by position: recurrent (SSM/xLSTM) states would
        # absorb the pad tokens, and ring (SWA) writes could wrap over
        # live entries — those archs run exact-size chunks instead.
        self._pad_ok = self.window is None and kinds <= {"attn",
                                                         "shared_attn"}
        self.prefill_profile, self.decode_profile = serve_profiles(cfg)
        # Workload keys carry the model's shape, not just its name:
        # cfg.reduced() keeps the name, and a persisted t_iter smoothed on
        # the tiny config must never drive decisions for the full one.
        sig = (cfg.name, cfg.d_model, cfg.n_layers)
        self.prefill_key = ("serve_prefill",) + sig
        self.decode_key = ("serve_decode",) + sig
        # Paged KV pool (kv_cache.PagedKVCachePool): memory layout as an
        # ExecutionModel decision.  The page size is decided at
        # construction (``serve_page_size`` — geometry is baked into the
        # compiled steps), seeded analytically from whatever the store
        # already knows and re-decided on timed syncs as the run observes
        # real page-management and prefill costs; the refined choice
        # drives the *next* pool this store backs.  The prefill/decode
        # interleave ratio (``serve_prefill_interleave``) is decided per
        # tick — see ``_decide_interleave``.
        self.paged = bool(paged)
        self.page_size_key = DecisionKey("serve_page_size", sig)
        self.interleave_key = DecisionKey("serve_prefill_interleave", sig)
        self.page_mgmt_key = ("serve_page_mgmt",) + sig
        if isinstance(prefill_interleave, str):
            if prefill_interleave != "auto":
                raise ValueError(
                    f"prefill_interleave must be an int or 'auto'; "
                    f"got {prefill_interleave!r}")
        else:
            prefill_interleave = max(int(prefill_interleave), 1)
        self.prefill_interleave = prefill_interleave
        # Decode lanes stalled on prefill: cumulative seconds the tick
        # blocked on prefill chunks while decode lanes were active with
        # no fused dispatch in flight to hide behind — the number the
        # interleave decision minimises (benchmarks/serve_throughput.py
        # surfaces it per tick).
        self.prefill_stall_s = 0.0
        self._last_depth = 0
        self._page_size_auto = paged and page_size == "auto"
        if self.paged:
            if dispatch_depth is None:
                raise ValueError(
                    "paged serving requires the fused decode path: "
                    "pass dispatch_depth (an int or 'auto')")
            if isinstance(page_size, str):
                if page_size != "auto":
                    raise ValueError(
                        f"page_size must be an int or 'auto'; "
                        f"got {page_size!r}")
                ps = self._decide_page_size()
            else:
                ps = max(int(page_size), 1)
                model = self.decision_model()
                if model is not None:
                    model.note(self.page_size_key,
                               policy="fixed-page-size", cores=1,
                               chunk=ps, inputs=(("fixed", True),))
            self.pool: Any = PagedKVCachePool(
                cfg, n_slots, max_len, window=self.window,
                page_size=ps, mesh=mesh)
        else:
            self.pool = SlotKVCachePool(cfg, n_slots, max_len,
                                        window=self.window, mesh=mesh)
        # Engine key for the per-tick decision: every tick's width/chunk
        # choice lands in the shared ExecutionModel trace under this key
        # (--explain-decisions attributes serve ticks through it).
        self.tick_key = DecisionKey("serve_tick", sig)
        # Fused decode hot path (serve/decode_loop.py).  ``dispatch_depth``
        # is None (per-tick decode, one device round-trip per token),
        # an int (fixed depth), or "auto" (per-tick engine decision of
        # kind ``serve_dispatch_depth``).
        if isinstance(dispatch_depth, str):
            if dispatch_depth != "auto":
                raise ValueError(
                    f"dispatch_depth must be None, an int, or 'auto'; "
                    f"got {dispatch_depth!r}")
        elif dispatch_depth is not None:
            dispatch_depth = max(int(dispatch_depth), 1)
        self.dispatch_depth = dispatch_depth
        self._fused = dispatch_depth is not None
        self.max_dispatch_depth = max(int(max_dispatch_depth), 1)
        self.pipeline = max(int(pipeline), 1)
        self.sync_every = max(int(sync_every), 1)
        self.depth_key = DecisionKey("serve_dispatch_depth", sig)
        # Self-speculative decoding (decode_loop.make_spec_decode_step):
        # ``speculate`` is None (off), an int (fixed draft window), or
        # "auto" (decision kind ``serve_spec_depth`` — analytic prior
        # from the overhead law's accept-vs-verify-cost trade, refined
        # online from the acceptance rate observed at drain time, with
        # backoff to depth 1 when acceptance collapses).  Speculation
        # rides the fused path; rollback of rejected drafts is pure
        # position bookkeeping only under position-masked attention, so
        # _check_spec_arch gates out SWA rings and recurrent state.
        if isinstance(speculate, str):
            if speculate != "auto":
                raise ValueError(
                    f"speculate must be None, an int, or 'auto'; "
                    f"got {speculate!r}")
        elif speculate is not None:
            speculate = max(int(speculate), 1)
        self.speculate = speculate
        self._spec = speculate is not None
        if self._spec:
            if dispatch_depth is None:
                raise ValueError(
                    "speculative decoding rides the fused decode path: "
                    "pass dispatch_depth (an int or 'auto')")
            _check_spec_arch(cfg, self.window)
        self.max_spec_depth = max(int(max_spec_depth), 1)
        self.spec_history = max(int(spec_history), 8)
        self.spec_depth_key = DecisionKey("serve_spec_depth", sig)
        # Acceptance EMA: each drained spec dispatch contributes the
        # acceptance rate recovered at *its own* width (elems=verifies,
        # "seconds"=accept × verifies, so the refiner's per-element
        # ratio is the acceptance itself — floored at 1e-3 through
        # total-rejection stretches so the sample still records); plus
        # seconds per speculative loop round, the depth decision's cost
        # input.
        self.spec_accept_key = ("serve_spec_accept",) + sig
        self.spec_step_key = ("serve_spec_step",) + sig
        # Clean width-1 per-iteration seconds (non-speculative timed
        # dispatches only; ``fused_key`` is per-token and keeps being
        # observed under speculation for the window decisions).  The
        # ratio spec_step / fused_iter prices the verify width online —
        # the depth decision's width_cost stops being a static prior as
        # soon as both EMAs hold samples.
        self.fused_iter_key = ("serve_fused_iter",) + sig
        self._spec_jit: dict[int, Any] = {}
        self._dev_hist = None       # device-resident token-history ring
        self._hist_overrides: dict[int, list[int]] = {}
        self.spec_verifies = 0      # per-lane verify events drained
        self.spec_emitted = 0       # tokens emitted by speculative steps
        self.spec_rounds = 0        # speculative loop rounds drained
        self._spec_depth = 1
        # Admission policy: "greedy" fills every free slot (the pre-SLO
        # behaviour, what the deterministic trace tests pin); "adaptive"
        # makes the width a ``serve_admission`` engine decision from the
        # queue depth and the measured tick time (the front end's mode).
        if admission not in ("greedy", "adaptive"):
            raise ValueError(
                f"admission must be 'greedy' or 'adaptive', "
                f"got {admission!r}")
        self.admission = admission
        self.admit_key = DecisionKey("serve_admission", sig)
        # Mesh-aware batch width (decision kind ``serve_mesh_batch``):
        # the DecisionKey's hardware field is extended with the mesh
        # shape, so a width chosen on a (4,2) mesh never backs a (2,4)
        # run on the same silicon.
        self.mesh_key = None if mesh is None else DecisionKey(
            "serve_mesh_batch", sig,
            hardware=f"{hardware_key()}|mesh={self.mesh_desc}")
        # Deadline enforcement: with ``shed_expired`` a WAITING request
        # whose deadline has already passed is shed *before* prefill
        # (its tokens would be thrown away anyway); finished requests
        # that land past their deadline are counted as misses either
        # way.  Cumulative SLO counters (per-tick values ride on the
        # TickRecord):
        self.shed_expired = bool(shed_expired)
        self.deadline_misses = 0    # sheds + late finishes
        self.shed = 0               # expired before prefill, dropped
        self.cancelled = 0          # withdrawn by the caller mid-flight
        self._tick_misses = 0       # misses charged to the current tick
        self._queue_depth = 0       # waiting after this tick's admission
        # Timing keys for the depth decision's two inputs (both refined
        # online): seconds of host work per tick, seconds of device
        # work per fused-decoded token.
        self.host_tick_key = ("serve_host_tick",) + sig
        self.fused_key = ("serve_decode_fused",) + sig
        self._fused_jit = None
        self._warm_fused = False
        # Compiled fused-step variants ("fused" or ("spec", d)) that have
        # executed at least once — the timed-sync guard checks membership
        # so a cold compile is never recorded as dispatch time.
        self._warm_steps: set = set()
        self._dev_toks = None       # device-resident last-token carry
        self._tok_overrides: dict[int, int] = {}
        # In-flight fused dispatches: (out_buf, [(req, slot, take)...]).
        self._inflight: collections.deque = collections.deque()
        # Dispatch-granularity telemetry (benchmarks/serve_throughput.py
        # derives host-overhead-per-token and dispatches-per-token).
        self.decode_dispatches = 0
        self.decode_tokens = 0
        # Decode loop iterations executed (fused: max take per dispatch
        # — the fori_loop trip count; per-tick: 1 per dispatch).  This is
        # the multiplier for decode_cost_analysis()'s per-iteration
        # flops/bytes in the benchmark's TFLOP/s + HBM-BW accounting.
        self.decode_loop_iters = 0
        self.host_roundtrips = 0    # block/device_get events, all paths
        self.host_overhead_s = 0.0  # tick wall-clock minus device waits
        self._blocked_s = 0.0
        self._rid = itertools.count()
        self._waiting: list[Request] = []
        self._active: list[Request] = []
        self.requests: dict[int, Request] = {}
        self.trace: list[TickRecord] = []
        self._tick = 0
        self._prefill_jit: dict[int, Any] = {}
        self._decode_jit = None
        # Shapes that have executed at least once: a cold call pays XLA
        # compilation, and seconds of compile time must never be recorded
        # as t_iter (it would seed — and persist — a poisoned EMA).
        self._warm_prefill: set[int] = set()
        self._warm_decode = False
        if self._spec:
            self._spec_depth = self._decide_spec_depth()

    # ------------------------------------------------------------------ API
    def submit(self, tokens, max_new_tokens: int = 16, *,
               deadline: float | None = None,
               arrival: float | None = None) -> int:
        """Enqueue a request; returns its id.  ``tokens`` is a 1-D prompt.

        The prompt must fit the slot: prompt + generated tokens are capped
        by the pool's ``max_len``.
        """
        tokens = jnp.asarray(tokens, jnp.int32).reshape(-1)
        if tokens.shape[0] == 0:
            raise ValueError("empty prompt")
        if tokens.shape[0] >= self.max_len:
            raise PromptTooLongError(int(tokens.shape[0]), self.max_len)
        rid = next(self._rid)
        req = Request(rid=rid, tokens=tokens,
                      max_new_tokens=max(int(max_new_tokens), 1),
                      arrival=self.clock() if arrival is None else arrival,
                      deadline=deadline)
        if self._spec or (self.paged
                          and getattr(self.pool, "prefix_cache", False)):
            # Prefix-cache key / speculation history seed, captured here
            # — outside the tick's strict-mode transfer guard (submit is
            # the sanctioned spot for a prompt to touch the host).
            import numpy as np

            req.host_tokens = tuple(
                int(t) for t in np.asarray(tokens))
        self.requests[rid] = req
        self._waiting.append(req)
        return rid

    @property
    def pending(self) -> int:
        """Requests not yet finished (waiting + running)."""
        return len(self._waiting) + len(self._active)

    def cancel(self, rid: int) -> bool:
        """Withdraw a request mid-flight.  Its cache slot goes straight
        back to the free list (no reallocation — the pool's
        ``allocations==1`` donation invariant holds), and any tokens it
        has in a not-yet-drained fused dispatch are dropped at drain
        time instead of emitted.  Returns False when the request is
        unknown or already terminal (cancel is idempotent)."""
        req = self.requests.get(rid)
        if req is None or req.state in TERMINAL_STATES:
            return False
        if req.state is RequestState.WAITING:
            self._waiting.remove(req)
        else:
            self._active.remove(req)
            if req.slot is not None:
                # A first token the prefill already produced for this
                # slot must not be spliced into the next dispatch's
                # token carry — the slot may belong to someone else by
                # then.
                self._tok_overrides.pop(req.slot, None)
                self._hist_overrides.pop(req.slot, None)
                self.pool.release(req.slot)
                req.slot = None
        req.state = RequestState.CANCELLED
        req.finished_at = self.clock()
        self.cancelled += 1
        return True

    def decision_model(self) -> ExecutionModel | None:
        """The ExecutionModel engine behind this scheduler's decisions
        (None when the params object carries no calibration cache, e.g.
        StaticCoreChunk)."""
        cache = getattr(self.acc, "cache", None)
        return ExecutionModel.of(cache) if cache is not None else None

    def results(self) -> dict[int, list[int]]:
        self.flush()   # fused path: land every dispatched-but-undrained token
        return {rid: list(r.out) for rid, r in self.requests.items()
                if r.state is RequestState.DONE}

    def clear_finished(self) -> None:
        """Drop completed requests and the tick trace.  Long-lived
        callers (the ServeEngine facade) call this after draining —
        otherwise every prompt and TickRecord ever served stays
        reachable."""
        self.flush()   # a DONE request's tokens may still be in flight
        self.requests = {rid: r for rid, r in self.requests.items()
                         if r.state not in TERMINAL_STATES}
        self.trace.clear()

    def run_until_idle(self, max_ticks: int = 100_000) -> dict[int, list[int]]:
        for _ in range(max_ticks):
            if not self.pending:
                return self.results()
            self.tick()
        raise RuntimeError(f"scheduler did not drain in {max_ticks} ticks")

    def warmup(self) -> None:
        """Compile everything the steady-state tick path touches — the
        decode step, the prefill buckets, the donated slot write-back
        and the first-token argmax — so the first timed tick measures
        compute, not compilation."""
        if self._fused:
            # One compile serves every depth (dynamic trip count); the
            # zero-step calls donate and return the pool unchanged.
            self._tok_overrides[0] = 0   # compile the override splice
            zeros = jnp.zeros(self.pool.n_slots, jnp.int32)
            new_caches, out_buf, toks = self._fused_step()(
                self.params, self.pool.caches,
                *((self.pool.page_table_array(),) if self.paged else ()),
                self._decode_toks(), self.pool.positions_array(), zeros)
            self.pool.mark_donated("fused decode warmup")
            jax.block_until_ready(out_buf)
            self.pool.adopt(new_caches)
            self._dev_toks = toks
            self._warm_steps.add("fused")
            # Speculative variants: compile every width the adaptive
            # re-decision can land on (plus the plain fused step above,
            # which backoff-to-1 falls back to) — a mid-run width
            # switch must swap executables, never compile one.  Each
            # zero-step call's while cond is False, so nothing runs;
            # the history-override splice compiles along the way.
            if self._spec:
                cap = min(self.max_spec_depth, self.max_dispatch_depth)
                if self.speculate == "auto":
                    widths = [c for c in SPEC_DEPTH_CANDIDATES
                              if 2 <= c <= cap]
                else:
                    widths = [self._spec_depth] \
                        if self._spec_depth >= 2 else []
                for d in widths:
                    self._hist_overrides[0] = [0]
                    new_caches, hist, out_buf, toks, _stats = \
                        self._spec_step(d)(
                            self.params, self.pool.caches,
                            *((self.pool.page_table_array(),)
                              if self.paged else ()),
                            self._decode_hist(), self._decode_toks(),
                            self.pool.positions_array(), zeros)
                    self.pool.mark_donated("fused decode warmup")
                    jax.block_until_ready(out_buf)
                    self.pool.adopt(new_caches)
                    self._dev_toks = toks
                    self._dev_hist = hist
                    self._warm_steps.add(("spec", d))
            self._warm_fused = True
        else:
            self._decode_step()(
                self.params, self.pool.caches,
                jnp.zeros(self.pool.n_slots, jnp.int32),
                self.pool.positions_array(),
                jnp.zeros(self.pool.n_slots, dtype=bool))
            self._warm_decode = True
        if self._pad_ok:
            warmed, warm_b = None, 0
            for b in self.chunk_buckets:
                if b < self.max_len:
                    row = self.pool.read_slot(0)
                    warmed = self._prefill_step(b)(
                        self.params, row, jnp.zeros((1, b), jnp.int32),
                        jnp.int32(0), jnp.int32(b - 1))
                    self._warm_prefill.add(b)
                    warm_b = b
            if warmed is not None:
                # Slot 0 is free here (warmup precedes admission) and
                # masking hides the garbage row: writing it back
                # compiles the donated write-back and the first-token
                # argmax the real prefill path goes through.
                logits, new_row = warmed
                int(jnp.argmax(logits[0, 0]))
                if self.paged:
                    # Unmapped table → the garbage row scatters into the
                    # scratch page; compiles the ranged page write.
                    self.pool.write_slot(0, new_row, 0, warm_b)
                else:
                    self.pool.write_slot(0, new_row)

    # ----------------------------------------------------------------- tick
    def tick(self) -> TickRecord:
        """One scheduler round: admit → decide → prefill chunks → decode.

        The wall-clock of everything that is *not* a device wait is
        accumulated as ``host_overhead_s`` — the per-dispatch T0 the
        fused path amortises.  On fused decode-only ticks it is also
        folded into the calibration store (``serve_host_tick``), which
        is what drives the next ``serve_dispatch_depth`` decision.

        Under strict mode (``core.strict``) the whole round runs with
        implicit device→host transfers disallowed — the sanctioned
        syncs all go through explicit ``device_get``/
        ``block_until_ready``, so anything else that blocks here is a
        bug the guard turns into a hard error.
        """
        with strict.hot_dispatch_guard():
            t_start = time.perf_counter()
            self._blocked_s = 0.0
            self._tick_misses = 0
            was_warm = self._warm_fused
            rec = self._tick_fused() if self._fused else self._tick_legacy()
            host_s = max(
                time.perf_counter() - t_start - self._blocked_s, 0.0)
            self.host_overhead_s += host_s
            if self._fused and was_warm and rec.decoded \
                    and not rec.prefill_ops:
                # Clean sample: no prefill compute and no cold compiles
                # in the window, so host_s is pure scheduling overhead.
                model = self.decision_model()
                if model is not None:
                    model.observe(self.host_tick_key, 1, host_s)
            return rec

    def _tick_legacy(self) -> TickRecord:
        """Per-tick decode: one device round-trip per decoded token."""
        admitted = self._admit()
        queued, cores, chunk = self._decide()
        prefill_ops, pf_finished = self._run_prefill(cores, chunk)
        decoded, dec_finished = self._run_decode()
        finished = pf_finished + dec_finished
        self._active = [r for r in self._active
                        if r.state is not RequestState.DONE]
        rec = TickRecord(
            tick=self._tick, admitted=tuple(admitted),
            prefill_ops=tuple(prefill_ops), decoded=tuple(decoded),
            finished=tuple(finished), queued_tokens=queued,
            n_cores=cores, chunk=chunk,
            deadline_misses=self._tick_misses,
            queue_depth=self._queue_depth)
        self.trace.append(rec)
        self._tick += 1
        return rec

    def _tick_fused(self) -> TickRecord:
        """Fused decode: admission and accounting run decoupled from the
        device stream.  The tick harvests whatever finished dispatches
        are ready (blocking only to bound the pipeline), runs prefill as
        before, then dispatches the next fused decode without waiting
        for it — tick N+1's host work overlaps tick N's device work."""
        self._drain(drop_to=self.pipeline - 1, harvest=True)
        admitted = self._admit()
        pf_pending = any(r.state is RequestState.PREFILL
                         for r in self._active)
        n_dec = sum(1 for r in self._active
                    if r.state is RequestState.DECODE)
        if pf_pending:
            queued, cores, chunk = self._decide()
            if self.paged and n_dec:
                # Chunked-prefill interleave: cap this tick's prefill
                # chunk-ops to what fits the window the in-flight fused
                # decode keeps the device busy (``serve_prefill_interleave``).
                cores = min(cores, self._decide_interleave(chunk))
            pre_blocked = self._blocked_s
            prefill_ops, pf_finished = self._run_prefill(cores, chunk)
            if n_dec and not self._inflight:
                # Decode lanes sat idle while these chunks ran — nothing
                # was in flight to hide the prefill behind.  This is the
                # stall the interleave decision minimises.
                self.prefill_stall_s += max(
                    self._blocked_s - pre_blocked, 0.0)
        else:
            # Decode-only tick: skip the prefill width/chunk query — on
            # the fused hot path those engine calls are host overhead.
            queued = n_dec
            cores, chunk = 0, 0
            prefill_ops, pf_finished = [], []
        decoded, dec_finished, depth, disp_toks, spec_d = \
            self._dispatch_decode()
        finished = pf_finished + dec_finished
        self._active = [r for r in self._active
                        if r.state is not RequestState.DONE]
        if not self._active and not self._waiting:
            # Going idle: nothing left to overlap the pipeline with, so
            # land every in-flight token now — finished_at must mean
            # "tokens on the host", not "whenever the next tick drains".
            self.flush()
        rec = TickRecord(
            tick=self._tick, admitted=tuple(admitted),
            prefill_ops=tuple(prefill_ops), decoded=tuple(decoded),
            finished=tuple(finished), queued_tokens=queued,
            n_cores=cores, chunk=chunk, depth=depth,
            deadline_misses=self._tick_misses,
            queue_depth=self._queue_depth,
            dispatched_tokens=disp_toks, spec_depth=spec_d)
        self.trace.append(rec)
        self._tick += 1
        return rec

    def _admit(self) -> list[int]:
        """Earliest-deadline-first admission into free slots; FIFO among
        requests without deadlines.  Exhausted pool ⇒ requests keep
        waiting (they are *queued*, never dropped — unless
        ``shed_expired`` and their deadline has already passed, in which
        case prefilling them would burn compute on tokens nobody can
        use: they are shed before prefill and counted as misses).  With
        ``admission="adaptive"`` the number of slots filled this tick is
        a ``serve_admission`` engine decision, not "all of them"."""
        if self.shed_expired and self._waiting:
            now = self.clock()
            kept = []
            for req in self._waiting:
                if req.deadline is not None and now > req.deadline:
                    req.state = RequestState.SHED
                    req.finished_at = now
                    self.shed += 1
                    self.deadline_misses += 1
                    self._tick_misses += 1
                else:
                    kept.append(req)
            self._waiting = kept
        self._waiting.sort(key=lambda r: (
            r.deadline if r.deadline is not None else float("inf"),
            r.arrival, r.rid))
        width = self._decide_admission()
        lane_cap = self._decide_mesh_batch()
        admitted = []
        while self._waiting and self.pool.free_slots() \
                and (width is None or len(admitted) < width) \
                and (lane_cap is None or len(self._active) < lane_cap):
            req = self._waiting.pop(0)
            if self.paged and req.host_tokens is not None:
                # Map any cached prefix of the prompt read-only into the
                # slot's page table; prefill resumes past it.
                req.slot, reused = self.pool.acquire_with_prefix(
                    req.rid, req.host_tokens)
                req.prefilled = reused
            else:
                req.slot = self.pool.acquire(req.rid)
            req.state = RequestState.PREFILL
            self._active.append(req)
            admitted.append(req.rid)
        self._queue_depth = len(self._waiting)
        return admitted

    def _decide_admission(self) -> int | None:
        """Admission width for this tick (decision kind
        ``serve_admission``), or None for greedy fill-every-slot.

        The analytic prior reads the Overhead Law at the request level:
        the measured host tick time is the T0 every admission round
        pays, one queued request's prefill bill (online-refined
        ``serve_prefill`` t_iter × its remaining prompt) is the t_iter,
        and the queue depth is the element count — the width is the
        widest admission that keeps the tick efficient, opened up to
        every free slot when the head-of-queue deadline slack is inside
        two admission rounds (deadline pressure beats efficiency).
        """
        if self.admission != "adaptive" or not self._waiting:
            return None
        free = self.pool.free_slots()
        if free == 0:
            return None
        model = self.decision_model()
        if model is None:       # static params object: no store, greedy
            return None
        host = model.smoothed_t_iter(self.host_tick_key)
        inputs: tuple = ()
        if host is None:
            # Same seed as the depth decision: the calibrated
            # empty-dispatch T0 plus a few engine queries — the host
            # work a tick provably pays before any tick was timed.
            host = self.acc.calibrate_t0(self.executor) \
                + 4.0 * decision_overhead_s()
            inputs = (("seeded", True),)
        head = self._waiting[0]
        t_pf = self.acc.measure_iteration(
            self.executor, self.prefill_profile,
            max(head.remaining_prefill, 1), key=self.prefill_key)
        req_cost = t_pf * max(head.remaining_prefill, 1)
        slack = None if head.deadline is None \
            else head.deadline - self.clock()
        decision = model.admission_width(
            self.admit_key, queue_depth=len(self._waiting),
            free_slots=free, host_tick_s=host, request_cost_s=req_cost,
            slack_s=slack, max_width=self.pool.n_slots,
            eff=getattr(self.acc, "efficiency",
                        overhead_law.DEFAULT_EFFICIENCY),
            evidence=(self.host_tick_key, self.prefill_key),
            inputs=inputs)
        return decision.cores

    def _decide_mesh_batch(self) -> int | None:
        """Global active-lane cap for a mesh-sharded pool (decision kind
        ``serve_mesh_batch``), or None when serving single-device / the
        queue is empty / the params object carries no store.

        Per-device batch width is the mesh's cores/chunk question: the
        engine amortises the measured per-dispatch host overhead
        (``serve_host_tick``) against the measured fused device step
        (``serve_decode_fused``) over the per-replica demand, and the
        cap is ``width * n_replicas`` — admission never opens more
        concurrent lanes per replica than the dispatch can keep
        efficient.  Only consulted when there is something to admit, so
        decode-only ticks pay no engine query."""
        if self.mesh is None or not self._waiting:
            return None
        model = self.decision_model()
        if model is None:       # static params object: every slot
            return None
        demand = len(self._waiting) + len(self._active)
        evidence = [self.host_tick_key, self.fused_key]
        inputs: tuple = (("mesh", self.mesh_desc),)
        host = model.smoothed_t_iter(self.host_tick_key)
        if host is None:
            host = self.acc.calibrate_t0(self.executor) \
                + 4.0 * decision_overhead_s()
            inputs += (("seeded", True),)
        dev = model.smoothed_t_iter(self.fused_key)
        if dev is None:
            dev = self.acc.measure_iteration(
                self.executor, self.decode_profile, max(demand, 1),
                key=self.decode_key)
            evidence.append(self.decode_key)
        decision = model.mesh_batch(
            self.mesh_key, demand=demand, n_replicas=self.n_replicas,
            slots_per_replica=self.slots_per_replica,
            host_tick_s=host, device_step_s=dev,
            eff=getattr(self.acc, "efficiency",
                        overhead_law.DEFAULT_EFFICIENCY),
            evidence=tuple(evidence), inputs=inputs)
        return decision.batch_width

    def _decide(self) -> tuple[int, int, int]:
        """(queued tokens, batch width, prefill chunk) for this tick.

        Spoken through the three customization points so any
        execution-parameters object plugs in: ``AdaptiveCoreChunk`` gives
        the Overhead-Law decision, ``StaticCoreChunk`` the fixed
        OpenMP-static split.  The queue's t_iter is the token-weighted
        mix of the prefill and decode regimes — each priced by its own
        profile, each overridden by its own online-feedback key once the
        executor has timed real chunks of that kind.
        """
        pf_tokens = sum(r.remaining_prefill for r in self._active
                        if r.state is RequestState.PREFILL)
        dec_tokens = sum(1 for r in self._active
                         if r.state is RequestState.DECODE)
        queued = pf_tokens + dec_tokens
        if queued <= 0:
            return 0, 0, 0
        t_pf = self.acc.measure_iteration(
            self.executor, self.prefill_profile, max(pf_tokens, 1),
            key=self.prefill_key)
        t_dec = self.acc.measure_iteration(
            self.executor, self.decode_profile, max(dec_tokens, 1),
            key=self.decode_key)
        t_iter = (pf_tokens * t_pf + dec_tokens * t_dec) / queued
        if hasattr(self.acc, "decide"):
            # One engine query per tick: cores + chunk in a single traced
            # decision (equivalent to the two customization-point calls
            # below — decide() is what both of them derive from).
            d = self.acc.decide(self.executor, t_iter, queued,
                                key=self.tick_key,
                                evidence=(self.prefill_key,
                                          self.decode_key))
            cores, chunk = d.n_cores, d.chunk_elems
        else:
            cores = self.acc.processing_units_count(self.executor, t_iter,
                                                    queued)
            chunk = self.acc.get_chunk_size(self.executor, t_iter, cores,
                                            queued)
        return queued, max(cores, 1), max(chunk, 1)

    # -- prefill -------------------------------------------------------------
    def _bucket(self, step: int) -> int:
        """Smallest bucket >= step (the compiled-width set); steps above
        the largest bucket are clamped down to it."""
        for b in self.chunk_buckets:
            if b >= step:
                return b
        return self.chunk_buckets[-1]

    def _segment(self, req: Request, chunk: int) -> int:
        """Next prefill piece for ``req``: the decided chunk, clamped to
        the remaining prompt, never crossing a ring-buffer (SWA) window
        boundary, and never wider than the largest compile bucket."""
        step = min(max(chunk, 1), req.remaining_prefill,
                   self.chunk_buckets[-1])
        if self.window is not None:
            pos = self.pool.positions[req.slot]
            step = min(step, self.window - pos % self.window)
        return step

    def _prefill_step(self, length: int):
        fn = self._prefill_jit.get(length)
        if fn is None:
            cfg, window = self.cfg, self.window

            def prefill_chunk(params, row_caches, piece, pos, last):
                with flags.kernel_tuner(self.kernel_tuner
                                        or flags.KERNEL_TUNER):
                    return lm.forward_cached(params, piece, row_caches, pos,
                                             cfg, window=window,
                                             logit_index=last)

            fn = jax.jit(prefill_chunk)
            self._prefill_jit[length] = fn
        return fn

    def _run_prefill(self, cores: int, chunk: int):
        ready = [r for r in self._active if r.state is RequestState.PREFILL]
        if not ready or chunk <= 0:
            return [], []
        # n_cores ↔ how many requests advance this tick (batching width).
        width = min(max(cores, 1), len(ready))
        ops = []
        for req in ready[:width]:
            step = self._segment(req, chunk)
            padded = self._bucket(step) if self._pad_ok else step
            if padded > self.max_len - req.prefilled:
                padded = step    # no room to pad: exact-size chunk
            ops.append((req, step, padded))

        if self.paged:
            # Page management is the ``serve_page_size`` decision's T0:
            # allocate/CoW the pages this wave will write, timed and fed
            # back (``serve_page_mgmt``) so the next pool's page size is
            # decided from measured cost, not the analytic prior.
            t_pg = time.perf_counter()
            for req, _, padded in ops:
                self.pool.ensure_writable(
                    req.slot, req.prefilled, req.prefilled + padded)
            model = self.decision_model()
            if model is not None:
                model.observe(self.page_mgmt_key, len(ops),
                              max(time.perf_counter() - t_pg, 0.0))

        pool, params = self.pool, self.params

        def run_chunk(chunk: Chunk):
            req, step, padded = ops[chunk.start]
            piece = jax.lax.dynamic_slice_in_dim(
                req.tokens, req.prefilled, step)
            if padded > step:
                piece = jnp.pad(piece, (0, padded - step))
            row = pool.read_slot(req.slot)
            # Synchronise inside the thunk: the executor times this call
            # for the feedback loop, and an async jit dispatch would
            # record microseconds of launch cost as the chunk's t_iter.
            return jax.block_until_ready(  # repro-lint: disable=RL002
                self._prefill_step(padded)(
                    params, row, piece[None], jnp.int32(req.prefilled),
                    jnp.int32(step - 1)))

        # Feedback only sees warm shapes: a tick whose ops include a
        # never-executed chunk width runs untimed (it compiles).
        if all(padded in self._warm_prefill for _, _, padded in ops):
            tag_workload(run_chunk, self.prefill_key)
        t_dev = time.perf_counter()
        futs = self.executor.bulk_async_execute(
            run_chunk, [Chunk(i, step) for i, (_, step, _) in enumerate(ops)])
        outs = when_all(futs).result()
        self._blocked_s += time.perf_counter() - t_dev
        self.host_roundtrips += 1
        self._warm_prefill.update(padded for _, _, padded in ops)

        # Cache writes and state transitions happen on the caller's
        # thread, after the join — chunk thunks never mutate the pool.
        prefill_ops, finished = [], []
        for (req, step, padded), (logits, new_row) in zip(ops, outs,
                                                          strict=True):
            if self.paged:
                # Scatter only the freshly-computed range into the
                # slot's pages: rows before ``prefilled`` may belong to
                # a shared (read-only) prefix.
                self.pool.write_slot(req.slot, new_row, req.prefilled,
                                     req.prefilled + padded)
            else:
                self.pool.write_slot(req.slot, new_row)
            req.prefilled += step
            self.pool.positions[req.slot] = req.prefilled
            prefill_ops.append((req.rid, step))
            if req.remaining_prefill == 0:
                # First-token sync: the scheduler needs this token on the
                # host to route the request into decode.  Explicit so the
                # strict-mode transfer guard stays armed for the rest.
                tok = int(jax.device_get(  # repro-lint: disable=RL002
                    jnp.argmax(logits[0, 0])))
                req.out.append(tok)
                req.first_token_at = self.clock()
                req.state = RequestState.DECODE
                if self.paged and req.host_tokens is not None:
                    # Publish the freshly-prefilled prompt's pages into
                    # the prefix cache (refcounted, shared read-only;
                    # the slot's own next write CoW-copies the tail).
                    self.pool.register_prefix(req.slot, req.host_tokens)
                if len(req.out) >= req.max_new_tokens:
                    self._finish(req)
                    finished.append(req.rid)
                elif self._fused:
                    # The host knows this slot's next input token; the
                    # device carry learns it at the next dispatch.
                    self._tok_overrides[req.slot] = tok
                    if self._spec:
                        # Seed the slot's history ring with the prompt
                        # tail + first token: the n-gram proposer drafts
                        # from it at the very first speculative dispatch.
                        seed = list(req.host_tokens or ()) + [tok]
                        self._hist_overrides[req.slot] = \
                            seed[-self.spec_history:]
        return prefill_ops, finished

    # -- decode (per-tick path) ---------------------------------------------
    def _decode_step(self):
        if self._decode_jit is None:
            # The per-lane step is shared with the fused loop
            # (decode_loop.make_lane_step), so the two paths cannot
            # drift numerically — token identity is by construction.
            lanes = make_lane_step(self.cfg, window=self.window,
                                   kernel_tuner=self.kernel_tuner)

            def decode_all(params, caches, toks, poss, active):
                next_toks, new_caches = lanes(params, caches, toks, poss)
                return next_toks, masked_merge(caches, new_caches, active)

            self._decode_jit = jax.jit(decode_all)
        return self._decode_jit

    def _run_decode(self):
        decs = [r for r in self._active if r.state is RequestState.DECODE]
        if not decs:
            return [], []
        n = self.pool.n_slots
        toks = [0] * n
        active = [False] * n
        for r in decs:
            toks[r.slot] = r.out[-1]
            active[r.slot] = True
        step = self._decode_step()
        pool, params = self.pool, self.params
        toks_a = jnp.asarray(toks, jnp.int32)
        poss_a = pool.positions_array()
        active_a = jnp.asarray(active, dtype=bool)

        def run_decode(_):
            # Synchronised for the same reason as the prefill thunks.
            return jax.block_until_ready(
                step(params, pool.caches, toks_a, poss_a, active_a))

        if self._warm_decode:   # cold call compiles; keep it untimed
            tag_workload(run_decode, self.decode_key, elems=len(decs))
        t_dev = time.perf_counter()
        fut = self.executor.then_execute(run_decode, Future.ready(None))
        self._warm_decode = True
        next_toks, new_caches = fut.result()
        self.pool.caches = new_caches
        next_toks = jax.device_get(next_toks)
        self._blocked_s += time.perf_counter() - t_dev
        self.decode_dispatches += 1
        self.decode_tokens += len(decs)
        self.decode_loop_iters += 1
        self.host_roundtrips += 2   # block_until_ready + device_get

        decoded, finished = [], []
        for r in decs:
            self.pool.positions[r.slot] += 1
            r.out.append(int(next_toks[r.slot]))
            decoded.append(r.rid)
            if len(r.out) >= r.max_new_tokens \
                    or self.pool.positions[r.slot] >= self.max_len:
                self._finish(r)
                finished.append(r.rid)
        return decoded, finished

    # -- decode (fused path) -------------------------------------------------
    def _fused_step(self):
        if self._fused_jit is None:
            if self.paged:
                self._fused_jit = make_paged_decode_step(
                    self.cfg, page_size=self.pool.page_size,
                    max_len=self.max_len,
                    kernel_tuner=self.kernel_tuner,
                    max_depth=self.max_dispatch_depth,
                    cache_shardings=self.pool.shardings)
            else:
                self._fused_jit = make_fused_decode_step(
                    self.cfg, window=self.window,
                    kernel_tuner=self.kernel_tuner,
                    max_depth=self.max_dispatch_depth,
                    cache_shardings=self.pool.shardings)
        return self._fused_jit

    def _spec_step(self, depth: int):
        """The compiled speculative fused step for draft window
        ``depth`` (one executable per depth — the verify width is a
        static shape; the tiny dict caches them across the adaptive
        re-decisions)."""
        fn = self._spec_jit.get(depth)
        if fn is None:
            if self.paged:
                fn = make_paged_spec_decode_step(
                    self.cfg, depth=depth,
                    page_size=self.pool.page_size, max_len=self.max_len,
                    history=self.spec_history,
                    kernel_tuner=self.kernel_tuner,
                    max_depth=self.max_dispatch_depth,
                    cache_shardings=self.pool.shardings)
            else:
                fn = make_spec_decode_step(
                    self.cfg, depth=depth, history=self.spec_history,
                    window=self.window, kernel_tuner=self.kernel_tuner,
                    max_depth=self.max_dispatch_depth,
                    cache_shardings=self.pool.shardings)
            self._spec_jit[depth] = fn
        return fn

    def _decode_hist(self) -> jax.Array:
        """The device-resident per-lane token-history ring the n-gram
        proposer drafts from, with any host-known seeds (prompt tails
        captured at prefill completion) spliced in — same dense-where
        splice rationale as ``_decode_toks``."""
        n, h = self.pool.n_slots, self.spec_history
        if self._dev_hist is None:
            self._dev_hist = jnp.full((n, h), -1, jnp.int32)
        if self._hist_overrides:
            mask = [False] * n
            vals = [[-1] * h for _ in range(n)]
            for slot, seed in self._hist_overrides.items():
                mask[slot] = True
                tail = list(seed[-h:])
                vals[slot] = [-1] * (h - len(tail)) + tail
            self._dev_hist = jnp.where(
                jnp.asarray(mask)[:, None],
                jnp.asarray(vals, jnp.int32), self._dev_hist)
            self._hist_overrides.clear()
        return self._dev_hist

    def _decide_spec_depth(self) -> int:
        """Speculation width for the next dispatches — the
        ``serve_spec_depth`` decision.  Fixed widths are traced as such;
        ``auto`` asks the engine to trade expected accepted tokens per
        verify (geometric in the acceptance rate) against the wider
        verify's cost, seeded from the analytic prior acceptance before
        any drain has observed real accept/reject behaviour and refined
        online afterwards (``serve_spec_accept`` EMAs the acceptance
        rate itself, recovered at each drained dispatch's own width —
        see ``_drain``).  Widening is hysteretic (one candidate rung per
        decision); collapsed acceptance forces depth 1 — speculation
        backs off to plain fused decode."""
        cap = min(self.max_spec_depth, self.max_dispatch_depth)
        model = self.decision_model()
        if self.speculate != "auto":
            d = max(min(int(self.speculate), cap), 1)
            if model is not None:
                model.note(self.spec_depth_key, policy="fixed-spec-depth",
                           cores=1, chunk=d, inputs=(("fixed", True),))
            return d
        if model is None:     # static params object: no store to consult
            return min(2, cap)
        evidence = (self.spec_accept_key, self.spec_step_key,
                    self.fused_key)
        inputs: tuple = ()
        ema = model.smoothed_t_iter(self.spec_accept_key)
        if ema is None:
            accept = DEFAULT_SPEC_ACCEPT
            inputs += (("seeded", True),)
        else:
            # The EMA already holds the acceptance rate (recovered at
            # each dispatch's own width in ``_drain``) — no inversion.
            accept = min(max(float(ema), 0.0), 0.999)
            inputs += (("accept_ema", round(float(ema), 4)),)
        step = model.smoothed_t_iter(self.spec_step_key) \
            or model.smoothed_t_iter(self.fused_key) or 0.0
        cands = tuple(c for c in SPEC_DEPTH_CANDIDATES if c <= cap) \
            or (1,)
        # Width cost measured, not assumed: the EMA'd speculative round
        # seconds over the EMA'd width-1 iteration seconds prices the
        # wider verify on *this* host and config (on CPU a width-2 GEMM
        # can even beat the width-1 GEMV — the static prior cannot know
        # that).  Falls back to the analytic prior until both step EMAs
        # hold samples.
        kwargs: dict = {}
        spec_s = model.smoothed_t_iter(self.spec_step_key)
        iter_s = model.smoothed_t_iter(self.fused_iter_key)
        if spec_s and iter_s:
            d_ref = max(self._spec_depth, 2)
            wc = (spec_s / iter_s - 1.0) / (d_ref - 1.0)
            kwargs["width_cost"] = min(max(wc, 0.0), 1.0)
            inputs += (("width_cost_online", True),)
        decision = model.spec_depth(
            self.spec_depth_key, candidates=cands, accept_rate=accept,
            step_s=step, max_depth=cap, current=self._spec_depth,
            evidence=evidence, inputs=inputs, **kwargs)
        return decision.chunk

    def spec_stats(self) -> dict:
        """Cumulative speculation telemetry (benchmarks and the serve
        CLI surface it): verify events, tokens they emitted, loop
        rounds, the tokens-per-verify ratio, the EMA'd acceptance rate
        (per-dispatch-width samples; inverted from tpv only when no
        decision store is attached), and the width itself."""
        tpv = self.spec_emitted / self.spec_verifies \
            if self.spec_verifies else 0.0
        d = self._spec_depth if self._spec else 0
        model = self.decision_model()
        ema = model.smoothed_t_iter(self.spec_accept_key) \
            if model is not None else None
        accept = float(ema) if ema is not None \
            else ((tpv - 1.0) / (d - 1.0) if d >= 2 and tpv else 0.0)
        return {"enabled": self._spec, "depth": d,
                "verifies": self.spec_verifies,
                "emitted": self.spec_emitted,
                "rounds": self.spec_rounds,
                "tokens_per_verify": tpv,
                "acceptance_rate": max(accept, 0.0)}

    def decode_cost_analysis(self) -> dict | None:
        """Per-device XLA costs of one decode loop iteration: flops,
        HBM bytes accessed, and collective wire bytes (analysis/roofline
        conventions; ``cost_analysis()`` is per-device, and a
        ``fori_loop`` body is counted once — i.e. the numbers are per
        iteration, so achieved TFLOP/s = flops × ``decode_loop_iters`` /
        makespan).  Lowering reuses the already-compiled executable via
        the jit cache; None when nothing has compiled cleanly."""
        from ..analysis import roofline

        n = self.pool.n_slots
        toks = jnp.zeros(n, jnp.int32)
        poss = self.pool.positions_array()
        try:
            if self._fused and self._spec and self._spec_depth >= 2:
                # Speculative hot path: cost the spec step's loop body
                # (one verify round — ``decode_loop_iters`` counts
                # exactly those rounds on this path).
                pt = (self.pool.page_table_array(),) if self.paged else ()
                hist = jnp.full((n, self.spec_history), -1, jnp.int32)
                lowered = self._spec_step(self._spec_depth).lower(
                    self.params, self.pool.caches, *pt, hist, toks,
                    poss, jnp.zeros(n, jnp.int32))
            elif self._fused and self.paged:
                lowered = self._fused_step().lower(
                    self.params, self.pool.caches,
                    self.pool.page_table_array(), toks, poss,
                    jnp.zeros(n, jnp.int32))
            elif self._fused:
                lowered = self._fused_step().lower(
                    self.params, self.pool.caches, toks, poss,
                    jnp.zeros(n, jnp.int32))
            else:
                lowered = self._decode_step().lower(
                    self.params, self.pool.caches, toks, poss,
                    jnp.zeros(n, dtype=bool))
            flops, byts, wire, _ = roofline.extract_costs(
                lowered.compile())
        except Exception:       # pragma: no cover - backend-dependent
            return None
        return {"flops_per_device": flops,
                "hbm_bytes_per_device": byts,
                "collective_wire_bytes_per_device": wire,
                "n_devices": 1 if self.mesh is None
                else self.mesh.devices.size}

    def _decode_toks(self) -> jax.Array:
        """The device-resident last-token carry, with any host-known
        updates (prefill-emitted first tokens) spliced in.  The splice
        is a dense ``where`` over the (tiny) slot vector — a scatter
        with dynamic indices costs a two-orders-of-magnitude larger
        one-time compile for no win at this size."""
        if self._dev_toks is None:
            self._dev_toks = jnp.zeros(self.pool.n_slots, jnp.int32)
        if self._tok_overrides:
            n = self.pool.n_slots
            mask = [False] * n
            vals = [0] * n
            for slot, tok in self._tok_overrides.items():
                mask[slot] = True
                vals[slot] = tok
            self._dev_toks = jnp.where(jnp.asarray(mask),
                                       jnp.asarray(vals, jnp.int32),
                                       self._dev_toks)
            self._tok_overrides.clear()
        return self._dev_toks

    def _decide_depth(self, decs) -> int:
        """Tokens per dispatch for this tick — the ``serve_dispatch_depth``
        decision.  Fixed depths are traced as such; ``auto`` asks the
        engine to amortise the measured host tick overhead against the
        measured device step time (seeded, before any observation, from
        the calibrated empty-dispatch T0 plus the decision-engine
        microbench's per-query cost — the host work a tick provably
        pays)."""
        model = self.decision_model()
        if self.dispatch_depth != "auto":
            depth = min(self.dispatch_depth, self.max_dispatch_depth)
            if model is not None:
                model.note(self.depth_key, policy="fixed-depth",
                           cores=1, chunk=depth,
                           inputs=(("fixed", True),))
            return depth
        if model is None:     # static params object: no store to consult
            return min(8, self.max_dispatch_depth)
        evidence = [self.host_tick_key, self.fused_key]
        inputs: tuple = ()
        host = model.smoothed_t_iter(self.host_tick_key)
        if host is None:
            t0 = self.acc.calibrate_t0(self.executor)
            host = t0 + 4.0 * decision_overhead_s()
            inputs = (("seeded", True),)
        dev = model.smoothed_t_iter(self.fused_key)
        if dev is None:
            # Fall back to the per-tick decode key's smoothed value, or
            # the analytic roofline profile behind it.
            dev = self.acc.measure_iteration(
                self.executor, self.decode_profile, max(len(decs), 1),
                key=self.decode_key)
            evidence.append(self.decode_key)
        decision = model.dispatch_depth(
            self.depth_key, host_overhead_s=host, device_step_s=dev,
            max_depth=self.max_dispatch_depth,
            eff=getattr(self.acc, "efficiency",
                        overhead_law.DEFAULT_EFFICIENCY),
            evidence=tuple(evidence), inputs=inputs)
        return decision.chunk

    def _decide_page_size(self) -> int:
        """Construction-time ``serve_page_size`` decision: the page size
        minimising the Overhead-Law cost of the paged pool —
        per-request page management (measured ``serve_page_mgmt``, paid
        ``max_len / ps`` times) against half a page of wasted prefill
        per prompt tail (priced at the online-refined ``serve_prefill``
        t_iter).  Analytic on a cold store; once this process has
        observed real page-management waves the re-decisions on timed
        syncs carry online provenance, and the refined choice drives
        the next pool built over the same store."""
        model = self.decision_model()
        if model is None:
            return 16
        mgmt = model.smoothed_t_iter(self.page_mgmt_key) or 0.0
        pf = model.smoothed_t_iter(self.prefill_key)
        inputs: tuple = ()
        if pf is None:
            pf = self.acc.measure_iteration(
                self.executor, self.prefill_profile, self.max_len,
                key=self.prefill_key)
            inputs = (("seeded", True),)
        decision = model.page_size(
            self.page_size_key, candidates=DEFAULT_PAGE_CANDIDATES,
            max_len=self.max_len, page_mgmt_s=mgmt,
            prefill_token_s=pf or 0.0,
            evidence=(self.page_mgmt_key, self.prefill_key),
            inputs=inputs)
        return decision.chunk

    def _decide_interleave(self, chunk: int) -> int:
        """Per-tick ``serve_prefill_interleave`` decision: how many
        prefill chunk-ops fit the window the in-flight fused decode
        keeps the device busy.  The window is the online-refined fused
        per-token time × the last dispatch depth × the active decode
        lanes; one chunk costs the online-refined prefill t_iter × the
        decided chunk.  More chunks than fit stall the decode lanes
        (``prefill_stall_s``); fewer starve admission."""
        ready = sum(1 for r in self._active
                    if r.state is RequestState.PREFILL)
        cap = max(ready, 1)
        if self.prefill_interleave != "auto":
            r = min(int(self.prefill_interleave), cap)
            model = self.decision_model()
            if model is not None:
                model.note(self.interleave_key, policy="fixed-interleave",
                           cores=1, chunk=max(r, 1),
                           inputs=(("fixed", True),))
            return max(r, 1)
        model = self.decision_model()
        if model is None:
            return cap
        n_dec = sum(1 for r in self._active
                    if r.state is RequestState.DECODE)
        dev = model.smoothed_t_iter(self.fused_key) or 0.0
        window = dev * max(self._last_depth, 1) * max(n_dec, 1)
        t_pf = model.smoothed_t_iter(self.prefill_key)
        inputs: tuple = (("depth", self._last_depth), ("lanes", n_dec))
        if t_pf is None:
            t_pf = self.acc.measure_iteration(
                self.executor, self.prefill_profile, max(chunk, 1),
                key=self.prefill_key)
            inputs += (("seeded", True),)
        decision = model.prefill_interleave(
            self.interleave_key, pending_chunks=ready,
            decode_window_s=window,
            chunk_cost_s=max(t_pf or 0.0, 0.0) * max(chunk, 1),
            max_chunks=self.pool.n_slots,
            evidence=(self.fused_key, self.prefill_key,
                      self.host_tick_key),
            inputs=inputs)
        return decision.chunk

    def _dispatch_decode(self):
        """Dispatch one fused decode step (no sync): every DECODE slot
        advances by up to the decided depth, clamped to its remaining
        token budget and cache room, with finish bookkeeping done
        immediately — the tokens themselves land later via ``_drain``."""
        decs = [r for r in self._active if r.state is RequestState.DECODE]
        if not decs:
            return [], [], 0, 0, 0
        depth = self._decide_depth(decs)
        self._last_depth = depth
        spec_d = self._spec_depth if self._spec else 1
        if self._spec and self.speculate == "auto" and spec_d < 2 \
                and min(self.max_spec_depth, self.max_dispatch_depth) >= 2 \
                and self.decode_dispatches % SPEC_PROBE_EVERY == 0:
            # Exploration probe (see SPEC_PROBE_EVERY): depth 1 must not
            # be absorbing, so one window per probe period runs at width
            # 2 to keep the acceptance EMA live.
            spec_d = 2
        use_spec = spec_d >= 2
        # Under speculation every verify window is ``spec_d`` wide
        # regardless of the lane's remaining budget, so the last
        # ``spec_d - 1`` cache positions are reserved: a window must
        # never clamp its KV write over live earlier entries.  The
        # usable cache length is effectively max_len - (spec_d - 1).
        margin = spec_d - 1 if use_spec else 0
        steps = [0] * self.pool.n_slots
        lanes = []
        for r in decs:
            budget = min(r.max_new_tokens - len(r.out) - r.pending_out,
                         self.max_len - margin
                         - self.pool.positions[r.slot])
            take = max(min(depth, budget), 0)
            steps[r.slot] = take
            lanes.append((r, r.slot, take))
        if self.paged:
            # CoW/allocation must land before the dispatch reads the
            # pool, and the table upload after — the loop body's gather
            # indirection is exactly this tick's host-resolved mapping.
            # Speculation widens the writable span by the draft margin:
            # rejected drafts scatter into positions past the accepted
            # frontier, and those writes must only ever land in pages
            # this slot owns exclusively (kv_cache.rollback enforces
            # the refcount invariant).
            t_pg = time.perf_counter()
            for _, slot, take in lanes:
                if take:
                    pos = self.pool.positions[slot]
                    self.pool.ensure_writable(
                        slot, pos, min(pos + take + margin, self.max_len))
            model = self.decision_model()
            if model is not None:
                model.observe(self.page_mgmt_key, len(lanes),
                              max(time.perf_counter() - t_pg, 0.0))
        toks_a = self._decode_toks()
        poss_a = self.pool.positions_array()
        steps_a = jnp.asarray(steps, jnp.int32)
        step_id = ("spec", spec_d) if use_spec else "fused"
        fused = self._spec_step(spec_d) if use_spec else self._fused_step()
        # Periodic synced dispatch: the only way to wall-clock the
        # device step honestly is with an empty pipeline around it.
        timed = step_id in self._warm_steps and \
            self.decode_dispatches % self.sync_every == 0
        if timed:
            self._drain(drop_to=0)
        t_dev = time.perf_counter()
        pt = (self.pool.page_table_array(),) if self.paged else ()
        stats = None
        if use_spec:
            new_caches, new_hist, out_buf, final_toks, stats = fused(
                self.params, self.pool.caches, *pt, self._decode_hist(),
                toks_a, poss_a, steps_a)
        else:
            new_hist = None
            new_caches, out_buf, final_toks = fused(
                self.params, self.pool.caches, *pt, toks_a, poss_a,
                steps_a)
        self.pool.mark_donated("fused decode dispatch")
        total = sum(take for _, _, take in lanes)
        if timed:
            # The periodic honest-timing sync (one per ``sync_every``
            # dispatches) — budgeted by design, see class docstring.
            jax.block_until_ready(out_buf)  # repro-lint: disable=RL002
            dt = time.perf_counter() - t_dev
            self._blocked_s += dt
            self.host_roundtrips += 1
            model = self.decision_model()
            if model is not None and total > 0:
                model.observe(self.fused_key, total, dt)
                if stats is not None:
                    # Pipeline is empty and the buffer ready: reading
                    # the loop-round count here is the same sanctioned
                    # sync, and it prices one speculative verify round
                    # for the depth decision.
                    rounds = int(jax.device_get(  # repro-lint: disable=RL002
                        stats)[0])
                    if rounds > 0:
                        model.observe(self.spec_step_key, rounds, dt)
                else:
                    # Width-1 per-iteration cost, uncontaminated by
                    # speculation — the denominator of the online
                    # width_cost (see _decide_spec_depth).
                    iters = max((take for _, _, take in lanes),
                                default=0)
                    if iters > 0:
                        model.observe(self.fused_iter_key, iters, dt)
            if self.paged and self._page_size_auto:
                # Re-decide with whatever page-management and prefill
                # costs the run has observed by now: the trace shows the
                # layout decision upgrading analytic → online, and the
                # refined size drives the next pool over this store
                # (geometry is compiled in — it cannot change mid-run).
                self._decide_page_size()
            if self._spec and self.speculate == "auto":
                # Re-decide the speculation width with the acceptance
                # rate the drains have observed — analytic → online in
                # the trace, with backoff to 1 when acceptance collapses.
                self._spec_depth = self._decide_spec_depth()
        self._warm_fused = True
        self._warm_steps.add(step_id)
        self.pool.adopt(new_caches)
        self._dev_toks = final_toks
        if new_hist is not None:
            self._dev_hist = new_hist
        self.decode_dispatches += 1
        self.decode_tokens += total
        if stats is None:
            self.decode_loop_iters += max((take for _, _, take in lanes),
                                          default=0)
        # else: speculative loop rounds are variable — counted at drain
        # time from the dispatch's stats vector.
        self._inflight.append(
            (out_buf, stats, spec_d if use_spec else 0, lanes))

        decoded, finished = [], []
        for r, slot, take in lanes:
            self.pool.advance(slot, take)
            r.pending_out += take
            decoded.append(r.rid)
            if len(r.out) + r.pending_out >= r.max_new_tokens \
                    or self.pool.positions[slot] >= self.max_len - margin:
                self._finish(r)
                finished.append(r.rid)
        return decoded, finished, depth, total, spec_d if use_spec else 0

    def _drain(self, drop_to: int | None = None,
               harvest: bool = False) -> None:
        """Land emitted tokens from finished fused dispatches.

        ``drop_to=N`` blocks until at most ``N`` dispatches remain in
        flight (the pipeline bound); ``harvest`` additionally pops any
        buffer that is already materialised, without blocking.  One
        ``device_get`` per dispatch — the fused path's only routine
        host round-trip."""
        while self._inflight:
            must = drop_to is not None and len(self._inflight) > drop_to
            if not must:
                if not harvest:
                    break
                probe = getattr(self._inflight[0][0], "is_ready", None)
                if probe is not None and not probe():
                    break
            out_buf, stats, disp_spec_d, lanes = self._inflight.popleft()
            t_dev = time.perf_counter()
            # The fused path's one sanctioned round-trip (docstring above).
            if stats is not None:
                toks, st = jax.device_get(  # repro-lint: disable=RL002
                    (out_buf, stats))
            else:
                toks = jax.device_get(out_buf)  # repro-lint: disable=RL002
                st = None
            if must:
                self._blocked_s += time.perf_counter() - t_dev
            self.host_roundtrips += 1
            if st is not None:
                # Speculation telemetry: loop rounds actually run,
                # per-lane verify events, and tokens they emitted.  The
                # acceptance rate is recovered *here*, at the width this
                # dispatch actually ran (``disp_spec_d``), not later at
                # whatever width the scheduler has since moved to —
                # tokens-per-verify saturates at the dispatch width, so
                # inverting it at any other width mis-reads acceptance.
                # Stored as elems=verifies, seconds=accept × verifies so
                # the EMA's per-element ratio *is* the acceptance rate.
                rounds, verifies, emitted = (int(x) for x in st)
                self.decode_loop_iters += rounds
                self.spec_rounds += rounds
                self.spec_verifies += verifies
                self.spec_emitted += emitted
                model = self.decision_model()
                if model is not None and verifies > 0 \
                        and disp_spec_d >= 2:
                    a_s = (emitted / verifies - 1.0) / (disp_spec_d - 1.0)
                    # Floor keeps the sample visible to the EMA (the
                    # refiner drops zero-cost observations) while
                    # staying far below the backoff threshold.
                    a_s = min(max(a_s, 1e-3), 0.999)
                    model.observe(self.spec_accept_key, verifies,
                                  a_s * verifies)
            for req, slot, take in lanes:
                req.pending_out -= take
                if req.state is RequestState.CANCELLED:
                    # Dispatched before the cancel landed: the buffer is
                    # drained (the slot bookkeeping must balance) but
                    # the tokens are dropped, never emitted.
                    continue
                req.out.extend(
                    # ``toks`` is host numpy already — not a device sync.
                    int(toks[j, slot])  # repro-lint: disable=RL002
                    for j in range(take))
                if req.state is RequestState.DONE \
                        and req.pending_out <= 0 \
                        and req.finished_at is None:
                    req.out = req.out[:req.max_new_tokens]
                    self._stamp_finished(req)

    def flush(self) -> None:
        """Block until every in-flight fused dispatch has drained."""
        self._drain(drop_to=0)

    def _finish(self, req: Request) -> None:
        req.state = RequestState.DONE
        self.pool.release(req.slot)
        if req.pending_out <= 0:
            req.out = req.out[:req.max_new_tokens]
            self._stamp_finished(req)
        # else: the drain that lands the final tokens truncates at the
        # stop point and stamps finished_at (serve/decode_loop.py).

    def _stamp_finished(self, req: Request) -> None:
        """Stamp completion time and charge a deadline miss if the
        request's tokens landed past its deadline (SLO accounting: a
        late completion is wasted work, same as a shed)."""
        req.finished_at = self.clock()
        if req.deadline is not None and req.finished_at > req.deadline:
            self.deadline_misses += 1
            self._tick_misses += 1
