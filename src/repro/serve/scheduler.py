"""Request-level continuous-batching scheduler driven by the acc model.

The paper's executor answers "how many cores, what chunk size?" for a
parallel loop from measured ``T0`` / ``t_iter``.  Re-read for serving,
the same decision is "how many requests advance this tick, what prefill
chunk?": the *workload* is the set of currently queued tokens (remaining
prefill plus one decode token per running request), and
``AdaptiveCoreChunk.decide`` over its ``WorkloadProfile`` yields

* ``n_cores``     → how many requests' prefills advance per tick
  (devices↔batching — Eq. 7's "leave units free" becomes "don't open
  more concurrent prefills than the queue can keep efficient");
* ``chunk_elems`` → the prefill chunk size per tick (Eq. 10 with the
  T_m floor), snapped to a small bucket set so compiled shapes are
  bounded.

Timings of every prefill chunk and decode step flow back through the
executor telemetry (core/feedback.py) into the calibration cache, so the
decisions track observed drift instead of a one-shot calibration — the
continuous adaptation HPX's Smart Executors argue for.

Mechanics:

* Requests wait in an arrival queue (earliest-deadline-first, FIFO among
  equal deadlines), are admitted when a cache slot frees up
  (serve/kv_cache.py), prefill chunk-by-chunk, then decode greedily.
* Decode runs **one compiled step for the whole slot pool** regardless of
  which slots are active: per-slot positions ride in as an array, lanes
  are vmapped, and inactive lanes' cache writes are masked out — so
  requests of any length mix in one batch with zero recompilation and
  zero cache reallocation.
* Everything is deterministic under ``SequentialExecutor`` (tick trace is
  a pure function of arrivals), which is what the tests pin down.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.acc import AdaptiveCoreChunk
from ..core.executor import Chunk, SequentialExecutor
from ..core.feedback import tag_workload
from ..core.future import Future, when_all
from ..core.model import DecisionKey, ExecutionModel
from ..core.properties import params_of
from ..models import flags, lm
from ..train.autotune import serve_profiles
from .kv_cache import SlotKVCachePool

DEFAULT_CHUNK_BUCKETS = (8, 16, 32, 64, 128, 256)


def percentile(xs, p: float) -> float:
    """Latency-report percentile; NaN on empty (shared by the launch CLI
    and the throughput benchmark so their numbers can't diverge)."""
    import numpy as np

    return float(np.percentile(np.asarray(xs), p)) if len(xs) else \
        float("nan")


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle bookkeeping."""

    rid: int
    tokens: jax.Array               # (S,) int32 prompt
    max_new_tokens: int
    arrival: float
    deadline: float | None = None
    state: RequestState = RequestState.WAITING
    slot: int | None = None
    prefilled: int = 0              # prompt tokens already in the cache
    out: list[int] = dataclasses.field(default_factory=list)
    first_token_at: float | None = None
    finished_at: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def remaining_prefill(self) -> int:
        return self.prompt_len - self.prefilled


@dataclasses.dataclass(frozen=True)
class TickRecord:
    """What one scheduler tick did (the determinism tests compare these)."""

    tick: int
    admitted: tuple[int, ...]
    prefill_ops: tuple[tuple[int, int], ...]   # (rid, tokens advanced)
    decoded: tuple[int, ...]
    finished: tuple[int, ...]
    queued_tokens: int
    n_cores: int
    chunk: int


class ServeScheduler:
    """Continuous batching over a slot pool, acc-decided per tick."""

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int,
                 max_len: int, window: int | None = None,
                 executor=None, acc: AdaptiveCoreChunk | None = None,
                 chunk_buckets: Sequence[int] = DEFAULT_CHUNK_BUCKETS,
                 clock: Callable[[], float] = time.monotonic,
                 kernel_tuner=None):
        kinds = set(cfg.layer_kinds())
        if "cross_attn" in kinds:
            raise ValueError(
                "ServeScheduler does not serve cross-attention archs "
                "(per-request frontend feats); use ServeEngine's legacy "
                "batch path")
        self.cfg = cfg
        self.params = params
        self.window = window if window is not None else cfg.attn_window
        if self.window is not None and self.window <= 0:
            self.window = None
        self.max_len = max_len
        self.executor = executor if executor is not None \
            else SequentialExecutor()
        self.acc = acc or params_of(self.executor) or AdaptiveCoreChunk()
        # Measured Pallas blocks for the compiled prefill/decode steps
        # (kernels/autotune.KernelTuner); None = analytic/jnp paths.  The
        # tuner runs at jit-trace time, so each compiled shape pays at
        # most one candidate search — and none when the winner is already
        # persisted in the calibration store.
        self.kernel_tuner = kernel_tuner
        self.pool = SlotKVCachePool(cfg, n_slots, max_len,
                                    window=self.window)
        self.clock = clock
        self.chunk_buckets = tuple(sorted(set(int(b) for b in chunk_buckets
                                              if b > 0))) or (max_len,)
        # Padding prefill chunks to a bucket is only sound when every
        # layer masks by position: recurrent (SSM/xLSTM) states would
        # absorb the pad tokens, and ring (SWA) writes could wrap over
        # live entries — those archs run exact-size chunks instead.
        self._pad_ok = self.window is None and kinds <= {"attn",
                                                         "shared_attn"}
        self.prefill_profile, self.decode_profile = serve_profiles(cfg)
        # Workload keys carry the model's shape, not just its name:
        # cfg.reduced() keeps the name, and a persisted t_iter smoothed on
        # the tiny config must never drive decisions for the full one.
        sig = (cfg.name, cfg.d_model, cfg.n_layers)
        self.prefill_key = ("serve_prefill",) + sig
        self.decode_key = ("serve_decode",) + sig
        # Engine key for the per-tick decision: every tick's width/chunk
        # choice lands in the shared ExecutionModel trace under this key
        # (--explain-decisions attributes serve ticks through it).
        self.tick_key = DecisionKey("serve_tick", sig)
        self._rid = itertools.count()
        self._waiting: list[Request] = []
        self._active: list[Request] = []
        self.requests: dict[int, Request] = {}
        self.trace: list[TickRecord] = []
        self._tick = 0
        self._prefill_jit: dict[int, Any] = {}
        self._decode_jit = None
        # Shapes that have executed at least once: a cold call pays XLA
        # compilation, and seconds of compile time must never be recorded
        # as t_iter (it would seed — and persist — a poisoned EMA).
        self._warm_prefill: set[int] = set()
        self._warm_decode = False

    # ------------------------------------------------------------------ API
    def submit(self, tokens, max_new_tokens: int = 16, *,
               deadline: float | None = None,
               arrival: float | None = None) -> int:
        """Enqueue a request; returns its id.  ``tokens`` is a 1-D prompt.

        The prompt must fit the slot: prompt + generated tokens are capped
        by the pool's ``max_len``.
        """
        tokens = jnp.asarray(tokens, jnp.int32).reshape(-1)
        if tokens.shape[0] == 0:
            raise ValueError("empty prompt")
        if tokens.shape[0] >= self.max_len:
            raise ValueError(
                f"prompt of {tokens.shape[0]} tokens does not fit a "
                f"max_len={self.max_len} slot")
        rid = next(self._rid)
        req = Request(rid=rid, tokens=tokens,
                      max_new_tokens=max(int(max_new_tokens), 1),
                      arrival=self.clock() if arrival is None else arrival,
                      deadline=deadline)
        self.requests[rid] = req
        self._waiting.append(req)
        return rid

    @property
    def pending(self) -> int:
        """Requests not yet finished (waiting + running)."""
        return len(self._waiting) + len(self._active)

    def decision_model(self) -> ExecutionModel | None:
        """The ExecutionModel engine behind this scheduler's decisions
        (None when the params object carries no calibration cache, e.g.
        StaticCoreChunk)."""
        cache = getattr(self.acc, "cache", None)
        return ExecutionModel.of(cache) if cache is not None else None

    def results(self) -> dict[int, list[int]]:
        return {rid: list(r.out) for rid, r in self.requests.items()
                if r.state is RequestState.DONE}

    def clear_finished(self) -> None:
        """Drop completed requests and the tick trace.  Long-lived
        callers (the ServeEngine facade) call this after draining —
        otherwise every prompt and TickRecord ever served stays
        reachable."""
        self.requests = {rid: r for rid, r in self.requests.items()
                         if r.state is not RequestState.DONE}
        self.trace.clear()

    def run_until_idle(self, max_ticks: int = 100_000) -> dict[int, list[int]]:
        for _ in range(max_ticks):
            if not self.pending:
                return self.results()
            self.tick()
        raise RuntimeError(f"scheduler did not drain in {max_ticks} ticks")

    def warmup(self) -> None:
        """Compile the decode step and the largest prefill bucket so the
        first timed tick measures compute, not compilation."""
        self._decode_step()(
            self.params, self.pool.caches,
            jnp.zeros(self.pool.n_slots, jnp.int32),
            self.pool.positions_array(),
            jnp.zeros(self.pool.n_slots, dtype=bool))
        self._warm_decode = True
        if self._pad_ok:
            for b in self.chunk_buckets:
                if b < self.max_len:
                    row = self.pool.read_slot(0)
                    self._prefill_step(b)(
                        self.params, row, jnp.zeros((1, b), jnp.int32),
                        jnp.int32(0), jnp.int32(b - 1))
                    self._warm_prefill.add(b)

    # ----------------------------------------------------------------- tick
    def tick(self) -> TickRecord:
        """One scheduler round: admit → decide → prefill chunks → decode."""
        admitted = self._admit()
        queued, cores, chunk = self._decide()
        prefill_ops, pf_finished = self._run_prefill(cores, chunk)
        decoded, dec_finished = self._run_decode()
        finished = pf_finished + dec_finished
        self._active = [r for r in self._active
                        if r.state is not RequestState.DONE]
        rec = TickRecord(
            tick=self._tick, admitted=tuple(admitted),
            prefill_ops=tuple(prefill_ops), decoded=tuple(decoded),
            finished=tuple(finished), queued_tokens=queued,
            n_cores=cores, chunk=chunk)
        self.trace.append(rec)
        self._tick += 1
        return rec

    def _admit(self) -> list[int]:
        """Earliest-deadline-first admission into free slots; FIFO among
        requests without deadlines.  Exhausted pool ⇒ requests keep
        waiting (they are *queued*, never dropped)."""
        self._waiting.sort(key=lambda r: (
            r.deadline if r.deadline is not None else float("inf"),
            r.arrival, r.rid))
        admitted = []
        while self._waiting and self.pool.free_slots():
            req = self._waiting.pop(0)
            req.slot = self.pool.acquire(req.rid)
            req.state = RequestState.PREFILL
            self._active.append(req)
            admitted.append(req.rid)
        return admitted

    def _decide(self) -> tuple[int, int, int]:
        """(queued tokens, batch width, prefill chunk) for this tick.

        Spoken through the three customization points so any
        execution-parameters object plugs in: ``AdaptiveCoreChunk`` gives
        the Overhead-Law decision, ``StaticCoreChunk`` the fixed
        OpenMP-static split.  The queue's t_iter is the token-weighted
        mix of the prefill and decode regimes — each priced by its own
        profile, each overridden by its own online-feedback key once the
        executor has timed real chunks of that kind.
        """
        pf_tokens = sum(r.remaining_prefill for r in self._active
                        if r.state is RequestState.PREFILL)
        dec_tokens = sum(1 for r in self._active
                         if r.state is RequestState.DECODE)
        queued = pf_tokens + dec_tokens
        if queued <= 0:
            return 0, 0, 0
        t_pf = self.acc.measure_iteration(
            self.executor, self.prefill_profile, max(pf_tokens, 1),
            key=self.prefill_key)
        t_dec = self.acc.measure_iteration(
            self.executor, self.decode_profile, max(dec_tokens, 1),
            key=self.decode_key)
        t_iter = (pf_tokens * t_pf + dec_tokens * t_dec) / queued
        if hasattr(self.acc, "decide"):
            # One engine query per tick: cores + chunk in a single traced
            # decision (equivalent to the two customization-point calls
            # below — decide() is what both of them derive from).
            d = self.acc.decide(self.executor, t_iter, queued,
                                key=self.tick_key,
                                evidence=(self.prefill_key,
                                          self.decode_key))
            cores, chunk = d.n_cores, d.chunk_elems
        else:
            cores = self.acc.processing_units_count(self.executor, t_iter,
                                                    queued)
            chunk = self.acc.get_chunk_size(self.executor, t_iter, cores,
                                            queued)
        return queued, max(cores, 1), max(chunk, 1)

    # -- prefill -------------------------------------------------------------
    def _bucket(self, step: int) -> int:
        """Smallest bucket >= step (the compiled-width set); steps above
        the largest bucket are clamped down to it."""
        for b in self.chunk_buckets:
            if b >= step:
                return b
        return self.chunk_buckets[-1]

    def _segment(self, req: Request, chunk: int) -> int:
        """Next prefill piece for ``req``: the decided chunk, clamped to
        the remaining prompt, never crossing a ring-buffer (SWA) window
        boundary, and never wider than the largest compile bucket."""
        step = min(max(chunk, 1), req.remaining_prefill,
                   self.chunk_buckets[-1])
        if self.window is not None:
            pos = self.pool.positions[req.slot]
            step = min(step, self.window - pos % self.window)
        return step

    def _prefill_step(self, length: int):
        fn = self._prefill_jit.get(length)
        if fn is None:
            cfg, window = self.cfg, self.window

            def prefill_chunk(params, row_caches, piece, pos, last):
                with flags.kernel_tuner(self.kernel_tuner
                                        or flags.KERNEL_TUNER):
                    return lm.forward_cached(params, piece, row_caches, pos,
                                             cfg, window=window,
                                             logit_index=last)

            fn = jax.jit(prefill_chunk)
            self._prefill_jit[length] = fn
        return fn

    def _run_prefill(self, cores: int, chunk: int):
        ready = [r for r in self._active if r.state is RequestState.PREFILL]
        if not ready or chunk <= 0:
            return [], []
        # n_cores ↔ how many requests advance this tick (batching width).
        width = min(max(cores, 1), len(ready))
        ops = []
        for req in ready[:width]:
            step = self._segment(req, chunk)
            padded = self._bucket(step) if self._pad_ok else step
            if padded > self.max_len - req.prefilled:
                padded = step    # no room to pad: exact-size chunk
            ops.append((req, step, padded))

        pool, params = self.pool, self.params

        def run_chunk(chunk: Chunk):
            req, step, padded = ops[chunk.start]
            piece = jax.lax.dynamic_slice_in_dim(
                req.tokens, req.prefilled, step)
            if padded > step:
                piece = jnp.pad(piece, (0, padded - step))
            row = pool.read_slot(req.slot)
            # Synchronise inside the thunk: the executor times this call
            # for the feedback loop, and an async jit dispatch would
            # record microseconds of launch cost as the chunk's t_iter.
            return jax.block_until_ready(self._prefill_step(padded)(
                params, row, piece[None], jnp.int32(req.prefilled),
                jnp.int32(step - 1)))

        # Feedback only sees warm shapes: a tick whose ops include a
        # never-executed chunk width runs untimed (it compiles).
        if all(padded in self._warm_prefill for _, _, padded in ops):
            tag_workload(run_chunk, self.prefill_key)
        futs = self.executor.bulk_async_execute(
            run_chunk, [Chunk(i, step) for i, (_, step, _) in enumerate(ops)])
        outs = when_all(futs).result()
        self._warm_prefill.update(padded for _, _, padded in ops)

        # Cache writes and state transitions happen on the caller's
        # thread, after the join — chunk thunks never mutate the pool.
        prefill_ops, finished = [], []
        for (req, step, _), (logits, new_row) in zip(ops, outs):
            self.pool.write_slot(req.slot, new_row)
            req.prefilled += step
            self.pool.positions[req.slot] = req.prefilled
            prefill_ops.append((req.rid, step))
            if req.remaining_prefill == 0:
                tok = int(jnp.argmax(logits[0, 0]))
                req.out.append(tok)
                req.first_token_at = self.clock()
                req.state = RequestState.DECODE
                if len(req.out) >= req.max_new_tokens:
                    self._finish(req)
                    finished.append(req.rid)
        return prefill_ops, finished

    # -- decode --------------------------------------------------------------
    def _decode_step(self):
        if self._decode_jit is None:
            cfg, window = self.cfg, self.window

            def lane(params, row_caches, tok, pos):
                caches = jax.tree.map(
                    lambda x: None if x is None else x[None], row_caches,
                    is_leaf=lambda x: x is None)
                with flags.kernel_tuner(self.kernel_tuner
                                        or flags.KERNEL_TUNER):
                    logits, new = lm.forward_cached(
                        params, tok[None, None], caches, pos, cfg,
                        window=window)
                squeezed = jax.tree.map(
                    lambda x: None if x is None else x[0], new,
                    is_leaf=lambda x: x is None)
                return jnp.argmax(logits[0, 0], axis=-1), squeezed

            lanes = jax.vmap(lane, in_axes=(None, 0, 0, 0))

            def decode_all(params, caches, toks, poss, active):
                next_toks, new_caches = lanes(params, caches, toks, poss)
                # Masked merge: inactive lanes (free or mid-prefill
                # slots) must not see their KV rows or recurrent states
                # advanced by the garbage token their lane decoded.
                def keep(old, new):
                    if old is None:
                        return None
                    a = active.reshape((-1,) + (1,) * (old.ndim - 1))
                    return jnp.where(a, new, old)

                merged = jax.tree.map(keep, caches, new_caches,
                                      is_leaf=lambda x: x is None)
                return next_toks, merged

            self._decode_jit = jax.jit(decode_all)
        return self._decode_jit

    def _run_decode(self):
        decs = [r for r in self._active if r.state is RequestState.DECODE]
        if not decs:
            return [], []
        n = self.pool.n_slots
        toks = [0] * n
        active = [False] * n
        for r in decs:
            toks[r.slot] = r.out[-1]
            active[r.slot] = True
        step = self._decode_step()
        pool, params = self.pool, self.params
        toks_a = jnp.asarray(toks, jnp.int32)
        poss_a = pool.positions_array()
        active_a = jnp.asarray(active, dtype=bool)

        def run_decode(_):
            # Synchronised for the same reason as the prefill thunks.
            return jax.block_until_ready(
                step(params, pool.caches, toks_a, poss_a, active_a))

        if self._warm_decode:   # cold call compiles; keep it untimed
            tag_workload(run_decode, self.decode_key, elems=len(decs))
        fut = self.executor.then_execute(run_decode, Future.ready(None))
        self._warm_decode = True
        next_toks, new_caches = fut.result()
        self.pool.caches = new_caches
        next_toks = jax.device_get(next_toks)

        decoded, finished = [], []
        for r in decs:
            self.pool.positions[r.slot] += 1
            r.out.append(int(next_toks[r.slot]))
            decoded.append(r.rid)
            if len(r.out) >= r.max_new_tokens \
                    or self.pool.positions[r.slot] >= self.max_len:
                self._finish(r)
                finished.append(r.rid)
        return decoded, finished

    def _finish(self, req: Request) -> None:
        req.out = req.out[:req.max_new_tokens]
        req.finished_at = self.clock()
        req.state = RequestState.DONE
        self.pool.release(req.slot)
