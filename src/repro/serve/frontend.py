"""Asyncio serving front end over ``ServeScheduler``: streaming,
cancellation, SLO enforcement, and bounded-queue backpressure.

The scheduler (serve/scheduler.py) is a synchronous tick loop: callers
submit, call ``tick()`` until idle, then read finished outputs.  That is
the right shape for deterministic tests and benchmarks, but a serving
process faces *concurrent* callers with per-request lifecycles: tokens
must stream out as they are produced, a disconnected client must release
its cache slot immediately, a request whose deadline already passed must
be shed before its prefill burns compute, and a burst of arrivals must
hit a bounded queue — not an unbounded one that converts overload into
unbounded latency for everyone.

``ServeFrontend`` is that layer:

* **Streaming** — ``submit()`` returns a ``TokenStream`` async iterator;
  after every scheduler tick the front end pumps freshly landed tokens
  (including tokens drained from fused dispatches, scheduler
  ``pending_out``) into each request's stream.
* **Cancellation** — ``cancel()`` (or ``TokenStream.cancel()``) releases
  the request's KV slot straight back to the pool mid-prefill or
  mid-fused-dispatch; tokens already dispatched to the device are
  drained but dropped, and the pool's ``allocations==1`` donation
  invariant holds (tests pin this).
* **SLO enforcement** — the per-request ``deadline`` that has been
  sitting on ``Request`` is enforced: expired WAITING requests are shed
  before prefill (``RequestState.SHED``), late completions are counted
  as deadline misses, and both feed the per-tick ``TickRecord``
  accounting and this module's per-request ledger (``RequestRecord``) —
  the numbers SLO-goodput is computed from.
* **Backpressure** — ``max_queue`` bounds the waiting queue;
  ``submit(wait=False)`` raises ``QueueFullError`` (shed-at-the-door),
  ``wait=True`` suspends the caller until a slot frees.
* **Adaptive admission** — run the scheduler with
  ``admission="adaptive"`` and every tick's admission width becomes a
  ``serve_admission`` ExecutionModel decision (queue depth + measured
  tick time in, online-refined, visible in ``--explain-decisions``) —
  the decide→execute→observe→refine loop applied at the request level,
  the outermost layer of the stack.

The serve loop runs on the event loop (scheduler ticks are milliseconds
on the fused path; a tick's device wait is the natural scheduling
quantum).  Typed errors (``PromptTooLongError``, ``QueueFullError``)
surface at the ``submit()`` call site — a bad request is the caller's
structured rejection, never a serve-loop crash.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Any

from .scheduler import (TERMINAL_STATES, PromptTooLongError,  # noqa: F401
                        RequestState, ServeScheduler)

_DONE = object()    # stream-closed sentinel (never a token value)


class QueueFullError(RuntimeError):
    """The bounded admission queue is full (backpressure): the caller
    should retry later, degrade, or route elsewhere — queueing more
    would only convert overload into deadline misses for everyone."""

    def __init__(self, depth: int, max_queue: int):
        self.depth = depth
        self.max_queue = max_queue
        super().__init__(
            f"admission queue full ({depth} waiting, bound {max_queue})")


@dataclasses.dataclass
class RequestRecord:
    """Per-request outcome ledger (what the load harness aggregates).

    ``status``: ``pending`` → ``completed`` | ``cancelled`` | ``shed``
    | ``aborted`` (front end stopped mid-request).  ``missed`` is the
    SLO verdict: a shed request or a completion past its deadline."""

    rid: int
    submitted_at: float
    deadline: float | None
    status: str = "pending"
    tokens: int = 0
    first_token_at: float | None = None
    finished_at: float | None = None
    token_times: list = dataclasses.field(default_factory=list)
    missed: bool = False


class TokenStream:
    """Async iterator over one request's tokens.  Ends (without error)
    when the request completes, is cancelled, or is shed — inspect
    ``record.status`` to tell which."""

    def __init__(self, frontend: "ServeFrontend", rid: int):
        self.frontend = frontend
        self.rid = rid
        self._q: asyncio.Queue = asyncio.Queue()

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def cancel(self) -> bool:
        """Withdraw this request (releases its cache slot); the stream
        ends after any already-pumped tokens are consumed."""
        return await self.frontend.cancel(self.rid)

    @property
    def record(self) -> RequestRecord:
        return self.frontend.records[self.rid]


class ServeFrontend:
    """Async request front end over a ``ServeScheduler``.

    Use as an async context manager (``async with ServeFrontend(sched)
    as fe:``) or call ``start()`` / ``stop()`` explicitly.  One serve
    task ticks the scheduler while work is pending and parks on an
    event when idle; ``submit()`` wakes it.
    """

    def __init__(self, sched: ServeScheduler, *, max_queue: int = 256,
                 enforce_deadlines: bool = True):
        self.sched = sched
        self.max_queue = max(int(max_queue), 1)
        if enforce_deadlines:
            # Deadline-aware shedding before prefill (scheduler-side);
            # late-completion accounting is always on.
            sched.shed_expired = True
        self.clock = sched.clock
        self.records: dict[int, RequestRecord] = {}
        self.rejected = 0           # backpressure rejections (no rid)
        self._streams: dict[int, TokenStream] = {}
        self._emitted: dict[int, int] = {}
        self._task: asyncio.Task | None = None
        self._wake: asyncio.Event | None = None
        self._space: asyncio.Event | None = None

    # -- lifecycle -----------------------------------------------------------
    async def __aenter__(self) -> "ServeFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc: Any) -> None:
        await self.stop()

    async def start(self) -> None:
        if self._task is not None:
            return
        self._wake = asyncio.Event()
        self._space = asyncio.Event()
        self._space.set()
        self._task = asyncio.create_task(self._serve(), name="serve-loop")

    async def stop(self) -> None:
        """Stop the serve loop; land in-flight tokens and close every
        stream (consumers never hang on a stopped front end)."""
        task, self._task = self._task, None
        if task is not None:
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        self.sched.flush()
        self._pump()
        for rid in list(self._streams):
            self._close(rid, "aborted")

    # -- API -----------------------------------------------------------------
    def queue_depth(self) -> int:
        """Requests waiting for a cache slot (the bounded queue)."""
        return len(self.sched._waiting)

    async def submit(self, tokens, max_new_tokens: int = 16, *,
                     deadline: float | None = None,
                     wait: bool = False) -> TokenStream:
        """Enqueue a request and return its token stream.

        Raises ``PromptTooLongError`` (typed, per-request — the serve
        loop is unaffected) for prompts that cannot fit a slot, and
        ``QueueFullError`` when the bounded queue is full and
        ``wait=False``; with ``wait=True`` the caller suspends until
        space frees instead.
        """
        if self._task is None:
            raise RuntimeError("ServeFrontend not started "
                               "(use 'async with' or call start())")
        while self.queue_depth() >= self.max_queue:
            if not wait:
                self.rejected += 1
                raise QueueFullError(self.queue_depth(), self.max_queue)
            self._space.clear()
            await self._space.wait()
        rid = self.sched.submit(tokens, max_new_tokens, deadline=deadline)
        self.records[rid] = RequestRecord(
            rid=rid, submitted_at=self.clock(), deadline=deadline)
        stream = TokenStream(self, rid)
        self._streams[rid] = stream
        self._emitted[rid] = 0
        self._wake.set()
        return stream

    async def cancel(self, rid: int) -> bool:
        """Cancel ``rid`` mid-flight: its slot is released immediately;
        tokens it has in a not-yet-drained dispatch are dropped."""
        ok = self.sched.cancel(rid)
        if ok:
            self._pump()    # closes the stream via the sentinel
        return ok

    def stats(self) -> dict:
        """Aggregate outcome counters (SLO-goodput's raw material)."""
        recs = list(self.records.values())
        by = lambda s: sum(1 for r in recs if r.status == s)  # noqa: E731
        completed = [r for r in recs if r.status == "completed"]
        ok = [r for r in completed if not r.missed]
        return {
            "submitted": len(recs) + self.rejected,
            "completed": len(completed),
            "completed_in_slo": len(ok),
            "goodput_tokens": sum(r.tokens for r in ok),
            "cancelled": by("cancelled"),
            "shed": by("shed"),
            "rejected": self.rejected,
            "missed": sum(1 for r in recs if r.missed) + self.rejected,
            "deadline_misses": self.sched.deadline_misses,
        }

    # -- serve loop ----------------------------------------------------------
    async def _serve(self) -> None:
        while True:
            if self.sched.pending:
                self.sched.tick()
                self._pump()
                # One tick per loop turn: submitters and consumers run
                # in the gaps between device dispatches.
                await asyncio.sleep(0)
            else:
                self.sched.flush()   # land any straggler fused tokens
                self._pump()
                self._wake.clear()
                if self.sched.pending:      # raced with a submit
                    continue
                await self._wake.wait()

    def _pump(self) -> None:
        """Move freshly landed tokens into each stream; close streams
        whose requests went terminal."""
        now = self.clock()
        for rid in list(self._streams):
            req = self.sched.requests.get(rid)
            if req is None:     # cleared behind our back
                self._close(rid, "aborted")
                continue
            rec = self.records[rid]
            seen = self._emitted[rid]
            fresh = req.out[seen:]
            if fresh:
                if rec.first_token_at is None:
                    rec.first_token_at = req.first_token_at \
                        if req.first_token_at is not None else now
                stream = self._streams[rid]
                # Inter-token timestamps: a fused dispatch drains k
                # tokens in one burst, and stamping them all ``now``
                # would report 0ms gaps (the itl_p99 the load harness
                # aggregates).  The tokens were *produced* spread across
                # the dispatch interval, so spread their emission times
                # linearly from the request's previous stamp to now —
                # the stream consumer still receives them in order, and
                # the last token of a burst keeps the exact drain time.
                prev = rec.token_times[-1] if rec.token_times \
                    else rec.first_token_at
                span = max(now - prev, 0.0)
                k = len(fresh)
                for i, tok in enumerate(fresh, start=1):
                    rec.token_times.append(prev + span * i / k)
                    stream._q.put_nowait(tok)
                rec.tokens += k
                self._emitted[rid] = seen + k
            if req.state in TERMINAL_STATES and (
                    req.state is not RequestState.DONE
                    or req.pending_out <= 0):
                self._close(rid, req.state.value, req)

    def _close(self, rid: int, status: str, req=None) -> None:
        stream = self._streams.pop(rid, None)
        self._emitted.pop(rid, None)
        if stream is not None:
            stream._q.put_nowait(_DONE)
        rec = self.records.get(rid)
        if rec is not None:
            rec.status = "completed" if status == "done" else status
            if req is not None:
                rec.finished_at = req.finished_at
            if rec.status == "completed":
                rec.missed = rec.deadline is not None \
                    and rec.finished_at is not None \
                    and rec.finished_at > rec.deadline
            elif rec.status == "shed":
                rec.missed = True       # work the SLO already lost
            # cancelled/aborted: the caller withdrew — not an SLO miss
        if self._space is not None \
                and self.queue_depth() < self.max_queue:
            self._space.set()
