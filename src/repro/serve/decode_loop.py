"""Fused on-device decode loop: ``k`` tokens per dispatch, donated buffers.

The scheduler's legacy decode path pays the worst dispatch granularity
the paper warns about: one device round-trip per decoded token —
``block_until_ready`` + ``device_get`` every tick, plus a fresh jit
dispatch (params-pytree flatten, executor future machinery, two engine
queries) per token.  On small models the host overhead is a large
fraction of the step time, and it is *fixed per dispatch* — exactly the
``T0`` of the paper's Overhead Law, re-read along the time axis.

This module is the fused alternative: one compiled ``lax.fori_loop``
advances every slot in the pool by up to ``k`` tokens per dispatch.

* **Dynamic trip count** — the loop bound is ``max(steps)`` where
  ``steps`` rides in as data, so a single compilation serves every
  depth ``k <= max_depth`` (no per-depth recompiles; ``fori_loop`` with
  a traced bound lowers to ``while``).
* **Masked early-exit** — each lane carries its remaining-step budget;
  a lane whose budget hits zero (request finished mid-loop, or its slot
  cache is full) stops merging cache writes and stops advancing, just
  like an inactive lane in the legacy per-tick step.
* **Donated slot buffers** — the whole cache pool is donated into the
  fused step (``donate_argnums``), extending the donation pattern of
  ``SlotKVCachePool.write_slot``: XLA aliases the output pool into the
  input buffers, so a decode dispatch allocates no new cache storage.
* **Device-resident token chain** — the final per-lane tokens come back
  as a device array that feeds the *next* dispatch directly, so the
  host never has to sync a token to keep the loop going; emitted tokens
  are drained asynchronously by the scheduler.

Token semantics are identical to the per-tick path: the per-lane step is
the same ``lane`` computation (shared with ``ServeScheduler``'s legacy
``_decode_step`` via ``make_lane_step``), greedy argmax, same masked
cache merge — a lane may compute garbage past its stop point but never
merges or emits it.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import flags, lm

# One compiled fused step serves every depth up to this; the scheduler
# clamps adaptive depth decisions against it.
DEFAULT_MAX_DEPTH = 32

# Self-speculative decoding (draft from the lane's own token history,
# verify in one batched forward).  The history ring is the draft
# proposer's only state; the candidate depths are the compiled widths
# the ``serve_spec_depth`` decision picks between.
DEFAULT_SPEC_HISTORY = 64
SPEC_DEPTH_CANDIDATES = (1, 2, 4, 8)


def make_lane_step(cfg: ArchConfig, *, window: int | None = None,
                   kernel_tuner=None) -> Callable:
    """The per-slot decode lane, vmapped over the pool.

    ``lanes(params, caches, toks, poss) -> (next_toks, new_caches)``
    where every leading dim is ``n_slots``.  Both the legacy per-tick
    step and the fused loop body call exactly this function, so their
    per-step numerics cannot diverge.
    """

    def lane(params, row_caches, tok, pos):
        caches = jax.tree.map(
            lambda x: None if x is None else x[None], row_caches,
            is_leaf=lambda x: x is None)
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, tok[None, None], caches, pos, cfg, window=window)
        squeezed = jax.tree.map(
            lambda x: None if x is None else x[0], new,
            is_leaf=lambda x: x is None)
        return jnp.argmax(logits[0, 0], axis=-1), squeezed

    return jax.vmap(lane, in_axes=(None, 0, 0, 0))


def masked_merge(caches, new_caches, active):
    """Keep ``new`` rows only for active lanes: inactive lanes (free
    slots, mid-prefill slots, lanes past their stop point) must not see
    their KV rows or recurrent states advanced by the garbage token
    their lane computed."""

    def keep(old, new):
        if old is None:
            return None
        a = active.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(a, new, old)

    return jax.tree.map(keep, caches, new_caches,
                        is_leaf=lambda x: x is None)


def _replicated_like(shardings):
    """Fully-replicated NamedShardings over the same mesh — the
    deliberate mid-loop reshard target for the HLO-audit gate test."""
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree.map(
        lambda s: None if s is None
        else NamedSharding(s.mesh, PartitionSpec()),
        shardings, is_leaf=lambda x: x is None)


def make_fused_decode_step(cfg: ArchConfig, *, window: int | None = None,
                           kernel_tuner=None,
                           max_depth: int = DEFAULT_MAX_DEPTH,
                           cache_shardings=None,
                           _inject_reshard: bool = False) -> Callable:
    """Build the jitted fused decode step.

    ``fused(params, caches, toks, poss, steps)`` advances lane ``i`` by
    ``steps[i]`` greedy tokens (``0 <= steps[i] <= max_depth``) and
    returns ``(new_caches, out_buf, final_toks)``:

    * ``new_caches`` — the pool after all merged writes (the input pool
      is **donated**; the caller must rebind it);
    * ``out_buf``    — ``(max_depth, n_slots)`` int32; row ``j`` holds
      lane ``i``'s ``j``-th emitted token for ``j < steps[i]`` (rows at
      and past a lane's budget repeat its last token — the scheduler
      truncates at each request's stop point);
    * ``final_toks`` — each lane's last token, ready to feed the next
      dispatch without a host round-trip.

    The loop runs ``max(steps)`` iterations (a traced bound: one
    compilation for all depths), so idle lanes never stretch the trip
    count beyond the deepest active budget.

    ``cache_shardings`` (a pytree of NamedShardings mirroring the slot
    pool) pins the mesh-sharded pool's placement at loop entry with a
    sharding constraint: the donated output must alias the sharded
    input buffers exactly, and the constraint stops GSPMD from electing
    to reshard the pool across the ``fori_loop`` carry.

    ``_inject_reshard`` (tests/CI only) re-constrains the pool to fully
    replicated *inside* the loop body — the exact mid-serve reshard the
    constraint exists to prevent.  ``analysis/hlo_audit`` lowers a step
    built this way to prove its gate fails when the hazard is real;
    the scheduler never sets it.
    """
    lanes = make_lane_step(cfg, window=window, kernel_tuner=kernel_tuner)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)

        def body(j, carry):
            caches, toks, poss, rem, out_buf = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            next_toks, new_caches = lanes(params, caches, toks, poss)
            caches = masked_merge(caches, new_caches, active)
            toks = jnp.where(active, next_toks, toks)
            out_buf = out_buf.at[j].set(toks)
            step = active.astype(poss.dtype)
            return caches, toks, poss + step, rem - step, out_buf

        trip = jnp.minimum(jnp.max(steps), max_depth)
        caches, toks, _, _, out_buf = jax.lax.fori_loop(
            0, trip, body, (caches, toks, poss,
                            jnp.minimum(steps, max_depth), out_buf))
        return caches, out_buf, toks

    return jax.jit(fused, donate_argnums=(1,))


_ATTN_KINDS = ("attn", "shared_attn")


# ---------------------------------------------------------------------------
# self-speculative decoding (n-gram draft → one batched verify → rollback)
# ---------------------------------------------------------------------------

def _check_spec_arch(cfg: ArchConfig, window) -> None:
    """Speculation needs per-position rollback, which only pure
    full-attention stacks give for free: a rejected draft's KV entry
    sits past the accept point where the causal mask never reads it and
    the next verify window overwrites it.  A sliding-window ring write
    would clobber *live* entries ``window`` positions back, and a
    recurrent (SSM/xLSTM) state absorbs the draft tokens with no way to
    unwind — both are hard errors, not silent wrong tokens."""
    kinds = set(cfg.layer_kinds())
    if not kinds <= set(_ATTN_KINDS):
        raise ValueError(
            f"speculative decoding requires attention-only archs "
            f"(recurrent state cannot roll back); got {sorted(kinds)}")
    if window is not None:
        raise ValueError(
            "speculative decoding requires full attention (a ring-buffer "
            "window write would clobber live entries on rollback); got "
            f"window={window}")


def draft_from_history(hist: jax.Array, depth: int) -> jax.Array:
    """Prompt-lookup draft for one lane: ``depth - 1`` candidate tokens
    from the lane's recent token history (``hist``, oldest→newest,
    ``-1``-padded; the last entry is the lane's current carry token).

    The proposer finds the most recent earlier occurrence of the
    current *bigram* (the standard prompt-lookup heuristic: long enough
    to skip spurious single-token hits, short enough to fire on
    templated text) and proposes the tokens that followed it.  No match
    proposes the carry token repeated — drafts only ever gate *extra*
    accepted tokens, so a bad draft costs nothing but the verify width
    the ``serve_spec_depth`` decision already budgeted."""
    h = hist.shape[0]
    d = int(depth)
    j = jnp.arange(1, h - 1)
    hit = (hist[j - 1] == hist[h - 2]) & (hist[j] == hist[h - 1])
    best = jnp.max(jnp.where(hit, j, -1))
    lo = jnp.clip(best + 1, 0, h - (d - 1))
    cont = jax.lax.dynamic_slice(hist, (lo,), (d - 1,))
    fallback = jnp.full((d - 1,), hist[h - 1], hist.dtype)
    # -1 padding never matches a real token; the verify rejects it, but
    # it must not reach the embedding gather as a negative index.
    return jnp.maximum(jnp.where(best >= 0, cont, fallback), 0)


def _draft_batch(hist: jax.Array, depth: int) -> jax.Array:
    """``draft_from_history`` over all lanes at once — numerically
    identical to ``vmap(draft_from_history)`` but shaped for the hot
    loop body: the no-match fallback (carry token repeated) folds into
    the gather *indices* instead of a ``where`` over gathered values,
    so the whole draft lowers to one compare/reduce fusion plus one
    gather."""
    h = hist.shape[1]
    d = int(depth)
    j = jnp.arange(1, h - 1)
    hit = (hist[:, :-2] == hist[:, h - 2:h - 1]) \
        & (hist[:, 1:-1] == hist[:, h - 1:h])
    best = jnp.max(jnp.where(hit, j[None, :], -1), axis=1)
    lo = jnp.clip(best + 1, 0, h - (d - 1))
    k = jnp.arange(d - 1)[None, :]
    idx = jnp.where(best[:, None] >= 0, lo[:, None] + k, h - 1)
    return jnp.maximum(jnp.take_along_axis(hist, idx, axis=1), 0)


def make_spec_lane_step(cfg: ArchConfig, *, depth: int,
                        window: int | None = None,
                        kernel_tuner=None) -> Callable:
    """The per-slot *verify* lane, vmapped over the pool.

    ``lanes(params, caches, seqs, poss) -> (verified, new_caches)``:
    ``seqs`` is ``(n_slots, depth)`` — each lane's carry token followed
    by its ``depth - 1`` drafts — and ``verified`` is ``(n_slots,
    depth)``, the greedy argmax after every fed position.  One forward
    verifies all ``depth`` positions; it is the same
    ``lm.forward_cached`` the non-speculative lane runs, just fed a
    chunk, so position ``j``'s logits are byte-identical to what ``j``
    sequential steps over the same tokens would produce."""
    _check_spec_arch(cfg, window)

    def lane(params, row_caches, seq, pos):
        caches = jax.tree.map(
            lambda x: None if x is None else x[None], row_caches,
            is_leaf=lambda x: x is None)
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, seq[None], caches, pos, cfg, window=window,
                all_logits=True)
        squeezed = jax.tree.map(
            lambda x: None if x is None else x[0], new,
            is_leaf=lambda x: x is None)
        return jnp.argmax(logits[0], axis=-1), squeezed

    return jax.vmap(lane, in_axes=(None, 0, 0, 0))


def _spec_emit(drafts, verified, rem):
    """Accept/emit bookkeeping shared by both speculative loop bodies.

    ``verified[:, j]`` is the model's token after position ``j`` of the
    fed chunk; draft ``j`` is accepted iff every earlier draft matched
    (the longest-matching-prefix rule — exactly the tokens sequential
    greedy decoding would have produced, by induction).  Each active
    lane emits ``accepted + 1`` tokens (the corrected token rides on
    every verify), clamped to its remaining budget for mid-loop
    completion.  Returns ``(n_emit, new_toks)``."""
    match = (drafts == verified[:, :-1]).astype(jnp.int32)
    accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    n_emit = jnp.minimum(accepted + 1, rem)
    last = jnp.clip(n_emit - 1, 0)
    new_toks = jnp.take_along_axis(verified, last[:, None], axis=1)[:, 0]
    return n_emit, new_toks


def _shift_history(hist, verified, n_emit):
    """Shift each lane's ``n_emit`` freshly-emitted tokens into its
    history ring (oldest→newest).  ``n_emit == 0`` (inactive lane)
    slices the original ring back out unchanged.  One batched gather
    over ``concat([hist, verified])`` rather than a vmapped
    dynamic-slice: the loop body runs every round, and XLA:CPU lowers
    the dense take to a single contiguous gather."""
    full = jnp.concatenate([hist, verified], axis=1)
    idx = n_emit[:, None] + jnp.arange(hist.shape[1])[None, :]
    return jnp.take_along_axis(full, idx, axis=1)


def _spec_write_out(out_buf, verified, cursor, n_emit):
    """Write each lane's emitted tokens into its ``out_buf`` rows
    ``cursor .. cursor + n_emit - 1`` (rows the drain reads in order).
    Dense gather + ``where`` over the whole ``(max_depth, n)`` grid
    instead of a 2D scatter: XLA:CPU lowers scatters to a scalar loop,
    and this runs in the hot loop body every verify round."""
    d = verified.shape[1]
    r = jnp.arange(out_buf.shape[0])[:, None]
    idx = jnp.clip(r - cursor[None, :], 0, d - 1)
    gathered = jnp.take_along_axis(verified.T, idx, axis=0)
    mask = (r >= cursor[None, :]) & (r < (cursor + n_emit)[None, :])
    return jnp.where(mask, gathered, out_buf)


def make_spec_decode_step(cfg: ArchConfig, *, depth: int,
                          history: int = DEFAULT_SPEC_HISTORY,
                          window: int | None = None, kernel_tuner=None,
                          max_depth: int = DEFAULT_MAX_DEPTH,
                          cache_shardings=None,
                          _inject_reshard: bool = False) -> Callable:
    """Build the jitted *self-speculative* fused decode step.

    ``fused(params, caches, hist, toks, poss, steps)`` — the
    ``make_fused_decode_step`` contract plus the per-lane token-history
    ring ``hist`` (``(n_slots, history)`` int32, ``-1``-padded, last
    entry = carry token).  Each loop round drafts ``depth - 1``
    candidate tokens per lane from its history (prompt-lookup bigram
    match), verifies all ``depth`` positions in **one** batched
    forward, accepts the longest matching prefix plus the corrected
    token, and rolls each lane back to its accept point — emitted
    output is byte-identical to greedy non-speculative decoding by
    construction, the loop just covers ``steps[i]`` tokens in fewer
    rounds.  Returns ``(new_caches, hist, out_buf, final_toks, stats)``
    where ``stats`` is ``(3,)`` int32: loop rounds executed, per-lane
    verify events, tokens emitted — the drain feeds them to the
    ``serve_spec_accept`` acceptance EMA behind the next
    ``serve_spec_depth`` decision.

    Rollback inside the donated loop: a rejected draft's KV entry sits
    at a position ``>=`` the lane's rolled-back position, where the
    causal mask never reads it, and the next verify round's ``depth``
    writes start at the rolled-back position and always cover the stale
    extent (it is at most ``depth - 1`` long) — so no cache write-back
    beyond the ordinary donated merge is ever needed.  The pool is
    **donated** at position 1 exactly like the non-speculative step.
    """
    d = max(int(depth), 2)
    lanes = make_spec_lane_step(cfg, depth=d, window=window,
                                kernel_tuner=kernel_tuner)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, hist, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)
        rem0 = jnp.minimum(steps, max_depth)

        def cond(carry):
            return jnp.any(carry[4] > 0)

        def body(carry):
            caches, hist, toks, poss, rem, out_buf, lane_rounds = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            drafts = _draft_batch(hist, d)
            seqs = jnp.concatenate([toks[:, None], drafts], axis=1)
            verified, new_caches = lanes(params, caches, seqs, poss)
            caches = masked_merge(caches, new_caches, active)
            n_emit, new_toks = _spec_emit(drafts, verified, rem)
            out_buf = _spec_write_out(out_buf, verified, rem0 - rem,
                                      n_emit)
            hist = _shift_history(hist, verified, n_emit)
            toks = jnp.where(active, new_toks, toks)
            # Per-lane round counters fuse with the elementwise carry
            # updates; the stats reduces run once after the loop.
            return (caches, hist, toks, poss + n_emit, rem - n_emit,
                    out_buf, lane_rounds + active.astype(jnp.int32))

        caches, hist, toks, _, _, out_buf, lane_rounds = \
            jax.lax.while_loop(
                cond, body,
                (caches, hist, toks, poss, rem0, out_buf,
                 jnp.zeros(n, jnp.int32)))
        # A lane is active for a prefix of the loop's rounds and emits
        # >= 1 token per active round, so: loop rounds = max lane
        # rounds, verify events = their sum, and every dispatched token
        # is emitted by exit (the cond drains rem to zero).
        stats = jnp.stack([jnp.max(lane_rounds),
                           jnp.sum(lane_rounds), jnp.sum(rem0)])
        return caches, hist, out_buf, toks, stats

    return jax.jit(fused, donate_argnums=(1,))


def make_paged_spec_lane_step(cfg: ArchConfig, *, depth: int,
                              page_size: int, max_len: int,
                              kernel_tuner=None) -> Callable:
    """The per-slot speculative verify lane over a *paged* pool, vmapped:
    ``make_paged_lane_step``'s gather-view construction with the
    ``depth``-wide verify forward, returning the ``depth`` newly-written
    KV tokens per attention layer (``(H_kv, depth, D)``) for the caller
    to scatter through the page table outside the vmap."""
    _check_spec_arch(cfg, None)
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)
    d = max(int(depth), 2)

    def lane(params, pt_row, caches, seq, pos):
        idx = (pt_row[:, None] * ps
               + jnp.arange(ps, dtype=pt_row.dtype)[None, :]
               ).reshape(-1)[:max_len]
        row = [jax.tree.map(lambda x: x[idx].transpose(1, 0, 2)[None], c)
               for c in caches]
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, seq[None], row, pos, cfg, window=None,
                all_logits=True)
        outs = [jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x[0], pos, d, axis=1),
            c) for c in new]
        return jnp.argmax(logits[0], axis=-1), outs

    axes = [None if kind in _ATTN_KINDS else 0 for kind in kinds]
    return jax.vmap(lane, in_axes=(None, 0, axes, 0, 0))


def make_paged_spec_decode_step(cfg: ArchConfig, *, depth: int,
                                page_size: int, max_len: int,
                                history: int = DEFAULT_SPEC_HISTORY,
                                kernel_tuner=None,
                                max_depth: int = DEFAULT_MAX_DEPTH,
                                cache_shardings=None,
                                _inject_reshard: bool = False) -> Callable:
    """The self-speculative fused step over a paged pool:
    ``fused(params, caches, page_tables, hist, toks, poss, steps)`` —
    the ``make_spec_decode_step`` contract with the page-table
    indirection riding in as data.  Each verify round scatters its
    ``depth`` KV tokens per lane through the table; positions past a
    lane's budget window or ``max_len`` are routed to the scratch page.
    Page-refcount safety is the *caller's* pre-dispatch contract: the
    scheduler's ``ensure_writable`` covers the whole speculative window
    ``[pos, pos + take + depth - 1)``, so a rejected draft only ever
    lands in a page this slot owns exclusively — never in a shared
    prefix page."""
    d = max(int(depth), 2)
    lanes = make_paged_spec_lane_step(cfg, depth=d, page_size=page_size,
                                      max_len=max_len,
                                      kernel_tuner=kernel_tuner)
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, page_tables, hist, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        n_pages_slot = page_tables.shape[1]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)
        lane_ix = jnp.arange(n)
        rem0 = jnp.minimum(steps, max_depth)

        def cond(carry):
            return jnp.any(carry[4] > 0)

        def body(carry):
            caches, hist, toks, poss, rem, out_buf, lane_rounds = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            drafts = _draft_batch(hist, d)
            seqs = jnp.concatenate([toks[:, None], drafts], axis=1)
            verified, outs = lanes(params, page_tables, caches, seqs,
                                   poss)
            q = poss[:, None] + jnp.arange(d)[None, :]     # (n, d)
            pages = page_tables[lane_ix[:, None],
                                jnp.clip(q // ps, 0, n_pages_slot - 1)]
            ok = active[:, None] & (q < max_len)
            flat_ix = jnp.where(ok, pages * ps + q % ps, 0).reshape(-1)

            def merge(kind, c, o):
                if c is None:
                    return None
                if kind in _ATTN_KINDS:
                    return jax.tree.map(
                        lambda x, v: x.at[flat_ix].set(
                            v.transpose(0, 2, 1, 3).reshape(
                                (-1,) + x.shape[1:]).astype(x.dtype)),
                        c, o)
                return masked_merge(c, o, active)

            caches = [merge(kind, c, o) for kind, c, o in
                      zip(kinds, caches, outs, strict=True)]
            n_emit, new_toks = _spec_emit(drafts, verified, rem)
            out_buf = _spec_write_out(out_buf, verified, rem0 - rem,
                                      n_emit)
            hist = _shift_history(hist, verified, n_emit)
            toks = jnp.where(active, new_toks, toks)
            # Same fused per-lane round counters as the contiguous body.
            return (caches, hist, toks, poss + n_emit, rem - n_emit,
                    out_buf, lane_rounds + active.astype(jnp.int32))

        caches, hist, toks, _, _, out_buf, lane_rounds = \
            jax.lax.while_loop(
                cond, body,
                (caches, hist, toks, poss, rem0, out_buf,
                 jnp.zeros(n, jnp.int32)))
        stats = jnp.stack([jnp.max(lane_rounds),
                           jnp.sum(lane_rounds), jnp.sum(rem0)])
        return caches, hist, out_buf, toks, stats

    return jax.jit(fused, donate_argnums=(1,))


def make_paged_lane_step(cfg: ArchConfig, *, page_size: int, max_len: int,
                         kernel_tuner=None) -> Callable:
    """The per-slot decode lane over a *paged* pool, vmapped.

    ``lanes(params, page_tables, caches, toks, poss) ->
    (next_toks, lane_outs)`` where ``caches`` is the paged pool tree
    (flat page stores for attention layers, slot-major state for
    recurrent ones) and ``page_tables`` is ``(n_slots, pages_per_slot)``
    int32.  Each lane gathers its pages into the *same contiguous
    ``(H_kv, max_len, D)`` view* the slot pool hands
    ``make_lane_step``'s lane, then runs the identical
    ``lm.forward_cached`` — byte-for-byte the contiguous computation,
    because every position past the lane's ``kv_len`` is masked to
    exactly zero weight regardless of which garbage the unmapped
    (scratch-page) gather rows carry.

    ``lane_outs`` is per-layer: the newly-written KV token ``(H_kv, D)``
    for attention layers (sliced back out of the lane's private view —
    the caller scatters it into the shared page store *outside* the
    vmap), the full new state for recurrent layers.
    """
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)

    def lane(params, pt_row, caches, tok, pos):
        idx = (pt_row[:, None] * ps
               + jnp.arange(ps, dtype=pt_row.dtype)[None, :]
               ).reshape(-1)[:max_len]

        def view(kind, c):
            if c is None:
                return None
            if kind in _ATTN_KINDS:
                return jax.tree.map(
                    lambda x: x[idx].transpose(1, 0, 2)[None], c)
            return jax.tree.map(lambda x: x[None], c)

        row = [view(kind, c) for kind, c in zip(kinds, caches,
                                                strict=True)]
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, tok[None, None], row, pos, cfg, window=None)

        def out(kind, c):
            if c is None:
                return None
            if kind in _ATTN_KINDS:
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x[0], pos, 1, axis=1)[:, 0, :], c)
            return jax.tree.map(lambda x: x[0], c)

        outs = [out(kind, c) for kind, c in zip(kinds, new, strict=True)]
        return jnp.argmax(logits[0, 0], axis=-1), outs

    axes = [None if kind in _ATTN_KINDS else 0 for kind in kinds]
    return jax.vmap(lane, in_axes=(None, 0, axes, 0, 0))


def make_paged_decode_step(cfg: ArchConfig, *, page_size: int,
                           max_len: int, kernel_tuner=None,
                           max_depth: int = DEFAULT_MAX_DEPTH,
                           cache_shardings=None,
                           _inject_reshard: bool = False) -> Callable:
    """Build the jitted fused decode step over a paged pool.

    ``fused(params, caches, page_tables, toks, poss, steps)`` — same
    contract as ``make_fused_decode_step`` with the page-table
    indirection riding in as data (loop-invariant: the host resolves
    allocation and copy-on-write *before* dispatch, so the table never
    changes mid-loop).  The pool tree is **donated** at position 1,
    exactly like the contiguous step, and per-iteration attention KV
    lands via one scatter per layer into the flat page store: active
    lanes write ``table[pos // ps] * ps + pos % ps``, inactive lanes
    are routed to the scratch page's row 0 (their garbage is never
    mapped by any table).
    """
    lanes = make_paged_lane_step(cfg, page_size=page_size,
                                 max_len=max_len,
                                 kernel_tuner=kernel_tuner)
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, page_tables, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)
        lane_ix = jnp.arange(n)

        def body(j, carry):
            caches, toks, poss, rem, out_buf = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            next_toks, outs = lanes(params, page_tables, caches, toks,
                                    poss)
            pages = page_tables[lane_ix, poss // ps]
            flat_ix = jnp.where(active, pages * ps + poss % ps, 0)

            def merge(kind, c, o):
                if c is None:
                    return None
                if kind in _ATTN_KINDS:
                    return jax.tree.map(
                        lambda x, v: x.at[flat_ix].set(v.astype(x.dtype)),
                        c, o)
                return masked_merge(c, o, active)

            caches = [merge(kind, c, o) for kind, c, o in
                      zip(kinds, caches, outs, strict=True)]
            toks = jnp.where(active, next_toks, toks)
            out_buf = out_buf.at[j].set(toks)
            step = active.astype(poss.dtype)
            return caches, toks, poss + step, rem - step, out_buf

        trip = jnp.minimum(jnp.max(steps), max_depth)
        caches, toks, _, _, out_buf = jax.lax.fori_loop(
            0, trip, body, (caches, toks, poss,
                            jnp.minimum(steps, max_depth), out_buf))
        return caches, out_buf, toks

    return jax.jit(fused, donate_argnums=(1,))
