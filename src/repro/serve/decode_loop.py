"""Fused on-device decode loop: ``k`` tokens per dispatch, donated buffers.

The scheduler's legacy decode path pays the worst dispatch granularity
the paper warns about: one device round-trip per decoded token —
``block_until_ready`` + ``device_get`` every tick, plus a fresh jit
dispatch (params-pytree flatten, executor future machinery, two engine
queries) per token.  On small models the host overhead is a large
fraction of the step time, and it is *fixed per dispatch* — exactly the
``T0`` of the paper's Overhead Law, re-read along the time axis.

This module is the fused alternative: one compiled ``lax.fori_loop``
advances every slot in the pool by up to ``k`` tokens per dispatch.

* **Dynamic trip count** — the loop bound is ``max(steps)`` where
  ``steps`` rides in as data, so a single compilation serves every
  depth ``k <= max_depth`` (no per-depth recompiles; ``fori_loop`` with
  a traced bound lowers to ``while``).
* **Masked early-exit** — each lane carries its remaining-step budget;
  a lane whose budget hits zero (request finished mid-loop, or its slot
  cache is full) stops merging cache writes and stops advancing, just
  like an inactive lane in the legacy per-tick step.
* **Donated slot buffers** — the whole cache pool is donated into the
  fused step (``donate_argnums``), extending the donation pattern of
  ``SlotKVCachePool.write_slot``: XLA aliases the output pool into the
  input buffers, so a decode dispatch allocates no new cache storage.
* **Device-resident token chain** — the final per-lane tokens come back
  as a device array that feeds the *next* dispatch directly, so the
  host never has to sync a token to keep the loop going; emitted tokens
  are drained asynchronously by the scheduler.

Token semantics are identical to the per-tick path: the per-lane step is
the same ``lane`` computation (shared with ``ServeScheduler``'s legacy
``_decode_step`` via ``make_lane_step``), greedy argmax, same masked
cache merge — a lane may compute garbage past its stop point but never
merges or emits it.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import flags, lm

# One compiled fused step serves every depth up to this; the scheduler
# clamps adaptive depth decisions against it.
DEFAULT_MAX_DEPTH = 32


def make_lane_step(cfg: ArchConfig, *, window: int | None = None,
                   kernel_tuner=None) -> Callable:
    """The per-slot decode lane, vmapped over the pool.

    ``lanes(params, caches, toks, poss) -> (next_toks, new_caches)``
    where every leading dim is ``n_slots``.  Both the legacy per-tick
    step and the fused loop body call exactly this function, so their
    per-step numerics cannot diverge.
    """

    def lane(params, row_caches, tok, pos):
        caches = jax.tree.map(
            lambda x: None if x is None else x[None], row_caches,
            is_leaf=lambda x: x is None)
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, tok[None, None], caches, pos, cfg, window=window)
        squeezed = jax.tree.map(
            lambda x: None if x is None else x[0], new,
            is_leaf=lambda x: x is None)
        return jnp.argmax(logits[0, 0], axis=-1), squeezed

    return jax.vmap(lane, in_axes=(None, 0, 0, 0))


def masked_merge(caches, new_caches, active):
    """Keep ``new`` rows only for active lanes: inactive lanes (free
    slots, mid-prefill slots, lanes past their stop point) must not see
    their KV rows or recurrent states advanced by the garbage token
    their lane computed."""

    def keep(old, new):
        if old is None:
            return None
        a = active.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(a, new, old)

    return jax.tree.map(keep, caches, new_caches,
                        is_leaf=lambda x: x is None)


def _replicated_like(shardings):
    """Fully-replicated NamedShardings over the same mesh — the
    deliberate mid-loop reshard target for the HLO-audit gate test."""
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree.map(
        lambda s: None if s is None
        else NamedSharding(s.mesh, PartitionSpec()),
        shardings, is_leaf=lambda x: x is None)


def make_fused_decode_step(cfg: ArchConfig, *, window: int | None = None,
                           kernel_tuner=None,
                           max_depth: int = DEFAULT_MAX_DEPTH,
                           cache_shardings=None,
                           _inject_reshard: bool = False) -> Callable:
    """Build the jitted fused decode step.

    ``fused(params, caches, toks, poss, steps)`` advances lane ``i`` by
    ``steps[i]`` greedy tokens (``0 <= steps[i] <= max_depth``) and
    returns ``(new_caches, out_buf, final_toks)``:

    * ``new_caches`` — the pool after all merged writes (the input pool
      is **donated**; the caller must rebind it);
    * ``out_buf``    — ``(max_depth, n_slots)`` int32; row ``j`` holds
      lane ``i``'s ``j``-th emitted token for ``j < steps[i]`` (rows at
      and past a lane's budget repeat its last token — the scheduler
      truncates at each request's stop point);
    * ``final_toks`` — each lane's last token, ready to feed the next
      dispatch without a host round-trip.

    The loop runs ``max(steps)`` iterations (a traced bound: one
    compilation for all depths), so idle lanes never stretch the trip
    count beyond the deepest active budget.

    ``cache_shardings`` (a pytree of NamedShardings mirroring the slot
    pool) pins the mesh-sharded pool's placement at loop entry with a
    sharding constraint: the donated output must alias the sharded
    input buffers exactly, and the constraint stops GSPMD from electing
    to reshard the pool across the ``fori_loop`` carry.

    ``_inject_reshard`` (tests/CI only) re-constrains the pool to fully
    replicated *inside* the loop body — the exact mid-serve reshard the
    constraint exists to prevent.  ``analysis/hlo_audit`` lowers a step
    built this way to prove its gate fails when the hazard is real;
    the scheduler never sets it.
    """
    lanes = make_lane_step(cfg, window=window, kernel_tuner=kernel_tuner)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)

        def body(j, carry):
            caches, toks, poss, rem, out_buf = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            next_toks, new_caches = lanes(params, caches, toks, poss)
            caches = masked_merge(caches, new_caches, active)
            toks = jnp.where(active, next_toks, toks)
            out_buf = out_buf.at[j].set(toks)
            step = active.astype(poss.dtype)
            return caches, toks, poss + step, rem - step, out_buf

        trip = jnp.minimum(jnp.max(steps), max_depth)
        caches, toks, _, _, out_buf = jax.lax.fori_loop(
            0, trip, body, (caches, toks, poss,
                            jnp.minimum(steps, max_depth), out_buf))
        return caches, out_buf, toks

    return jax.jit(fused, donate_argnums=(1,))


_ATTN_KINDS = ("attn", "shared_attn")


def make_paged_lane_step(cfg: ArchConfig, *, page_size: int, max_len: int,
                         kernel_tuner=None) -> Callable:
    """The per-slot decode lane over a *paged* pool, vmapped.

    ``lanes(params, page_tables, caches, toks, poss) ->
    (next_toks, lane_outs)`` where ``caches`` is the paged pool tree
    (flat page stores for attention layers, slot-major state for
    recurrent ones) and ``page_tables`` is ``(n_slots, pages_per_slot)``
    int32.  Each lane gathers its pages into the *same contiguous
    ``(H_kv, max_len, D)`` view* the slot pool hands
    ``make_lane_step``'s lane, then runs the identical
    ``lm.forward_cached`` — byte-for-byte the contiguous computation,
    because every position past the lane's ``kv_len`` is masked to
    exactly zero weight regardless of which garbage the unmapped
    (scratch-page) gather rows carry.

    ``lane_outs`` is per-layer: the newly-written KV token ``(H_kv, D)``
    for attention layers (sliced back out of the lane's private view —
    the caller scatters it into the shared page store *outside* the
    vmap), the full new state for recurrent layers.
    """
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)

    def lane(params, pt_row, caches, tok, pos):
        idx = (pt_row[:, None] * ps
               + jnp.arange(ps, dtype=pt_row.dtype)[None, :]
               ).reshape(-1)[:max_len]

        def view(kind, c):
            if c is None:
                return None
            if kind in _ATTN_KINDS:
                return jax.tree.map(
                    lambda x: x[idx].transpose(1, 0, 2)[None], c)
            return jax.tree.map(lambda x: x[None], c)

        row = [view(kind, c) for kind, c in zip(kinds, caches,
                                                strict=True)]
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            logits, new = lm.forward_cached(
                params, tok[None, None], row, pos, cfg, window=None)

        def out(kind, c):
            if c is None:
                return None
            if kind in _ATTN_KINDS:
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x[0], pos, 1, axis=1)[:, 0, :], c)
            return jax.tree.map(lambda x: x[0], c)

        outs = [out(kind, c) for kind, c in zip(kinds, new, strict=True)]
        return jnp.argmax(logits[0, 0], axis=-1), outs

    axes = [None if kind in _ATTN_KINDS else 0 for kind in kinds]
    return jax.vmap(lane, in_axes=(None, 0, axes, 0, 0))


def make_paged_decode_step(cfg: ArchConfig, *, page_size: int,
                           max_len: int, kernel_tuner=None,
                           max_depth: int = DEFAULT_MAX_DEPTH,
                           cache_shardings=None,
                           _inject_reshard: bool = False) -> Callable:
    """Build the jitted fused decode step over a paged pool.

    ``fused(params, caches, page_tables, toks, poss, steps)`` — same
    contract as ``make_fused_decode_step`` with the page-table
    indirection riding in as data (loop-invariant: the host resolves
    allocation and copy-on-write *before* dispatch, so the table never
    changes mid-loop).  The pool tree is **donated** at position 1,
    exactly like the contiguous step, and per-iteration attention KV
    lands via one scatter per layer into the flat page store: active
    lanes write ``table[pos // ps] * ps + pos % ps``, inactive lanes
    are routed to the scratch page's row 0 (their garbage is never
    mapped by any table).
    """
    lanes = make_paged_lane_step(cfg, page_size=page_size,
                                 max_len=max_len,
                                 kernel_tuner=kernel_tuner)
    kinds = tuple(cfg.layer_kinds())
    ps = int(page_size)
    max_depth = max(int(max_depth), 1)
    reshard_to = _replicated_like(cache_shardings) \
        if _inject_reshard and cache_shardings is not None else None

    def fused(params, caches, page_tables, toks, poss, steps):
        if cache_shardings is not None:
            caches = jax.lax.with_sharding_constraint(caches,
                                                      cache_shardings)
        n = toks.shape[0]
        out_buf = jnp.zeros((max_depth, n), jnp.int32)
        lane_ix = jnp.arange(n)

        def body(j, carry):
            caches, toks, poss, rem, out_buf = carry
            if reshard_to is not None:
                caches = jax.lax.with_sharding_constraint(caches,
                                                          reshard_to)
            active = rem > 0
            next_toks, outs = lanes(params, page_tables, caches, toks,
                                    poss)
            pages = page_tables[lane_ix, poss // ps]
            flat_ix = jnp.where(active, pages * ps + poss % ps, 0)

            def merge(kind, c, o):
                if c is None:
                    return None
                if kind in _ATTN_KINDS:
                    return jax.tree.map(
                        lambda x, v: x.at[flat_ix].set(v.astype(x.dtype)),
                        c, o)
                return masked_merge(c, o, active)

            caches = [merge(kind, c, o) for kind, c, o in
                      zip(kinds, caches, outs, strict=True)]
            toks = jnp.where(active, next_toks, toks)
            out_buf = out_buf.at[j].set(toks)
            step = active.astype(poss.dtype)
            return caches, toks, poss + step, rem - step, out_buf

        trip = jnp.minimum(jnp.max(steps), max_depth)
        caches, toks, _, _, out_buf = jax.lax.fori_loop(
            0, trip, body, (caches, toks, poss,
                            jnp.minimum(steps, max_depth), out_buf))
        return caches, out_buf, toks

    return jax.jit(fused, donate_argnums=(1,))
