"""Serving engine: a thin facade over the continuous-batching runtime.

``generate`` routes through ``ServeScheduler`` (serve/scheduler.py):
requests go into the arrival queue, acc decides per-tick batching and
prefill chunking, and the slot pool (serve/kv_cache.py) holds the caches.
The stateful single-batch surface (``prefill`` / ``decode`` / ``pos``)
remains for callers that drive one lock-step batch themselves — it is
also the path for cross-attention archs, whose per-request frontend
feats the scheduler does not carry.

``make_prefill_step``/``make_decode_step`` produce the jit-able pure
functions the dry-run lowers.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.acc import AdaptiveCoreChunk
from ..core.executor import SequentialExecutor
from ..core.future import Future
from ..core.properties import params_of
from ..models import flags, lm


def prefill_segments(s: int, chunk: int, *, pos: int = 0,
                     window: int | None = None) -> list[tuple[int, int]]:
    """(start, step) prefill pieces for ``s`` new tokens.

    Ring-buffer (SWA) writes must not cross the window boundary, so steps
    depend on the evolving position — a position already *on* a boundary
    gets a full-window step.  ``window`` that is None or <= 0 means no
    windowing.  The segments are guaranteed to tile [0, s) exactly.
    """
    if s < 0:
        raise ValueError(f"negative token count: {s}")
    window = None if window is None or window <= 0 else window
    chunk = max(int(chunk), 1)
    segs: list[tuple[int, int]] = []
    start = 0
    while start < s:
        step = min(chunk, s - start)
        if window is not None:
            step = min(step, window - pos % window)
        segs.append((start, step))
        pos += step
        start += step
    assert sum(step for _, step in segs) == s, (segs, s)
    return segs


def make_decode_step(cfg: ArchConfig, *, window: int | None = None,
                     kernel_tuner=None) -> Callable:
    """(params, caches, tokens (B,1), pos) → (logits (B,1,V), caches).

    ``kernel_tuner`` (an ``autotune.KernelTuner``) is applied around the
    forward at trace time, so the compiled step bakes in measured Pallas
    blocks."""

    def decode_step(params, caches, tokens, pos, frontend_feats=None):
        with flags.kernel_tuner(kernel_tuner or flags.KERNEL_TUNER):
            return lm.forward_cached(params, tokens, caches, pos, cfg,
                                     window=window,
                                     frontend_feats=frontend_feats)

    return decode_step


def make_prefill_step(cfg: ArchConfig, *, window: int | None = None,
                      attn_impl: str = "chunked") -> Callable:
    """One-shot prefill: (params, tokens (B,S)) → (last logits, caches).

    Uses the parallel (scan) forward for the hidden states, then writes
    caches chunk-by-chunk via the cached path when caches are needed.
    For the dry-run cell we lower the full-sequence forward (the compute
    shape that matters); engine.prefill() below does the cache-building
    variant for real serving."""

    def prefill_step(params, batch):
        logits, _ = lm.forward(params, batch, cfg, window=window,
                               attn_impl=attn_impl)
        return logits[:, -1:]

    return prefill_step


class ServeEngine:
    """Stateful wrapper used by the examples and integration tests."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int, max_len: int,
                 window: int | None = None,
                 acc: AdaptiveCoreChunk | None = None,
                 executor=None, kernel_tuner=None,
                 dispatch_depth: int | str | None = None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.window = window if window is not None else cfg.attn_window
        self.max_len = max_len
        self.batch = batch
        self._caches = None   # lazy: the scheduled generate() path never
        self.pos = 0          # touches the monolithic batch cache
        # v2: an AdaptiveExecutor carries the acc object; an explicit
        # ``acc=`` argument still wins for backwards compatibility.
        self.executor = executor if executor is not None \
            else SequentialExecutor()
        self.acc = acc or params_of(self.executor) or AdaptiveCoreChunk()
        # Opt-in measured Pallas blocks for prefill/decode (tentpole
        # feedback loop); None keeps the analytic/jnp paths untouched.
        self.kernel_tuner = kernel_tuner
        # Fused decode loop (serve/decode_loop.py): None = per-tick
        # decode, int = fixed tokens per dispatch, "auto" = adaptive
        # serve_dispatch_depth decisions.  Scheduler path only.
        self.dispatch_depth = dispatch_depth
        # Device mesh for sharded serving (launch/mesh.make_serve_mesh);
        # scheduler path only — the legacy lock-step batch loop stays
        # single-device.
        self.mesh = mesh
        self._decode = jax.jit(make_decode_step(
            cfg, window=self.window, kernel_tuner=kernel_tuner))
        self._sched = None   # lazily built, reused across generate() calls

    @property
    def caches(self):
        if self._caches is None:
            self._caches = lm.init_caches(self.cfg, self.batch, self.max_len,
                                          window=self.window)
        return self._caches

    @caches.setter
    def caches(self, value):
        self._caches = value

    def _prefill_segments(self, s: int, chunk: int) -> list[tuple[int, int]]:
        return prefill_segments(s, chunk, pos=self.pos, window=self.window)

    def prefill(self, tokens: jax.Array, frontend_feats=None,
                chunk: int | None = None) -> jax.Array:
        """Chunked prefill; chunk size from the acc model unless given.

        The per-chunk forward passes are chained through the executor with
        ``then_execute`` — each continuation consumes the previous chunk's
        (logits, caches, position) state, so the whole prefill is one
        future chain joined only at the end.
        """
        bsz, s = tokens.shape
        if chunk is None:
            from ..train.autotune import token_profile

            d = self.acc.decide_for_profile(
                self.executor, token_profile(self.cfg, training=False), s)
            chunk = max(min(d.chunk_elems, s), 1)

        def step_for(start: int, step: int):
            piece = tokens[:, start:start + step]

            def run(state):
                _, caches, pos = state
                with flags.kernel_tuner(self.kernel_tuner
                                        or flags.KERNEL_TUNER):
                    logits, caches = lm.forward_cached(
                        self.params, piece, caches, pos, self.cfg,
                        window=self.window, frontend_feats=frontend_feats)
                return logits, caches, pos + step

            return run

        state = Future.ready((None, self.caches, self.pos))
        for start, step in self._prefill_segments(s, chunk):
            state = self.executor.then_execute(step_for(start, step), state)
        logits, self.caches, self.pos = state.result()
        return logits

    def decode(self, tokens: jax.Array, frontend_feats=None) -> jax.Array:
        logits, self.caches = self._decode(
            self.params, self.caches, tokens, self.pos, frontend_feats)
        self.pos += tokens.shape[1]
        return logits

    def generate(self, prompt: jax.Array, n_new: int,
                 frontend_feats=None) -> jax.Array:
        """Greedy generation; returns (B, n_new) token ids.

        Routed through the continuous-batching scheduler (one request per
        prompt row, all sharing the slot pool) whenever the arch supports
        it; cross-attention archs and engines with existing cache state
        fall back to the legacy lock-step batch loop.
        """
        if frontend_feats is None and self.pos == 0 \
                and "cross_attn" not in self.cfg.layer_kinds():
            return self._generate_scheduled(prompt, n_new)
        return self._generate_legacy(prompt, n_new, frontend_feats)

    def _generate_scheduled(self, prompt: jax.Array, n_new: int) -> jax.Array:
        from .scheduler import ServeScheduler

        bsz = prompt.shape[0]
        # One scheduler per engine: its slot pool and compiled prefill/
        # decode steps are reused across generate() calls (the scheduler
        # drains fully each call, so no state leaks between them).
        if self._sched is None or self._sched.pool.n_slots < bsz:
            self._sched = ServeScheduler(
                self.cfg, self.params, n_slots=bsz, max_len=self.max_len,
                window=self.window, executor=self.executor, acc=self.acc,
                kernel_tuner=self.kernel_tuner,
                dispatch_depth=self.dispatch_depth, mesh=self.mesh)
        rids = [self._sched.submit(prompt[i], max_new_tokens=n_new)
                for i in range(bsz)]
        outs = self._sched.run_until_idle()
        tokens = jnp.asarray([outs[rid] for rid in rids], jnp.int32)
        self._sched.clear_finished()   # facade reuse must not leak history
        return tokens

    def _generate_legacy(self, prompt: jax.Array, n_new: int,
                         frontend_feats=None) -> jax.Array:
        logits = self.prefill(prompt, frontend_feats)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for _ in range(n_new):
            out.append(tok)
            logits, self.caches = self._decode(
                self.params, self.caches, tok, self.pos, frontend_feats)
            self.pos += 1
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return jnp.concatenate(out, axis=1)
