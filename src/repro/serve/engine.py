"""Serving engine: chunked prefill + batched decode with KV/SSM caches.

The acc executor drives the prefill chunk size (the workload is the
prompt; chunks are prefill segments) and — at the launch layer — how many
devices a batch occupies.  ``make_prefill_step``/``make_decode_step``
produce the jit-able pure functions the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.acc import AdaptiveCoreChunk
from ..core.executor import SequentialExecutor
from ..core.future import Future
from ..core.properties import params_of
from ..models import lm


def make_decode_step(cfg: ArchConfig, *, window: int | None = None
                     ) -> Callable:
    """(params, caches, tokens (B,1), pos) → (logits (B,1,V), caches)."""

    def decode_step(params, caches, tokens, pos, frontend_feats=None):
        return lm.forward_cached(params, tokens, caches, pos, cfg,
                                 window=window,
                                 frontend_feats=frontend_feats)

    return decode_step


def make_prefill_step(cfg: ArchConfig, *, window: int | None = None,
                      attn_impl: str = "chunked") -> Callable:
    """One-shot prefill: (params, tokens (B,S)) → (last logits, caches).

    Uses the parallel (scan) forward for the hidden states, then writes
    caches chunk-by-chunk via the cached path when caches are needed.
    For the dry-run cell we lower the full-sequence forward (the compute
    shape that matters); engine.prefill() below does the cache-building
    variant for real serving."""

    def prefill_step(params, batch):
        logits, _ = lm.forward(params, batch, cfg, window=window,
                               attn_impl=attn_impl)
        return logits[:, -1:]

    return prefill_step


class ServeEngine:
    """Stateful wrapper used by the examples and integration tests."""

    def __init__(self, cfg: ArchConfig, params, *, batch: int, max_len: int,
                 window: int | None = None,
                 acc: AdaptiveCoreChunk | None = None,
                 executor=None):
        self.cfg = cfg
        self.params = params
        self.window = window if window is not None else cfg.attn_window
        self.max_len = max_len
        self.caches = lm.init_caches(cfg, batch, max_len, window=self.window)
        self.pos = 0
        # v2: an AdaptiveExecutor carries the acc object; an explicit
        # ``acc=`` argument still wins for backwards compatibility.
        self.executor = executor if executor is not None \
            else SequentialExecutor()
        self.acc = acc or params_of(self.executor) or AdaptiveCoreChunk()
        self._decode = jax.jit(make_decode_step(cfg, window=self.window))

    def _prefill_segments(self, s: int, chunk: int) -> list[tuple[int, int]]:
        """(start, step) prefill pieces; ring-buffer writes must not cross
        the window boundary, so steps depend on the evolving position."""
        segs = []
        start, pos = 0, self.pos
        while start < s:
            step = min(chunk, s - start)
            if self.window:
                step = min(step, self.window, self.window - pos % self.window)
            segs.append((start, step))
            pos += step
            start += step
        return segs

    def prefill(self, tokens: jax.Array, frontend_feats=None,
                chunk: int | None = None) -> jax.Array:
        """Chunked prefill; chunk size from the acc model unless given.

        The per-chunk forward passes are chained through the executor with
        ``then_execute`` — each continuation consumes the previous chunk's
        (logits, caches, position) state, so the whole prefill is one
        future chain joined only at the end.
        """
        bsz, s = tokens.shape
        if chunk is None:
            from ..train.autotune import token_profile

            d = self.acc.decide_for_profile(
                self.executor, token_profile(self.cfg, training=False), s)
            chunk = max(min(d.chunk_elems, s), 1)

        def step_for(start: int, step: int):
            piece = tokens[:, start:start + step]

            def run(state):
                _, caches, pos = state
                logits, caches = lm.forward_cached(
                    self.params, piece, caches, pos, self.cfg,
                    window=self.window, frontend_feats=frontend_feats)
                return logits, caches, pos + step

            return run

        state = Future.ready((None, self.caches, self.pos))
        for start, step in self._prefill_segments(s, chunk):
            state = self.executor.then_execute(step_for(start, step), state)
        logits, self.caches, self.pos = state.result()
        return logits

    def decode(self, tokens: jax.Array, frontend_feats=None) -> jax.Array:
        logits, self.caches = self._decode(
            self.params, self.caches, tokens, self.pos, frontend_feats)
        self.pos += tokens.shape[1]
        return logits

    def generate(self, prompt: jax.Array, n_new: int,
                 frontend_feats=None) -> jax.Array:
        """Greedy generation; returns (B, n_new) token ids."""
        logits = self.prefill(prompt, frontend_feats)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for _ in range(n_new):
            out.append(tok)
            logits, self.caches = self._decode(
                self.params, self.caches, tok, self.pos, frontend_feats)
            self.pos += 1
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return jnp.concatenate(out, axis=1)
