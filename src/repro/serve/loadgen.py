"""Seeded request-trace generators for the load harness.

Three arrival processes, one fixed-seed contract: the same
``(generator, n, seed)`` always yields the identical trace, so every
scheduler configuration in ``benchmarks/load_harness.py`` replays the
exact same load and the comparison is apples-to-apples.

* ``poisson_trace``      — memoryless arrivals at a constant rate: the
  baseline open-loop assumption every queueing result starts from.
* ``bursty_trace``       — a two-state Markov-modulated Poisson process
  (calm rate / burst rate, exponential dwell in each state): the
  traffic shape that punishes greedy admission, because a burst that is
  admitted wholesale parks a wall of prefills in the slot pool.
* ``heavy_tailed_trace`` — lognormal prompt *and* output lengths: a few
  requests are orders of magnitude longer than the median, the regime
  real LM serving lives in (and the acceptance trace for this repo's
  front end).
* ``templated_trace``    — motif-tiled prompts with high n-gram
  self-overlap: the structured-output shape where a prompt-lookup
  speculative drafter earns its keep.

Every request carries an SLO deadline derived from an ``SLOModel``
(TTFT allowance plus a per-token inter-token budget — longer answers
legitimately get more time), which is what turns a replay into a
goodput measurement instead of a throughput one.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLOModel:
    """Deadline = arrival + ttft_s + per_token_s * new_tokens."""

    ttft_s: float = 0.75
    per_token_s: float = 0.06

    def deadline_offset(self, new_tokens: int) -> float:
        return self.ttft_s + self.per_token_s * max(int(new_tokens), 1)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One request in a trace: arrival offset (seconds from trace
    start), prompt/output lengths, and the absolute-offset deadline
    (None: no SLO on this request).  ``prefix_len`` > 0 marks the first
    that many prompt tokens as the trace's *shared system prompt*:
    ``materialize`` gives every such request the identical token
    content there, so a prefix-caching pool can recognise and reuse
    it."""

    arrival_s: float
    prompt_len: int
    new_tokens: int
    deadline_s: float | None
    prefix_len: int = 0
    # ``motif_len`` > 0 marks a *templated* prompt: ``materialize``
    # builds it by tiling a seeded per-request motif of that length, so
    # the token stream has high n-gram self-overlap — the regime where
    # a prompt-lookup speculative drafter gets real acceptance.
    motif_len: int = 0


def _finalize(arrivals, plens, news, slo: SLOModel | None,
              prefix_lens=None, motif_lens=None) -> list[TraceRequest]:
    out = []
    pre = prefix_lens if prefix_lens is not None else [0] * len(arrivals)
    mot = motif_lens if motif_lens is not None else [0] * len(arrivals)
    for t, p, n, x, m in zip(arrivals, plens, news, pre, mot,
                             strict=True):
        p, n = int(max(p, 1)), int(max(n, 1))
        d = None if slo is None else float(t) + slo.deadline_offset(n)
        out.append(TraceRequest(float(t), p, n, d, int(x), int(m)))
    return out


def poisson_trace(n: int, *, rate_rps: float,
                  prompt_lens: tuple[int, ...] = (8, 16, 32),
                  new_tokens: int = 12, seed: int = 0,
                  slo: SLOModel | None = SLOModel()) -> list[TraceRequest]:
    """Constant-rate Poisson arrivals, prompt lengths drawn uniformly
    from ``prompt_lens``."""
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    plens = rng.choice(np.asarray(prompt_lens), size=n)
    news = np.full(n, new_tokens)
    return _finalize(arrivals, plens, news, slo)


def bursty_trace(n: int, *, base_rate_rps: float, burst_rate_rps: float,
                 mean_dwell_s: tuple[float, float] = (1.0, 0.25),
                 prompt_lens: tuple[int, ...] = (8, 16, 32),
                 new_tokens: int = 12, seed: int = 0,
                 slo: SLOModel | None = SLOModel()) -> list[TraceRequest]:
    """Two-state MMPP: exponential dwell in a calm state
    (``base_rate_rps``) and a burst state (``burst_rate_rps``), Poisson
    arrivals at the current state's rate."""
    rng = np.random.RandomState(seed)
    rates = (float(base_rate_rps), float(burst_rate_rps))
    t, state = 0.0, 0
    next_switch = rng.exponential(mean_dwell_s[0])
    arrivals = []
    while len(arrivals) < n:
        dt = rng.exponential(1.0 / rates[state])
        if t + dt >= next_switch:
            # State flips before the next arrival lands: restart the
            # draw from the switch point at the new rate (memoryless).
            t = next_switch
            state = 1 - state
            next_switch = t + rng.exponential(mean_dwell_s[state])
            continue
        t += dt
        arrivals.append(t)
    plens = rng.choice(np.asarray(prompt_lens), size=n)
    news = np.full(n, new_tokens)
    return _finalize(arrivals, plens, news, slo)


def heavy_tailed_trace(n: int, *, rate_rps: float,
                       median_prompt: int = 12, prompt_sigma: float = 0.7,
                       median_new: int = 8, new_sigma: float = 0.6,
                       max_prompt: int = 96, max_new: int = 48,
                       seed: int = 0,
                       slo: SLOModel | None = SLOModel()
                       ) -> list[TraceRequest]:
    """Poisson arrivals with lognormal prompt and output lengths
    (median-parameterised, clipped to the slot geometry): the
    heavy-tailed length mix where a handful of long requests dominate
    the token budget."""
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    plens = np.clip(np.rint(rng.lognormal(
        math.log(median_prompt), prompt_sigma, size=n)), 1, max_prompt)
    news = np.clip(np.rint(rng.lognormal(
        math.log(median_new), new_sigma, size=n)), 1, max_new)
    return _finalize(arrivals, plens, news, slo)


def shared_prefix_trace(n: int, *, rate_rps: float, prefix_len: int = 24,
                        shared_fraction: float = 0.9,
                        median_suffix: int = 6, suffix_sigma: float = 0.7,
                        max_suffix: int = 32,
                        median_new: int = 8, new_sigma: float = 0.6,
                        max_new: int = 32, seed: int = 0,
                        slo: SLOModel | None = SLOModel()
                        ) -> list[TraceRequest]:
    """Poisson arrivals where a seeded ``shared_fraction`` of requests
    open with the *same* hot system prompt (``prefix_len`` tokens,
    identical content under ``materialize``) followed by a heavy-tailed
    lognormal unique suffix — the chatbot / RAG shape where most of
    every prompt's KV work is redundant across requests.  The workload
    a paged pool with copy-on-write prefix reuse is built for: the
    prefix is prefilled once, later requests map its pages read-only
    and only pay for their suffix."""
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    shared = rng.random_sample(n) < shared_fraction
    suffixes = np.clip(np.rint(rng.lognormal(
        math.log(median_suffix), suffix_sigma, size=n)), 1, max_suffix)
    plens = np.where(shared, prefix_len + suffixes, suffixes)
    news = np.clip(np.rint(rng.lognormal(
        math.log(median_new), new_sigma, size=n)), 1, max_new)
    prefix_lens = np.where(shared, prefix_len, 0)
    return _finalize(arrivals, plens, news, slo, prefix_lens)


def templated_trace(n: int, *, rate_rps: float, motif_len: int = 8,
                    median_prompt: int = 24, prompt_sigma: float = 0.4,
                    max_prompt: int = 96,
                    median_new: int = 12, new_sigma: float = 0.5,
                    max_new: int = 48, seed: int = 0,
                    slo: SLOModel | None = SLOModel()
                    ) -> list[TraceRequest]:
    """Poisson arrivals whose prompts are *templated*: each is a seeded
    ``motif_len``-token motif tiled out to the prompt length (see
    ``materialize``), giving the token stream high n-gram self-overlap.
    Greedy continuations of such prompts keep cycling the motif, so a
    prompt-lookup speculative drafter sees real acceptance — the trace
    the ``--speculate`` harness measures its win on (structured
    form-filling / code-completion-like load, as opposed to the
    near-zero-overlap random-token traces above)."""
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    plens = np.clip(np.rint(rng.lognormal(
        math.log(median_prompt), prompt_sigma, size=n)),
        max(motif_len, 1), max_prompt)
    news = np.clip(np.rint(rng.lognormal(
        math.log(median_new), new_sigma, size=n)), 1, max_new)
    motifs = np.full(n, max(int(motif_len), 1))
    return _finalize(arrivals, plens, news, slo, motif_lens=motifs)


GENERATORS = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "heavy": heavy_tailed_trace,
    "shared_prefix": shared_prefix_trace,
    "templated": templated_trace,
}


def materialize(trace: list[TraceRequest], vocab: int, seed: int = 0
                ) -> list[tuple[TraceRequest, np.ndarray]]:
    """Attach a seeded int32 prompt token array to every trace request
    (kept separate from generation so traces stay cheap to describe and
    compare).  Requests with ``prefix_len > 0`` share one system-prompt
    array (drawn once per call from the seed): identical head content
    is what makes the paged pool's token-hash prefix lookup hit."""
    rng = np.random.RandomState(seed ^ 0x5EED)
    max_pre = max((tr.prefix_len for tr in trace), default=0)
    shared = np.random.RandomState(seed ^ 0x5AFE).randint(
        0, vocab, size=max_pre).astype(np.int32) if max_pre else None
    out = []
    for tr in trace:
        if tr.motif_len:
            # Templated prompt: a per-request seeded motif tiled to the
            # prompt length (same (trace, seed) → same tokens contract).
            motif = rng.randint(0, vocab, size=tr.motif_len)
            reps = -(-tr.prompt_len // tr.motif_len)
            toks = np.tile(motif, reps)[:tr.prompt_len].astype(np.int32)
            out.append((tr, toks))
            continue
        toks = rng.randint(0, vocab, size=tr.prompt_len - tr.prefix_len
                           ).astype(np.int32)
        if tr.prefix_len:
            toks = np.concatenate([shared[:tr.prefix_len], toks])
        out.append((tr, toks))
    return out


def trace_summary(trace: list[TraceRequest]) -> dict:
    """Shape-of-the-load numbers for reports (duration, rates, length
    percentiles) — what BENCH_load.json records alongside the results."""
    arr = np.asarray([t.arrival_s for t in trace])
    plens = np.asarray([t.prompt_len for t in trace])
    news = np.asarray([t.new_tokens for t in trace])
    dur = float(arr[-1]) if len(arr) else 0.0
    pre = np.asarray([t.prefix_len for t in trace])
    extra = {}
    if pre.any():
        extra = {"shared_prefix_requests": int((pre > 0).sum()),
                 "shared_prefix_tokens": int(pre.sum())}
    mot = np.asarray([t.motif_len for t in trace])
    if mot.any():
        extra |= {"templated_requests": int((mot > 0).sum())}
    return extra | {
        "requests": len(trace),
        "duration_s": round(dur, 3),
        "mean_rate_rps": round(len(trace) / dur, 2) if dur else 0.0,
        "prompt_p50": int(np.percentile(plens, 50)),
        "prompt_p99": int(np.percentile(plens, 99)),
        "new_tokens_p50": int(np.percentile(news, 50)),
        "new_tokens_p99": int(np.percentile(news, 99)),
        "total_tokens": int(plens.sum() + news.sum()),
    }
