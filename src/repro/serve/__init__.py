from .decode_loop import (DEFAULT_MAX_DEPTH, DEFAULT_SPEC_HISTORY,
                          SPEC_DEPTH_CANDIDATES, draft_from_history,
                          make_fused_decode_step, make_lane_step,
                          make_paged_spec_decode_step,
                          make_spec_decode_step, masked_merge)
from .engine import (ServeEngine, make_decode_step, make_prefill_step,
                     prefill_segments)
from .frontend import (QueueFullError, RequestRecord, ServeFrontend,
                       TokenStream)
from .kv_cache import CacheLayoutError, SlotKVCachePool, SlotOverflowError
from .loadgen import (GENERATORS, SLOModel, TraceRequest, bursty_trace,
                      heavy_tailed_trace, materialize, poisson_trace,
                      shared_prefix_trace, templated_trace, trace_summary)
from .scheduler import (TERMINAL_STATES, PromptTooLongError, Request,
                        RequestState, ServeScheduler, TickRecord,
                        percentile)

__all__ = [
    "ServeEngine", "make_decode_step", "make_prefill_step",
    "prefill_segments",
    "SlotKVCachePool", "SlotOverflowError", "CacheLayoutError",
    "ServeScheduler", "Request", "RequestState", "TickRecord",
    "percentile", "PromptTooLongError", "TERMINAL_STATES",
    "ServeFrontend", "TokenStream", "RequestRecord", "QueueFullError",
    "SLOModel", "TraceRequest", "GENERATORS", "poisson_trace",
    "bursty_trace", "heavy_tailed_trace", "shared_prefix_trace",
    "templated_trace", "materialize", "trace_summary",
    "DEFAULT_MAX_DEPTH", "make_fused_decode_step", "make_lane_step",
    "masked_merge",
    "DEFAULT_SPEC_HISTORY", "SPEC_DEPTH_CANDIDATES",
    "draft_from_history", "make_spec_decode_step",
    "make_paged_spec_decode_step",
]
