from .decode_loop import (DEFAULT_MAX_DEPTH, make_fused_decode_step,
                          make_lane_step, masked_merge)
from .engine import (ServeEngine, make_decode_step, make_prefill_step,
                     prefill_segments)
from .kv_cache import SlotKVCachePool
from .scheduler import (Request, RequestState, ServeScheduler, TickRecord,
                        percentile)

__all__ = [
    "ServeEngine", "make_decode_step", "make_prefill_step",
    "prefill_segments",
    "SlotKVCachePool",
    "ServeScheduler", "Request", "RequestState", "TickRecord",
    "percentile",
    "DEFAULT_MAX_DEPTH", "make_fused_decode_step", "make_lane_step",
    "masked_merge",
]
