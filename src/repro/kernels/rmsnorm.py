"""Fused RMSNorm Pallas kernel: one HBM read, one write per row block
(the unfused jnp version reads x twice — mean, then normalise)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, o_ref, *, eps: float):
    xf = x_ref[...].astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    o_ref[...] = ((xf / rms) * g_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 128, plan=None,
                   interpret: bool = True) -> jax.Array:
    """x: (rows, d); gamma: (d,).  rows must divide by block_rows
    (ops.py pads).  An externally-chosen ``plan`` (a ``tuning.BlockPlan``,
    e.g. a measured winner from ``autotune.KernelTuner``) overrides
    ``block_rows``."""
    if plan is not None:
        block_rows = plan.block
    rows, d = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, gamma)
