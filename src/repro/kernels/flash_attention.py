"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiling: grid (batch*q_heads, q_blocks, kv_blocks) with the
kv dimension innermost; running max/denominator/accumulator live in VMEM
scratch across kv steps.  Supports causal masking, sliding windows (SWA)
and grouped KV heads (GQA) — the kv-head block index is derived from the
q-head grid index, so no HBM repeat of K/V is ever materialised.

The (block_q, block_kv) tile comes from tuning.plan_attention — the
paper's chunk-size model applied to the VMEM budget: blocks as large as
double-buffering allows (T_m floor), grid deep enough to keep the
DMA/compute pipeline full (C chunks per core).

Fully-masked tiles (above the causal diagonal / outside the window) skip
their compute via pl.when — on real hardware this removes ~half the work
for causal prefill, the structural analogue of the paper's "don't schedule
empty chunks".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
_STAT_LANES = 128  # TPU scratch wants a 128-lane trailing dim


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_kv: int, sq: int, skv: int, kv_len: int,
            nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Query positions are aligned to the *end* of the kv axis so the same
    # kernel serves training (sq == skv) and chunked prefill (sq < skv).
    q_off = iq * block_q + (kv_len - sq)
    k_off = ik * block_kv

    # Tile visibility: skip tiles that the causal diagonal or the window
    # excludes entirely (plus tiles fully in kv padding).
    visible = k_off < kv_len
    if causal:
        visible &= q_off + block_q - 1 >= k_off
    if window is not None:
        visible &= q_off - (k_off + block_kv - 1) < window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qi = q_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kj = k_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kj < kv_len
        if causal:
            mask &= qi >= kj
        if window is not None:
            mask &= (qi - kj) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Rows with no visible key yet keep m == -inf; exp of (-inf - -inf)
        # is NaN — neutralise via the mask / alpha guards below.
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, kv_len: int | None = None,
    sq_true: int | None = None,
    block_q: int = 128, block_kv: int = 128,
    plan: tuple[int, int] | None = None,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Hq % Hkv == 0.

    Sq/Skv must be multiples of the block sizes.  ops.py pads and passes
    ``kv_len`` = true kv length (padding keys masked) and ``sq_true`` =
    true q length, so real q rows keep end-aligned positions
    (row r ↦ global position r + kv_len - sq_true).  An externally-chosen
    ``plan`` — a (block_q, block_kv) pair, e.g. a measured winner from
    ``autotune.KernelTuner.plan_attention`` — overrides the block
    arguments."""
    if plan is not None:
        block_q, block_kv = plan
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else skv
    sq_true = sq_true if sq_true is not None else sq
    nq, nk = sq // block_q, skv // block_kv

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, sq=sq_true, skv=skv,
        kv_len=kv_len, nk=nk)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda g, i, j: (g // hq, g % hq, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda g, i, j: (g // hq, (g % hq) // group, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda g, i, j: (g // hq, (g % hq) // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda g, i, j: (g // hq, g % hq, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _paged_kernel(pt_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
                  hq: int, sq: int, page_size: int, nk: int):
    g = pl.program_id(0)
    j = pl.program_id(1)
    b = g // hq

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[b]
    q_off = kv_len - sq          # queries end-aligned, as in _kernel
    k_off = j * page_size

    @pl.when(k_off < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[:, 0].astype(jnp.float32)
        v = v_ref[:, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qi = q_off + jax.lax.broadcasted_iota(
            jnp.int32, (sq, page_size), 0)
        kj = k_off + jax.lax.broadcasted_iota(
            jnp.int32, (sq, page_size), 1)
        mask = kj < kv_len
        if causal:
            mask &= qi >= kj
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def paged_flash_attention_pallas(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, kv_lens: jax.Array, *,
    page_size: int, causal: bool = True, scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Flash attention reading K/V through a page table.

    ``q``: (B, Hq, Sq, D) queries, end-aligned per lane (row ``r`` of
    lane ``b`` sits at global position ``kv_lens[b] - Sq + r``).
    ``k_pages``/``v_pages``: the paged pool's flat token-major stores,
    ``(n_pages * page_size, Hkv, D)`` — page ``p`` owns rows
    ``[p*ps, (p+1)*ps)``.  ``page_table``: (B, n_blocks) int32, lane
    ``b``'s block ``j`` lives in page ``page_table[b, j]``.  ``kv_lens``:
    (B,) int32 true kv length per lane.

    The page table and lengths ride in as **scalar-prefetched**
    operands (``pltpu.PrefetchScalarGridSpec``): the BlockSpec index map
    reads ``page_table[b, j]`` to aim each kv tile's DMA directly at
    its page in HBM — the indirection costs an SMEM lookup, not a
    gather materialising the contiguous view.  With
    ``block_kv == page_size`` the tile schedule is *identical* to
    ``flash_attention_pallas`` over contiguously-laid K/V, so the two
    are byte-identical — the property the paged pool's hypothesis test
    pins (tests/test_serve_paged.py).  Pages at or past a lane's length
    skip their compute via ``pl.when`` — unmapped (scratch) entries are
    never touched, the structural "don't schedule empty chunks".

    Sliding windows are unsupported by design: the paged pool rejects
    SWA (a wrapped ring write would straddle shared pages)."""
    b, hq, sq, d = q.shape
    rows, hkv, _ = k_pages.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    ps = int(page_size)
    assert rows % ps == 0, (rows, ps)
    nk = int(page_table.shape[1])
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _paged_kernel, scale=scale, causal=causal, hq=hq, sq=sq,
        page_size=ps, nk=nk)

    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, sq, d),
                         lambda g, j, pt, lens: (g // hq, g % hq, 0, 0)),
            pl.BlockSpec((ps, 1, d),
                         lambda g, j, pt, lens:
                         (pt[g // hq, j], (g % hq) // group, 0)),
            pl.BlockSpec((ps, 1, d),
                         lambda g, j, pt, lens:
                         (pt[g // hq, j], (g % hq) // group, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, sq, d), lambda g, j, pt, lens: (g // hq, g % hq, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, d), jnp.float32),
            pltpu.VMEM((sq, _STAT_LANES), jnp.float32),
            pltpu.VMEM((sq, _STAT_LANES), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), jnp.asarray(kv_lens, jnp.int32),
      q, k_pages, v_pages)
