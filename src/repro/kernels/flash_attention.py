"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiling: grid (batch*q_heads, q_blocks, kv_blocks) with the
kv dimension innermost; running max/denominator/accumulator live in VMEM
scratch across kv steps.  Supports causal masking, sliding windows (SWA)
and grouped KV heads (GQA) — the kv-head block index is derived from the
q-head grid index, so no HBM repeat of K/V is ever materialised.

The (block_q, block_kv) tile comes from tuning.plan_attention — the
paper's chunk-size model applied to the VMEM budget: blocks as large as
double-buffering allows (T_m floor), grid deep enough to keep the
DMA/compute pipeline full (C chunks per core).

Fully-masked tiles (above the causal diagonal / outside the window) skip
their compute via pl.when — on real hardware this removes ~half the work
for causal prefill, the structural analogue of the paper's "don't schedule
empty chunks".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")
_STAT_LANES = 128  # TPU scratch wants a 128-lane trailing dim


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_kv: int, sq: int, skv: int, kv_len: int,
            nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Query positions are aligned to the *end* of the kv axis so the same
    # kernel serves training (sq == skv) and chunked prefill (sq < skv).
    q_off = iq * block_q + (kv_len - sq)
    k_off = ik * block_kv

    # Tile visibility: skip tiles that the causal diagonal or the window
    # excludes entirely (plus tiles fully in kv padding).
    visible = k_off < kv_len
    if causal:
        visible &= q_off + block_q - 1 >= k_off
    if window is not None:
        visible &= q_off - (k_off + block_kv - 1) < window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qi = q_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kj = k_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kj < kv_len
        if causal:
            mask &= qi >= kj
        if window is not None:
            mask &= (qi - kj) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Rows with no visible key yet keep m == -inf; exp of (-inf - -inf)
        # is NaN — neutralise via the mask / alpha guards below.
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, kv_len: int | None = None,
    sq_true: int | None = None,
    block_q: int = 128, block_kv: int = 128,
    plan: tuple[int, int] | None = None,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Hq % Hkv == 0.

    Sq/Skv must be multiples of the block sizes.  ops.py pads and passes
    ``kv_len`` = true kv length (padding keys masked) and ``sq_true`` =
    true q length, so real q rows keep end-aligned positions
    (row r ↦ global position r + kv_len - sq_true).  An externally-chosen
    ``plan`` — a (block_q, block_kv) pair, e.g. a measured winner from
    ``autotune.KernelTuner.plan_attention`` — overrides the block
    arguments."""
    if plan is not None:
        block_q, block_kv = plan
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = kv_len if kv_len is not None else skv
    sq_true = sq_true if sq_true is not None else sq
    nq, nk = sq // block_q, skv // block_kv

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, sq=sq_true, skv=skv,
        kv_len=kv_len, nk=nk)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda g, i, j: (g // hq, g % hq, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda g, i, j: (g // hq, (g % hq) // group, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda g, i, j: (g // hq, (g % hq) // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda g, i, j: (g // hq, g % hq, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
