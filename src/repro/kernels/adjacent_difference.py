"""Pallas TPU kernel for adjacent_difference (the paper's memory-bound
benchmark), with neighbour-block halo.

Each grid step i owns elements [i*B, (i+1)*B).  The first element of the
block needs x[i*B - 1]; rather than shifting the whole array in HBM, the
kernel receives the *previous block* as a second input (index_map i-1,
clamped at 0) — the TPU-idiomatic halo read.  Block size comes from the
adaptive plan (tuning.plan_1d), i.e. the paper's Eq. 10 on the VMEM/
pipeline level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, prev_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    prev_last = prev_ref[x.shape[0] - 1]
    # Shift x right by one within the block; position 0 gets the halo.
    shifted = jnp.concatenate([prev_last[None], x[:-1]])
    out = x - shifted
    # Block 0, element 0: out[0] = x[0] (definition) — prev block is a
    # clamped self-read there, so fix it up.
    first = jnp.where(i == 0, x[0], out[0])
    o_ref[...] = jnp.concatenate([first[None], out[1:]])


def adjacent_difference_pallas(x: jax.Array, *, block: int,
                               interpret: bool = True) -> jax.Array:
    """1-d adjacent difference.  ``x`` length must be a multiple of
    ``block`` (ops.py handles padding)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = n // block
    return pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (jnp.maximum(i - 1, 0),)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, x)
