"""Measured Pallas block autotuner — the paper's feedback loop reaching
the kernel grid.

``tuning.py`` maps the paper's quantities onto VMEM tiling analytically
(chunk → one grid step, T_m floor → minimum block).  That is the static
Overhead-Law *prior*; the paper's actual claim — and HPX Smart Executors'
result — is that **measured** per-workload overheads beat any static
formula.  This module closes that gap for the kernels themselves:

* **candidate generation** — a small neighbourhood around the analytic
  prior (halved/doubled blocks), every candidate tile-aligned and inside
  the VMEM double-buffering budget, so the search space is the set of
  plans the static model would already consider legal;
* **measurement harness** — each candidate is wall-clocked through the
  ``ExecutionModel`` engine's measured-search policy (core/model.py)
  with the same cold-call discipline as ``core/feedback.py``: one
  untimed call pays XLA compilation, then best-of-``repeats`` timed
  calls strip scheduler noise (compile seconds must never be recorded
  as a winner's cost);
* **persistence** — the winner is stored through ``CalibrationCache``'s
  versioned JSON store under a ``(kernel, shape-bucket, dtype, hardware)``
  ``DecisionKey``, so a later process (serving or training — they share
  the store) skips the search, while a *different* accelerator keys
  separately: winners tuned on another machine are never inherited, and
  machines sharing one store coexist instead of overwriting each other.

Shapes are bucketed to powers of two: nearby problem sizes share one
winner, keeping the store and the search effort bounded under a serving
load where every request length differs.

Since the ExecutionModel unification, ``KernelTuner`` is a thin
kernel-facing front-end: candidate generation and ``BlockPlan``
packaging live here; the search loop, the store round-trip and the
decision trace live on the engine (one trace for kernel, algorithm,
serve and train decisions).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Hashable, Sequence

from ..core.calibration import CalibrationCache
from ..core.hardware import TPU_V5E, HardwareSpec
from ..core.model import DecisionKey, ExecutionModel, hardware_key
from . import tuning
from .tuning import (LANE, SUBLANE, BlockPlan, attention_live_bytes,
                     max_block_1d)

KEY_NAMESPACE = "pallas_block"

__all__ = ["KernelTuner", "TuneReport", "KEY_NAMESPACE", "hardware_key",
           "shape_bucket", "candidates_1d", "candidates_attention",
           "attention_live_bytes"]


def shape_bucket(n: int) -> int:
    """Smallest power of two >= n (>= 1) — the shape-key granularity."""
    return 1 << max(int(n) - 1, 0).bit_length()


def candidates_1d(n: int, *, bytes_per_elem: int = 4,
                  arrays_in_vmem: int = 2, hw: HardwareSpec = TPU_V5E,
                  align: int = LANE, prior: int | None = None,
                  vmem_fraction: float = 0.25) -> list[int]:
    """Candidate block sizes for a 1-d kernel, analytic prior first.

    The prior (``tuning.plan_1d`` unless given) is bracketed by /4, /2,
    x2, x4 neighbours plus the budget extremes; everything is
    ``align``-aligned, within [align, max_block], and no wider than the
    padded problem — properties the tests sweep.
    """
    n = max(int(n), 1)
    cap = max_block_1d(bytes_per_elem=bytes_per_elem,
                       arrays_in_vmem=arrays_in_vmem, hw=hw, align=align,
                       vmem_fraction=vmem_fraction)
    cap = min(cap, ((n + align - 1) // align) * align)
    if prior is None:
        prior = tuning.plan_1d(n, bytes_per_elem=bytes_per_elem,
                               arrays_in_vmem=arrays_in_vmem, hw=hw,
                               vmem_fraction=vmem_fraction).block

    def snap(b: int) -> int:
        return min(max((int(b) // align) * align, align), cap)

    prior = snap(prior)
    out = [prior]
    for b in (prior // 4, prior // 2, prior * 2, prior * 4, align, cap):
        b = snap(b)
        if b not in out:
            out.append(b)
    return out


def candidates_attention(sq: int, skv: int, d: int, *,
                         bytes_per_elem: int = 2,
                         hw: HardwareSpec = TPU_V5E,
                         vmem_fraction: float = 0.5
                         ) -> list[tuple[int, int]]:
    """Candidate (block_q, block_kv) pairs, analytic prior first.

    Each axis of the prior is varied by x1/2, x1, x2; pairs must stay
    tile-aligned (SUBLANE for q, LANE for kv), inside the VMEM budget,
    and no larger than the padded sequence lengths.
    """
    budget = hw.vmem_bytes * vmem_fraction / 2.0
    pbq, pbk = tuning.plan_attention(sq, skv, d,
                                     bytes_per_elem=bytes_per_elem, hw=hw,
                                     vmem_fraction=vmem_fraction)
    cap_q = ((max(sq, 1) + SUBLANE - 1) // SUBLANE) * SUBLANE
    cap_k = ((max(skv, 1) + LANE - 1) // LANE) * LANE
    out: list[tuple[int, int]] = []
    for fq in (1.0, 0.5, 2.0):
        for fk in (1.0, 0.5, 2.0):
            bq = min(max((int(pbq * fq) // SUBLANE) * SUBLANE, SUBLANE),
                     cap_q)
            bk = min(max((int(pbk * fk) // LANE) * LANE, LANE), cap_k)
            if attention_live_bytes(bq, bk, d, bytes_per_elem) > budget:
                continue
            if (bq, bk) not in out:
                out.append((bq, bk))
    if not out:  # prior itself may exceed a tiny budget: smallest tile
        out = [(SUBLANE, LANE)]
    return out


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """One resolved lookup: where the blocks came from and what each
    candidate cost (empty timings when the store already had a winner)."""

    key: tuple
    winner: tuple
    prior: tuple
    measured: bool
    timings: tuple[tuple[tuple, float], ...] = ()

    @property
    def prior_seconds(self) -> float | None:
        for cand, sec in self.timings:
            if cand == self.prior:
                return sec
        return None

    @property
    def winner_seconds(self) -> float | None:
        for cand, sec in self.timings:
            if cand == self.winner:
                return sec
        return None


class KernelTuner:
    """Per-(kernel, shape-bucket, dtype, hardware) measured block store.

    ``run`` callables passed to the ``plan_*`` methods execute the real
    kernel once for a candidate on synthetic data of the right shape and
    must synchronise internally (``jax.block_until_ready``) — the same
    contract the executor feedback layer imposes on timed thunks.  The
    engine's search policy wraps every probe in an eager escape hatch,
    so the synthetic arrays stay concrete and the kernel really executes
    even when the consumer is mid-trace inside an outer ``jax.jit``
    (without it the probes would be staged and the clock would time
    tracing).
    """

    def __init__(self, cache: CalibrationCache | None = None, *,
                 hw: HardwareSpec = TPU_V5E, repeats: int = 3,
                 hardware: str | None = None):
        self.cache = cache if cache is not None else CalibrationCache()
        self.model = ExecutionModel.of(self.cache)
        self.hw = hw
        self.repeats = max(int(repeats), 1)
        self.hardware = hardware if hardware is not None else hardware_key()
        self.searches = 0      # measured searches (cache misses)
        self.cache_hits = 0    # lookups answered from the store
        # Recent lookups for benchmarks/tests; bounded — a serving loop
        # resolves a plan per compiled shape forever.
        self.reports: collections.deque[TuneReport] = \
            collections.deque(maxlen=256)

    @classmethod
    def persistent(cls, cache_dir: str | None = None, **kw) -> "KernelTuner":
        """A tuner over the same persistent store the acc calibrations
        use — training and serving processes share winners through it."""
        return cls(CalibrationCache.persistent(cache_dir), **kw)

    def _resolve(self, key: DecisionKey, candidates: Sequence[tuple],
                 run: Callable[..., None], fields: tuple[str, ...]) -> tuple:
        """Winner for ``key`` (which includes the hardware id): resolved
        by the ExecutionModel — from the store when present, else the
        measured-search policy sweeps ``candidates`` and persists."""
        decision = self.model.tuned_blocks(key, candidates, run, fields,
                                           repeats=self.repeats)
        measured = bool(decision.input("measured"))
        if measured:
            self.searches += 1
        else:
            self.cache_hits += 1
        self.reports.append(TuneReport(
            key=key.cache_key(), winner=decision.block_plan,
            prior=tuple(candidates[0]), measured=measured,
            timings=tuple(decision.input("timings", ()))))
        return decision.block_plan

    # -- public planning entry points ----------------------------------------
    def plan_1d(self, kernel: str, n: int,
                run: Callable[[int], None], *, dtype="float32",
                bytes_per_elem: int = 4, arrays_in_vmem: int = 2,
                align: int = LANE, prior: int | None = None,
                vmem_fraction: float = 0.25) -> BlockPlan:
        """Measured ``BlockPlan`` for a 1-d kernel.

        ``run(block)`` must execute the kernel with that block size on a
        representative (padded) input and block until ready.
        """
        n = max(int(n), 1)
        cands = candidates_1d(n, bytes_per_elem=bytes_per_elem,
                              arrays_in_vmem=arrays_in_vmem, hw=self.hw,
                              align=align, prior=prior,
                              vmem_fraction=vmem_fraction)
        key = DecisionKey(kind=KEY_NAMESPACE,
                          shape=(kernel, shape_bucket(n)),
                          dtype=str(dtype), hardware=self.hardware)
        (block,) = self._resolve(key, [(c,) for c in cands],
                                 lambda b: run(int(b)), ("block",))
        block = min(block, ((n + align - 1) // align) * align)
        grid = math.ceil(n / block)
        return BlockPlan(block=block, grid=grid, padded=block * grid)

    def plan_attention(self, kernel: str, sq: int, skv: int, d: int,
                       run: Callable[[int, int], None], *, dtype="bfloat16",
                       bytes_per_elem: int = 2, variant: Hashable = (),
                       vmem_fraction: float = 0.5) -> tuple[int, int]:
        """Measured (block_q, block_kv) for a flash-attention-shaped
        kernel; ``run(bq, bk)`` executes it with those tiles.

        ``variant`` is any extra configuration that changes the work per
        tile — causal flag, sliding window — and therefore must key
        separately: the measurement runs under the caller's config, so a
        winner measured with one masking setup says nothing about
        another (a causal grid skips ~half its tiles).
        """
        cands = candidates_attention(sq, skv, d,
                                     bytes_per_elem=bytes_per_elem,
                                     hw=self.hw,
                                     vmem_fraction=vmem_fraction)
        # raw= pins the exact pre-unification (schema v2) tuple order —
        # dtype before variant — so winners persisted by older processes
        # keep resolving; the typed fields label the trace only.
        key = DecisionKey(kind=KEY_NAMESPACE,
                          shape=(kernel, shape_bucket(sq),
                                 shape_bucket(skv), int(d), repr(variant)),
                          dtype=str(dtype), hardware=self.hardware,
                          raw=(KEY_NAMESPACE, kernel, shape_bucket(sq),
                               shape_bucket(skv), int(d), str(dtype),
                               repr(variant), self.hardware))
        bq, bk = self._resolve(key, cands,
                               lambda q, k: run(int(q), int(k)),
                               ("block_q", "block_kv"))
        # A cached bucket-mate's winner may exceed this call's (smaller)
        # padded sequence; cap like plan_1d caps its winner to n.
        bq = min(bq, ((max(sq, 1) + SUBLANE - 1) // SUBLANE) * SUBLANE)
        bk = min(bk, ((max(skv, 1) + LANE - 1) // LANE) * LANE)
        return bq, bk
