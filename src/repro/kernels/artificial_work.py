"""Pallas TPU kernel for the paper's compute-bound "artificial work" body:
``iters`` dependent FMAs per element.  Pure map — no halo; the block size
(adaptive, tuning.plan_1d) controls the VMEM working set and pipeline
depth exactly as the paper's chunk size controls task granularity."""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, iters: int):
    x = x_ref[...]

    def body(_, c):
        return c * 1.000000119 + 0.1

    o_ref[...] = jax.lax.fori_loop(0, iters, body, x)


def artificial_work_pallas(x: jax.Array, *, iters: int, block: int,
                           interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    assert n % block == 0, (n, block)
    return pl.pallas_call(
        functools.partial(_kernel, iters=iters),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)
