"""Adaptive BlockSpec selection — the paper's chunking model applied to
VMEM tiling.

On a TPU chip a Pallas grid runs on one TensorCore with the grid steps
software-pipelined (HBM→VMEM DMA of step i+1 overlaps compute of step i).
The paper's quantities map as:

* "core"            → the TensorCore (1 per chip for this purpose);
* "chunk"           → one grid step's block;
* C = 8 chunks/core → minimum pipeline depth: at least 8 grid steps so the
  DMA/compute pipeline is busy and a straggling step costs ≤ 1/8 of the
  work (same load-balance argument as the paper's work stealing);
* T_m floor         → block must be big enough that per-step launch
  overhead is amortised (and MXU/VPU lanes are full): blocks are rounded
  to the 128-lane × 8-sublane tile and bounded by the VMEM budget.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.hardware import TPU_V5E, HardwareSpec
from ..core.overhead_law import DEFAULT_CHUNKS_PER_CORE

LANE = 128          # TPU lane width (last dim tile)
SUBLANE = 8         # float32 sublane tile (second-to-last dim)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    block: int          # elements per grid step (1-d kernels)
    grid: int           # number of grid steps
    padded: int         # padded array length (block * grid)


def max_block_1d(*, bytes_per_elem: int, arrays_in_vmem: int,
                 hw: HardwareSpec = TPU_V5E, align: int = LANE,
                 vmem_fraction: float = 0.25) -> int:
    """Largest legal 1-d block under the double-buffered VMEM budget,
    floored at one tile.  The single budget model shared by the analytic
    planner below and the measured autotuner's candidate filter
    (autotune.py): budget = vmem * fraction / (2 * live arrays)."""
    budget = hw.vmem_bytes * vmem_fraction / (2.0 * arrays_in_vmem)
    return max((int(budget // bytes_per_elem) // align) * align, align)


def attention_live_bytes(bq: int, bk: int, d: int,
                         bytes_per_elem: int) -> int:
    """VMEM live set of one flash-attention grid step: q, k, v and the
    score tile in the kernel dtype plus the f32 accumulator.  Shared by
    ``plan_attention`` and the autotuner's candidate filter."""
    return (2 * bq * d + 2 * bk * d + bq * bk) * bytes_per_elem \
        + bq * d * 4


def plan_1d(n: int, *, bytes_per_elem: int = 4,
            arrays_in_vmem: int = 2,
            hw: HardwareSpec = TPU_V5E,
            chunks_per_core: int = DEFAULT_CHUNKS_PER_CORE,
            vmem_fraction: float = 0.25) -> BlockPlan:
    """Choose a 1-d block size for an elementwise/stencil kernel.

    Eq. 10 with N_C = 1 TensorCore: block = N / C, then clamped to
    [LANE*SUBLANE, vmem_budget] and rounded to the hardware tile.
    ``arrays_in_vmem`` counts live blocks (in + out + halo...) so double
    buffering fits: budget = vmem * fraction / (2 * arrays).
    """
    n = max(int(n), 1)
    max_block = max_block_1d(bytes_per_elem=bytes_per_elem,
                             arrays_in_vmem=arrays_in_vmem, hw=hw,
                             vmem_fraction=vmem_fraction)
    # A small budget can push max_block below the preferred minimum; the
    # VMEM budget is the hard constraint, so the minimum shrinks (down to
    # one LANE tile) rather than the block exceeding the budget.
    min_block = min(LANE * SUBLANE, max_block)
    target = round_up(math.ceil(n / chunks_per_core), LANE)
    block = max(min(target, max_block), min_block)
    block = min(block, round_up(n, LANE), max_block)
    grid = math.ceil(n / block)
    return BlockPlan(block=block, grid=grid, padded=block * grid)


def plan_attention(sq: int, skv: int, d: int, *,
                   bytes_per_elem: int = 2,
                   hw: HardwareSpec = TPU_V5E,
                   vmem_fraction: float = 0.5) -> tuple[int, int]:
    """(block_q, block_kv) for flash attention.

    VMEM live set per step ≈ (Bq*D + 2*Bk*D + Bq*Bk + Bq*D acc) * bytes.
    Blocks are multiples of the tile; prefer square-ish blocks (maximises
    arithmetic intensity Bq*Bk / (Bq + Bk)).
    """
    budget = hw.vmem_bytes * vmem_fraction / 2.0  # double buffering
    bq = min(512, round_up(min(sq, 512), SUBLANE))
    while bq > SUBLANE:
        bk = min(1024, round_up(min(skv, 1024), LANE))
        while bk >= LANE:
            if attention_live_bytes(bq, bk, d, bytes_per_elem) <= budget:
                return min(bq, round_up(sq, SUBLANE)), min(bk, round_up(skv, LANE))
            bk //= 2
        bq //= 2
    return SUBLANE, LANE
