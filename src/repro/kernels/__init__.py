"""Pallas TPU kernels for the perf-critical compute hot spots, with
adaptive (acc-model) block tiling.  Validated in interpret mode on CPU
against the pure-jnp oracles in ref.py."""
from . import ops, ref, tuning
from .ops import (adjacent_difference, artificial_work, flash_attention,
                  inclusive_scan, reduce_sum, rmsnorm)

__all__ = [
    "ops", "ref", "tuning",
    "adjacent_difference", "artificial_work", "flash_attention",
    "inclusive_scan", "reduce_sum", "rmsnorm",
]
