"""Pallas TPU kernels for the perf-critical compute hot spots, with
adaptive (acc-model) block tiling — static analytic plans (tuning.py)
or measured, persisted winners (autotune.py).  Validated in interpret
mode on CPU against the pure-jnp oracles in ref.py."""
from . import autotune, ops, ref, tuning
from .autotune import KernelTuner
from .ops import (adjacent_difference, artificial_work, flash_attention,
                  inclusive_scan, reduce_sum, rmsnorm)

__all__ = [
    "autotune", "ops", "ref", "tuning", "KernelTuner",
    "adjacent_difference", "artificial_work", "flash_attention",
    "inclusive_scan", "reduce_sum", "rmsnorm",
]
