"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

All functions are deliberately naive/direct: full-precision, full
materialisation, no tiling.  Tests sweep shapes/dtypes and
``assert_allclose`` kernel outputs against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adjacent_difference_ref(x: jax.Array) -> jax.Array:
    """out[0] = x[0]; out[i] = x[i] - x[i-1]."""
    return jnp.concatenate([x[:1], x[1:] - x[:-1]])


def artificial_work_ref(x: jax.Array, iters: int) -> jax.Array:
    """Iterated FMA chain (the paper's compute-bound body)."""
    def step(c, _):
        return c * 1.000000119 + 0.1, None

    out, _ = jax.lax.scan(step, x, None, length=iters)
    return out


def map_ref(x: jax.Array, fn) -> jax.Array:
    return fn(x)


def reduce_sum_ref(x: jax.Array) -> jax.Array:
    return jnp.sum(x, dtype=jnp.float32).astype(x.dtype)


def inclusive_scan_ref(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x, dtype=jnp.float32).astype(x.dtype)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / rms) * gamma.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """Full-softmax multi-head attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    ``window``: sliding-window size (attend to keys in (i-window, i]).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    skv = k.shape[2]
    qi = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (decode support)
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
