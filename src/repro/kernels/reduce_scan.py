"""Pallas TPU kernels for chunked reduction and prefix sum.

Reduction: each grid step writes its block's partial into out[i]; the
(grid,)-sized partial vector is combined outside (two-phase, like the
algorithm layer and the paper's chunked map-reduce).

Scan: three-phase chunk-parallel prefix sum —
  (1) kernel pass computes per-block inclusive scans and block totals,
  (2) an exclusive scan over the (grid,) totals (negligible, jnp),
  (3) kernel pass adds each block's offset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], dtype=jnp.float32).reshape(1).astype(
        o_ref.dtype)


def reduce_sum_pallas(x: jax.Array, *, block: int | None = None, plan=None,
                      interpret: bool = True) -> jax.Array:
    """``block`` or an externally-chosen ``plan`` (``tuning.BlockPlan``,
    e.g. an ``autotune.KernelTuner`` winner) sets the grid step."""
    if plan is not None:
        block = plan.block
    assert block is not None, "need block= or plan="
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = n // block
    partials = pl.pallas_call(
        _reduce_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.sum(partials, dtype=jnp.float32).astype(x.dtype)


def _scan_local_kernel(x_ref, scan_ref, total_ref):
    xf = x_ref[...].astype(jnp.float32)
    s = jnp.cumsum(xf)
    scan_ref[...] = s.astype(scan_ref.dtype)
    total_ref[...] = s[-1:].astype(total_ref.dtype)


def _scan_offset_kernel(scan_ref, off_ref, o_ref):
    o_ref[...] = (scan_ref[...].astype(jnp.float32)
                  + off_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def inclusive_scan_pallas(x: jax.Array, *, block: int | None = None,
                          plan=None, interpret: bool = True) -> jax.Array:
    """``block`` or an externally-chosen ``plan`` sets the grid step (see
    ``reduce_sum_pallas``)."""
    if plan is not None:
        block = plan.block
    assert block is not None, "need block= or plan="
    n = x.shape[0]
    assert n % block == 0, (n, block)
    grid = n // block
    local, totals = pl.pallas_call(
        _scan_local_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), x.dtype),
                   jax.ShapeDtypeStruct((grid,), jnp.float32)],
        interpret=interpret,
    )(x)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.float32), jnp.cumsum(totals)[:-1]])
    return pl.pallas_call(
        _scan_offset_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(local, offsets)
