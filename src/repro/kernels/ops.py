"""jit'd public wrappers around the Pallas kernels.

Each wrapper: picks a block plan (static: tuning.py — the acc chunk
model; measured: an ``autotune.KernelTuner`` passed as ``tuner=``), pads
to the plan, dispatches the kernel, unpads.  ``interpret`` defaults to
True off-TPU so the same call sites validate on CPU and run
Mosaic-compiled on TPU.

The ``tuner=`` path is the paper's feedback loop at the kernel grid:
the tuner wall-clocks candidate blocks seeded from the analytic prior on
synthetic data of the same padded shape (its harness forces eager
evaluation, so the probes really execute even when a consumer resolves
plans while tracing inside an outer jit) and persists the winner, so
only the first process on a given (kernel, shape-bucket, dtype,
hardware) ever pays the search.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import tuning
from .adjacent_difference import adjacent_difference_pallas
from .artificial_work import artificial_work_pallas
from .flash_attention import flash_attention_pallas
from .reduce_scan import inclusive_scan_pallas, reduce_sum_pallas
from .rmsnorm import rmsnorm_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_1d(x: jax.Array, padded: int, fill=0.0):
    n = x.shape[0]
    if padded == n:
        return x
    return jnp.pad(x, (0, padded - n), constant_values=fill)


def _tuned_block_1d(tuner, kernel: str, n: int, dtype, *,
                    arrays_in_vmem: int, call) -> int:
    """Measured block for a 1-d kernel: ``call(x, block)`` is the jit'd
    kernel invocation; the tuner times it on synthetic zeros at each
    candidate (its harness keeps the probes eager and concrete even
    mid-trace of an outer jit)."""

    def run(block: int) -> None:
        padded = ((n + block - 1) // block) * block
        jax.block_until_ready(call(jnp.zeros((padded,), dtype), block))

    return tuner.plan_1d(kernel, n, run, dtype=str(dtype),
                         bytes_per_elem=dtype.itemsize,
                         arrays_in_vmem=arrays_in_vmem).block


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _adjdiff_call(x, block, interpret):
    return adjacent_difference_pallas(x, block=block, interpret=interpret)


def adjacent_difference(x: jax.Array, *, block: int | None = None,
                        interpret: bool | None = None,
                        tuner=None) -> jax.Array:
    n = x.shape[0]
    interpret = _default_interpret() if interpret is None else interpret
    if block is None and tuner is not None:
        block = _tuned_block_1d(
            tuner, "adjacent_difference", n, x.dtype, arrays_in_vmem=3,
            call=lambda xz, b: _adjdiff_call(xz, b, interpret))
    if block is None:
        block = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize,
                               arrays_in_vmem=3).block
    padded = ((n + block - 1) // block) * block
    out = _adjdiff_call(_pad_1d(x, padded), block, interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def _awork_call(x, iters, block, interpret):
    return artificial_work_pallas(x, iters=iters, block=block,
                                  interpret=interpret)


def artificial_work(x: jax.Array, *, iters: int = 256,
                    block: int | None = None,
                    interpret: bool | None = None,
                    tuner=None) -> jax.Array:
    n = x.shape[0]
    interpret = _default_interpret() if interpret is None else interpret
    if block is None and tuner is not None:
        block = _tuned_block_1d(
            tuner, f"artificial_work_{iters}", n, x.dtype, arrays_in_vmem=2,
            call=lambda xz, b: _awork_call(xz, iters, b, interpret))
    if block is None:
        block = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize,
                               arrays_in_vmem=2).block
    padded = ((n + block - 1) // block) * block
    return _awork_call(_pad_1d(x, padded), iters, block, interpret)[:n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _rsum_call(x, block, interpret):
    return reduce_sum_pallas(x, block=block, interpret=interpret)


def reduce_sum(x: jax.Array, *, block: int | None = None,
               interpret: bool | None = None, tuner=None) -> jax.Array:
    n = x.shape[0]
    interpret = _default_interpret() if interpret is None else interpret
    if block is None and tuner is not None:
        block = _tuned_block_1d(
            tuner, "reduce_sum", n, x.dtype, arrays_in_vmem=1,
            call=lambda xz, b: _rsum_call(xz, b, interpret))
    if block is None:
        block = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize,
                               arrays_in_vmem=1).block
    padded = ((n + block - 1) // block) * block
    return _rsum_call(_pad_1d(x, padded), block, interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _iscan_call(x, block, interpret):
    return inclusive_scan_pallas(x, block=block, interpret=interpret)


def inclusive_scan(x: jax.Array, *, block: int | None = None,
                   interpret: bool | None = None, tuner=None) -> jax.Array:
    n = x.shape[0]
    interpret = _default_interpret() if interpret is None else interpret
    if block is None and tuner is not None:
        block = _tuned_block_1d(
            tuner, "inclusive_scan", n, x.dtype, arrays_in_vmem=2,
            call=lambda xz, b: _iscan_call(xz, b, interpret))
    if block is None:
        block = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize,
                               arrays_in_vmem=2).block
    padded = ((n + block - 1) // block) * block
    out = _iscan_call(_pad_1d(x, padded), block, interpret)
    return out[:n]


# pallas_call has no autodiff rule, but the training step differentiates
# through model-layer norms when --kernel-autotune reroutes them here: the
# forward stays the fused kernel, the backward is the closed-form RMSNorm
# VJP in plain jnp (f32, matching the kernel's compute dtype).
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _rmsnorm_diffable(eps, block_rows, interpret, x, gamma):
    return rmsnorm_pallas(x, gamma, eps=eps, block_rows=block_rows,
                          interpret=interpret)


def _rmsnorm_diffable_fwd(eps, block_rows, interpret, x, gamma):
    out = rmsnorm_pallas(x, gamma, eps=eps, block_rows=block_rows,
                         interpret=interpret)
    return out, (x, gamma)


def _rmsnorm_diffable_bwd(eps, block_rows, interpret, res, dy):
    x, gamma = res
    xf = x.astype(jnp.float32)
    gf = gamma.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    xhat = xf * r
    dg = jnp.sum(dyf * xhat, axis=0).astype(gamma.dtype)
    gdy = dyf * gf
    dx = (gdy - xhat * jnp.mean(gdy * xhat, axis=-1, keepdims=True)) * r
    return dx.astype(x.dtype), dg


_rmsnorm_diffable.defvjp(_rmsnorm_diffable_fwd, _rmsnorm_diffable_bwd)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _rmsnorm_call(x, gamma, eps, block_rows, interpret):
    return _rmsnorm_diffable(eps, block_rows, interpret, x, gamma)


def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6,
            block_rows: int | None = None,
            interpret: bool | None = None, tuner=None) -> jax.Array:
    """x: (..., d) — leading dims flattened to rows."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    interpret = _default_interpret() if interpret is None else interpret
    if block_rows is None and tuner is not None:
        # Row blocks: an element is one d-wide row, tiles are sublanes.
        def run(br: int) -> None:
            rp = ((rows + br - 1) // br) * br
            jax.block_until_ready(_rmsnorm_call(
                jnp.zeros((rp, d), x.dtype), jnp.zeros((d,), gamma.dtype),
                eps, br, interpret))

        block_rows = tuner.plan_1d(
            f"rmsnorm_d{d}", rows, run, dtype=str(x.dtype),
            bytes_per_elem=d * x.dtype.itemsize, arrays_in_vmem=2,
            align=tuning.SUBLANE,
            prior=min(128, max(8, rows))).block
    block_rows = block_rows or min(128, max(8, rows))
    padded = ((rows + block_rows - 1) // block_rows) * block_rows
    if padded != rows:
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))
    out = _rmsnorm_call(x2, gamma, eps, block_rows, interpret)
    return out[:rows].reshape(shape)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    block_q: int | None = None, block_kv: int | None = None,
                    interpret: bool | None = None,
                    tuner=None) -> jax.Array:
    """Padded + adaptively-tiled flash attention.  Shapes as in
    flash_attention_pallas; arbitrary Sq/Skv (padding handled here)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    skv = k.shape[2]
    interpret = _default_interpret() if interpret is None else interpret
    # The tuner searches (block_q, block_kv) *pairs*; with one block
    # pinned by the caller the winner's other half would come from a
    # pairing that was never measured, so the search only runs when both
    # are free (a pinned block falls through to the analytic plan).
    if block_q is None and block_kv is None and tuner is not None:
        def run(bq: int, bk: int) -> None:
            sq_p = ((sq + bq - 1) // bq) * bq
            skv_p = ((skv + bk - 1) // bk) * bk
            jax.block_until_ready(_flash_call(
                jnp.zeros((b, hq, sq_p, d), q.dtype),
                jnp.zeros((b, hkv, skv_p, d), k.dtype),
                jnp.zeros((b, hkv, skv_p, d), v.dtype),
                causal, window, scale, skv, bq, bk, sq, interpret))

        block_q, block_kv = tuner.plan_attention(
            "flash_attention", sq, skv, d, run, dtype=str(q.dtype),
            bytes_per_elem=q.dtype.itemsize,
            variant=(causal, window))
    if block_q is None or block_kv is None:
        bq, bk = tuning.plan_attention(sq, skv, d,
                                       bytes_per_elem=q.dtype.itemsize)
        block_q = block_q or bq
        block_kv = block_kv or bk
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(128, skv))
    sq_p = ((sq + block_q - 1) // block_q) * block_q
    skv_p = ((skv + block_kv - 1) // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    out = _flash_call(qp, kp, vp, causal, window, scale, skv,
                      block_q, block_kv, sq, interpret)
    return out[:, :, :sq]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "kv_len", "block_q", "block_kv", "sq_true",
    "interpret"))
def _flash_call(q, k, v, causal, window, scale, kv_len, block_q, block_kv,
                sq_true, interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale, kv_len=kv_len,
        sq_true=sq_true, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
