"""jit'd public wrappers around the Pallas kernels.

Each wrapper: picks an adaptive block plan (tuning.py — the acc chunk
model), pads to the plan, dispatches the kernel, unpads.  ``interpret``
defaults to True off-TPU so the same call sites validate on CPU and run
Mosaic-compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import tuning
from .adjacent_difference import adjacent_difference_pallas
from .artificial_work import artificial_work_pallas
from .flash_attention import flash_attention_pallas
from .reduce_scan import inclusive_scan_pallas, reduce_sum_pallas
from .rmsnorm import rmsnorm_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_1d(x: jax.Array, padded: int, fill=0.0):
    n = x.shape[0]
    if padded == n:
        return x
    return jnp.pad(x, (0, padded - n), constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _adjdiff_call(x, block, interpret):
    return adjacent_difference_pallas(x, block=block, interpret=interpret)


def adjacent_difference(x: jax.Array, *, block: int | None = None,
                        interpret: bool | None = None) -> jax.Array:
    n = x.shape[0]
    plan = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize, arrays_in_vmem=3)
    block = block or plan.block
    padded = ((n + block - 1) // block) * block
    interpret = _default_interpret() if interpret is None else interpret
    out = _adjdiff_call(_pad_1d(x, padded), block, interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def _awork_call(x, iters, block, interpret):
    return artificial_work_pallas(x, iters=iters, block=block,
                                  interpret=interpret)


def artificial_work(x: jax.Array, *, iters: int = 256,
                    block: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    n = x.shape[0]
    plan = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize, arrays_in_vmem=2)
    block = block or plan.block
    padded = ((n + block - 1) // block) * block
    interpret = _default_interpret() if interpret is None else interpret
    return _awork_call(_pad_1d(x, padded), iters, block, interpret)[:n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _rsum_call(x, block, interpret):
    return reduce_sum_pallas(x, block=block, interpret=interpret)


def reduce_sum(x: jax.Array, *, block: int | None = None,
               interpret: bool | None = None) -> jax.Array:
    n = x.shape[0]
    plan = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize, arrays_in_vmem=1)
    block = block or plan.block
    padded = ((n + block - 1) // block) * block
    interpret = _default_interpret() if interpret is None else interpret
    return _rsum_call(_pad_1d(x, padded), block, interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _iscan_call(x, block, interpret):
    return inclusive_scan_pallas(x, block=block, interpret=interpret)


def inclusive_scan(x: jax.Array, *, block: int | None = None,
                   interpret: bool | None = None) -> jax.Array:
    n = x.shape[0]
    plan = tuning.plan_1d(n, bytes_per_elem=x.dtype.itemsize, arrays_in_vmem=2)
    block = block or plan.block
    padded = ((n + block - 1) // block) * block
    interpret = _default_interpret() if interpret is None else interpret
    return _iscan_call(_pad_1d(x, padded), block, interpret)[:n]


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _rmsnorm_call(x, gamma, eps, block_rows, interpret):
    return rmsnorm_pallas(x, gamma, eps=eps, block_rows=block_rows,
                          interpret=interpret)


def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-6,
            block_rows: int | None = None,
            interpret: bool | None = None) -> jax.Array:
    """x: (..., d) — leading dims flattened to rows."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = block_rows or min(128, max(8, rows))
    padded = ((rows + block_rows - 1) // block_rows) * block_rows
    if padded != rows:
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))
    interpret = _default_interpret() if interpret is None else interpret
    out = _rmsnorm_call(x2, gamma, eps, block_rows, interpret)
    return out[:rows].reshape(shape)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    block_q: int | None = None, block_kv: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Padded + adaptively-tiled flash attention.  Shapes as in
    flash_attention_pallas; arbitrary Sq/Skv (padding handled here)."""
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    if block_q is None or block_kv is None:
        bq, bk = tuning.plan_attention(sq, skv, d,
                                       bytes_per_elem=q.dtype.itemsize)
        block_q = block_q or bq
        block_kv = block_kv or bk
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(128, skv))
    sq_p = ((sq + block_q - 1) // block_q) * block_q
    skv_p = ((skv + block_kv - 1) // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    interpret = _default_interpret() if interpret is None else interpret
    out = _flash_call(qp, kp, vp, causal, window, scale, skv,
                      block_q, block_kv, sq, interpret)
    return out[:, :, :sq]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "kv_len", "block_q", "block_kv", "sq_true",
    "interpret"))
def _flash_call(q, k, v, causal, window, scale, kv_len, block_q, block_kv,
                sq_true, interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale, kv_len=kv_len,
        sq_true=sq_true, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
