from .roofline import RooflineReport, analyze, collective_bytes, model_flops

__all__ = ["analyze", "collective_bytes", "model_flops", "RooflineReport"]
