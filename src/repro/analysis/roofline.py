"""Roofline analysis from a compiled dry-run artifact.

``compiled.cost_analysis()`` is per-device (verified: a (1024,1024)@8-way
matmul reports 2·M³/8 flops), so:

    compute    = flops_per_device    / peak_flops          (s)
    memory     = bytes_per_device    / hbm_bw              (s)
    collective = collective_bytes_per_device / link_bw     (s)

collective bytes are parsed from the post-partitioning HLO text
(``compiled.as_text()``): for every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, sum the operand shape
bytes (the assignment's convention).  MODEL_FLOPS = 6·N(active)·D for
training, 2·N(active)·tokens for serve steps; the useful-fraction
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import re

from ..configs.base import ArchConfig, ShapeConfig
from ..core.hardware import TPU_V5E, HardwareSpec

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "f4e2m1fn": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals from partitioned HLO text.

    Post-optimization HLO prints operands as names, so sizes are taken
    from the RESULT shape(s) printed between '=' and the op name.  For
    all-reduce / all-to-all / collective-permute the result equals the
    operand size; for all-gather the result is the gathered buffer, which
    matches ring wire traffic ((g-1)/g ≈ 1×result); for reduce-scatter
    the result understates wire traffic by ~g — noted, rare in our
    modules (GSPMD emits AR+AG pairs).
    """
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        eq = line.rfind("=", 0, m.start())
        if eq < 0:
            continue
        kind = m.group(1)
        result_part = line[eq:m.start()]
        total = sum(_shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(result_part))
        out[kind] = out.get(kind, 0.0) + total
        counts[kind] = counts.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["wire_total"] = wire_bytes(out)
    return {"bytes": out, "counts": counts}


def wire_bytes(byte_map: dict) -> float:
    """Ring-wire traffic model: an all-reduce traverses the ring twice
    (reduce-scatter + all-gather phases ⇒ 2× buffer bytes); the others
    move ~1× their result bytes."""
    total = 0.0
    for kind, v in byte_map.items():
        if kind in ("total", "wire_total"):
            continue
        total += 2.0 * v if kind == "all-reduce" else v
    return total


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_detail: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_fraction: float
    peak_memory_bytes: float
    argument_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: overlapped model = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time (MFU-at-the-roofline)."""
        ideal = self.model_flops / (self.chips * TPU_V5E.peak_flops)
        return ideal / self.step_time_s if self.step_time_s > 0 else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    # decode: one token per sequence, plus the KV/state read is memory not
    # flops — 2·N_active·batch
    return 2.0 * n_active * shape.global_batch


def analyze(compiled, *, cfg: ArchConfig, shape: ShapeConfig,
            mesh_name: str, chips: int,
            hw: HardwareSpec = TPU_V5E) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: list of per-program dicts
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    cb = float(coll["bytes"].get("wire_total", 0.0))
    ma = compiled.memory_analysis()
    peak = float(getattr(ma, "temp_size_in_bytes", 0)
                 + getattr(ma, "output_size_in_bytes", 0))
    args = float(getattr(ma, "argument_size_in_bytes", 0))
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=cb, collective_detail=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=byts / hw.mem_bw,
        collective_s=cb / hw.link_bw,
        model_flops=mf,
        useful_fraction=mf / (flops * chips) if flops else 0.0,
        peak_memory_bytes=peak,
        argument_bytes=args,
    )


def extract_costs(compiled) -> tuple[float, float, float, dict]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: list of per-program dicts
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["bytes"].get("wire_total", 0.0)), coll)


def analyze_calibrated(full_compiled, comp_group, comp_base,
                       multiplier: float, *, cfg: ArchConfig,
                       shape: ShapeConfig, mesh_name: str, chips: int,
                       hw: HardwareSpec = TPU_V5E) -> RooflineReport:
    """Roofline with loop-calibrated totals.

    XLA cost analysis counts a while-loop body once (verified), so the
    layer-group scan and grad-accum scan undercount.  ``comp_group`` is
    the cell lowered with exactly one pattern group (inner loops unrolled
    via flags.unroll_for_accounting) and ``comp_base`` with zero layers;
    total = base + multiplier · (group − base), multiplier = n_layers /
    period.  ``full_compiled`` (the deliverable artifact) provides the
    memory analysis.
    """
    fa, ba, ca_, coll_a = extract_costs(comp_group)
    fb, bb, cb_, coll_b = extract_costs(comp_base)
    flops = fb + multiplier * (fa - fb)
    byts = bb + multiplier * (ba - bb)
    coll = cb_ + multiplier * (ca_ - cb_)
    ma = full_compiled.memory_analysis()
    peak = float(getattr(ma, "temp_size_in_bytes", 0)
                 + getattr(ma, "output_size_in_bytes", 0))
    args = float(getattr(ma, "argument_size_in_bytes", 0))
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=coll,
        collective_detail={"group": coll_a, "base": coll_b,
                           "multiplier": multiplier},
        compute_s=flops / hw.peak_flops,
        memory_s=byts / hw.mem_bw,
        collective_s=coll / hw.link_bw,
        model_flops=mf,
        useful_fraction=mf / (flops * chips) if flops else 0.0,
        peak_memory_bytes=peak,
        argument_bytes=args,
    )


def flash_attention_hbm_bytes(cfg: ArchConfig, shape: ShapeConfig, *,
                              chips: int, passes: float = 4.0,
                              dtype_bytes: int = 2) -> float:
    """Per-device HBM traffic of the Pallas flash kernel for one step.

    Per layer, forward: q read + out write (2·T·Hq·D·b) plus K/V streamed
    once per q-block row (nq · 2·T·Hkv·D·b · visible-fraction; causal ⇒
    ~0.55 of tiles visible; SWA caps visible keys at the window).
    ``passes``: fwd(1) + bwd(2) + remat-fwd(1) = 4 for training, 1 for
    prefill.  Used by the §Perf flash-adjusted memory term together with
    an ``attn_impl="skip"`` lowering that removes the jnp attention's
    accounted bytes.
    """
    from ..kernels import tuning

    s = shape.seq_len
    tokens = shape.global_batch * s
    d = cfg.head_dim_
    bq, _ = tuning.plan_attention(s, s, d, bytes_per_elem=dtype_bytes)
    nq = max(s // bq, 1)
    visible = 0.55 if cfg.attn_window is None else min(
        cfg.attn_window / s + 0.5 / nq, 1.0)
    attn_layers = sum(1 for k in cfg.layer_kinds()
                      if k in ("attn", "shared_attn", "cross_attn"))
    per_layer = (2.0 * tokens * cfg.n_heads * d * dtype_bytes
                 + nq * 2.0 * tokens * cfg.n_kv_heads * d * dtype_bytes
                 * visible)
    return passes * attn_layers * per_layer / chips


def save_report(report: RooflineReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=2)


def format_row(r: RooflineReport) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:6s} "
            f"cmp {r.compute_s*1e3:9.3f}ms  mem {r.memory_s*1e3:9.3f}ms  "
            f"col {r.collective_s*1e3:9.3f}ms  dom={r.dominant:10s} "
            f"useful {r.useful_fraction*100:5.1f}%  "
            f"roofline {r.roofline_fraction*100:5.1f}%  "
            f"hbm {(r.argument_bytes+r.peak_memory_bytes)/2**30:6.2f}GiB")
