"""repro-lint: hot-path static analysis for the hazards this codebase
lives on.

The runtime-adaptation thesis only holds when the execution layer's
overheads are what the ExecutionModel believes they are: a stray host
sync inflates every measured T0, a silent recompile poisons a t_iter
EMA for the life of the calibration store, and a GSPMD reshard inside
the fused decode loop turns the donation invariant into silent cache
corruption.  PRs 2-7 established those invariants by hand (and twice
re-established them after regressions: the PR-5 181ms eager-scatter
compile, the PR-7 mid-serve reshard pinning); this package makes them
machine-checked.

Rules (AST-based, flow-insensitive; see rules.py for details):

=======  ==========================================================
RL001    use-after-donation: a value passed at a donated jit
         position is read again before the rebind (``adopt()``)
RL002    implicit host sync inside functions reachable from the
         serve hot path (``_tick_fused`` / ``decode_loop`` /
         ``frontend._pump``) via a conservative call-graph walk
RL003    recompile hazard: ``jax.jit`` constructed inside a loop
         body (one compile per iteration)
RL004    tracer leak: assignment to ``self.*`` or a global from
         inside a jitted / ``fori_loop`` / ``scan`` body
RL005    blocking call inside ``async def`` (``time.sleep``,
         synchronous device transfers, unbounded ``queue.get``)
RL006    decision-key instability: ``id()``-derived or unhashable
         components flowing into ``DecisionKey``
=======  ==========================================================

Findings print ruff-style (``path:line:col: CODE message``); a line is
suppressed with ``# repro-lint: disable=RL002`` (comma-separate for
several codes).  ``python -m repro.analysis.lint src tests benchmarks``
exits non-zero when any unsuppressed finding remains — the CI gate.
"""
from .engine import (Finding, LintConfig, SourceFile, format_finding,
                     lint_paths, load_file)

__all__ = ["Finding", "LintConfig", "SourceFile", "format_finding",
           "lint_paths", "load_file"]
