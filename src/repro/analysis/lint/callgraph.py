"""Conservative call graph for the RL002 hot-path walk.

Resolution is by *bare name*: a reference to ``free_slots`` — as a
call, an attribute access (property reads count: they run code), or a
bare name (callbacks handed to executors count: they run later) —
edges to every function of that name defined in the group.  That
over-approximates reachability, which is the correct direction for a
gate: a host sync is flagged if it *might* be on the hot path, and the
per-line suppression (with its justification comment) is the sanctioned
escape for the syncs the design actually budgets (the drain's one
``device_get`` per dispatch, the periodic honest-timing sync).

Groups: all files under a ``serve`` directory lint as one graph (the
real serving stack spans scheduler/kv_cache/decode_loop/frontend); a
standalone file that defines a root (``_tick_fused``, ``_pump``, or a
module named ``decode_loop``) forms its own single-file graph, which is
what lets the golden fixtures exercise the rule in isolation.
"""
from __future__ import annotations

import ast
import collections
import pathlib
from typing import Iterator

from .engine import LintConfig, SourceFile
from .rules import functions


def _defines_root(sf: SourceFile, config: LintConfig) -> bool:
    if sf.module in config.hot_modules:
        return True
    return any(fn.name in config.hot_roots
               for _, fn in functions(sf.tree))


def hot_groups(files: list[SourceFile],
               config: LintConfig) -> list[list[SourceFile]]:
    hot, rest = [], []
    for sf in files:
        dirs = pathlib.Path(sf.path).parts[:-1]
        (hot if any(d in config.hot_dirs for d in dirs) else rest).append(sf)
    groups = [hot] if hot else []
    groups.extend([sf] for sf in rest if _defines_root(sf, config))
    return groups


def _is_property(fn: ast.AST) -> bool:
    decs = getattr(fn, "decorator_list", ())
    return any(isinstance(d, ast.Name) and d.id in ("property",
                                                    "cached_property")
               for d in decs)


def _refs(fn: ast.AST, properties: set[str]) -> set[str]:
    """Every bare name this function might invoke: called names,
    bare-name references (callbacks handed to executors), attribute
    reads through ``self`` (method callbacks like ``self._chunk``), and
    attribute reads matching a known ``@property`` (those run code).
    Field reads on *other* objects — ``chunk.start`` — must not edge to
    same-named methods; that chain once pulled the whole legacy decode
    path into the fused root's reachable set."""
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            tail = None
            if isinstance(node.func, ast.Attribute):
                tail = node.func.attr
            elif isinstance(node.func, ast.Name):
                tail = node.func.id
            if tail:
                out.add(tail)
        elif isinstance(node, ast.Attribute) and isinstance(node.ctx,
                                                            ast.Load):
            is_self = (isinstance(node.value, ast.Name)
                       and node.value.id == "self")
            if node.attr in properties or is_self:
                out.add(node.attr)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            out.add(node.id)
    return out


def reachable(group: list[SourceFile], config: LintConfig
              ) -> Iterator[tuple[SourceFile, str, ast.AST, str]]:
    """(file, qualname, node, root-label) for every function reachable
    from the group's hot roots."""
    defs: dict[str, list] = collections.defaultdict(list)
    properties: set[str] = set()
    all_fns = []
    for sf in group:
        for qual, fn in functions(sf.tree):
            defs[fn.name].append((sf, qual, fn))
            all_fns.append((sf, qual, fn))
            if _is_property(fn):
                properties.add(fn.name)

    queue: collections.deque = collections.deque()
    for sf, qual, fn in all_fns:
        if fn.name in config.hot_roots:
            queue.append((sf, qual, fn, qual))
        elif sf.module in config.hot_modules:
            queue.append((sf, qual, fn, f"{sf.module} (hot module)"))

    seen: dict[int, tuple] = {}
    while queue:
        sf, qual, fn, root = queue.popleft()
        if id(fn) in seen:
            continue
        seen[id(fn)] = (sf, qual, fn, root)
        for name in _refs(fn, properties):
            for entry in defs.get(name, ()):
                if id(entry[2]) not in seen:
                    queue.append((*entry, root))
    yield from seen.values()
