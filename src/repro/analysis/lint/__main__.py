"""CLI: ``python -m repro.analysis.lint src tests benchmarks``.

Ruff-style output, one line per finding; exit 1 when any unsuppressed
finding remains (the CI ``lint-repro`` gate), 0 on a clean tree.
"""
from __future__ import annotations

import argparse
import sys

from .engine import RULE_CODES, LintConfig, format_finding, lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: hot-path static analysis "
                    f"({', '.join(RULE_CODES)})")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run "
                         "(default: all)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the summary line")
    args = ap.parse_args(argv)

    select = None
    if args.select:
        select = frozenset(c.strip().upper()
                           for c in args.select.split(",") if c.strip())
        unknown = select - set(RULE_CODES)
        if unknown:
            ap.error(f"unknown rule code(s): {', '.join(sorted(unknown))}")

    findings, suppressed = lint_paths(args.paths,
                                      LintConfig(select=select))
    for f in findings:
        print(format_finding(f))
    if not args.quiet:
        n = len(findings)
        print(f"repro-lint: {n} finding{'s' if n != 1 else ''} "
              f"({suppressed} suppressed)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
