"""Lint driver: file loading, suppressions, rule dispatch, reporting.

Kept deliberately dependency-free (``ast`` + stdlib only): the linter
must run in CI before jax imports — and on any tree, including one
broken enough that importing ``repro`` would fail.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable, Mapping

# ``# repro-lint: disable=RL001`` or ``disable=RL001,RL004`` anywhere on
# the offending line suppresses those codes for that line only.
_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")

SEVERITIES: Mapping[str, str] = {
    "RL000": "error",    # file does not parse
    "RL001": "error",
    "RL002": "error",
    "RL003": "error",
    "RL004": "error",
    "RL005": "error",
    "RL006": "error",
}

RULE_CODES = tuple(c for c in SEVERITIES if c != "RL000")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint hit, addressed like a compiler diagnostic."""

    path: str
    line: int
    col: int
    code: str
    message: str

    @property
    def severity(self) -> str:
        return SEVERITIES.get(self.code, "error")


def format_finding(f: Finding) -> str:
    return f"{f.path}:{f.line}:{f.col}: {f.code} [{f.severity}] {f.message}"


@dataclasses.dataclass
class LintConfig:
    """What the rules consider hot / donating for *this* repo.

    The defaults encode the serving stack's layout: the hot-path roots
    are the fused tick, the decode-loop module, and the front end's
    token pump; ``donating_factories`` names the call surfaces that
    return donated-argument jits (``make_fused_decode_step`` /
    ``make_paged_decode_step`` / the speculative
    ``make_spec_decode_step`` / ``make_paged_spec_decode_step`` and the
    scheduler's ``_fused_step`` / ``_paged_step`` / ``_spec_step``
    accessors all donate the cache pool at positional index 1 — the
    paged steps' page tables and the speculative steps' history ring
    are deliberately *not* donated).  Tests override these to lint
    micro-fixtures.
    """

    select: frozenset[str] | None = None      # None = all rules
    hot_roots: tuple[str, ...] = ("_tick_fused", "_pump")
    hot_modules: tuple[str, ...] = ("decode_loop",)
    hot_dirs: tuple[str, ...] = ("serve",)
    donating_factories: Mapping[str, tuple[int, ...]] = \
        dataclasses.field(default_factory=lambda: {
            "make_fused_decode_step": (1,),
            "make_paged_decode_step": (1,),
            "make_spec_decode_step": (1,),
            "make_paged_spec_decode_step": (1,),
            "_fused_step": (1,),
            "_paged_step": (1,),
            "_spec_step": (1,),
        })

    def wants(self, code: str) -> bool:
        return self.select is None or code in self.select


@dataclasses.dataclass
class SourceFile:
    """A parsed module plus its per-line suppression table."""

    path: str
    text: str
    tree: ast.Module | None
    suppressed: dict[int, set[str]]
    parse_error: Finding | None = None

    @property
    def module(self) -> str:
        return pathlib.Path(self.path).stem


def _suppressions(text: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def load_file(path: str | pathlib.Path) -> SourceFile:
    p = str(path)
    text = pathlib.Path(p).read_text()
    try:
        tree = ast.parse(text, filename=p)
        err = None
    except SyntaxError as e:
        tree = None
        err = Finding(p, e.lineno or 1, e.offset or 0, "RL000",
                      f"file does not parse: {e.msg}")
    return SourceFile(path=p, text=text, tree=tree,
                      suppressed=_suppressions(text), parse_error=err)


def collect_files(paths: Iterable[str | pathlib.Path]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    # de-dup while keeping order (a file named twice lints once)
    seen: set[str] = set()
    uniq = []
    for p in out:
        if str(p) not in seen:
            seen.add(str(p))
            uniq.append(p)
    return uniq


def lint_sources(files: list[SourceFile],
                 config: LintConfig | None = None
                 ) -> tuple[list[Finding], int]:
    """Run every selected rule over ``files``.

    Returns ``(findings, n_suppressed)`` with findings sorted by
    location and de-duplicated (the RL002 graph walk can reach one
    function through several roots).
    """
    from . import rules

    config = config or LintConfig()
    raw: list[Finding] = []
    for sf in files:
        if sf.parse_error is not None:
            raw.append(sf.parse_error)
            continue
        for code, rule in rules.PER_FILE_RULES:
            if config.wants(code):
                raw.extend(rule(sf, config))
    parsed = [sf for sf in files if sf.tree is not None]
    for code, rule in rules.PROJECT_RULES:
        if config.wants(code):
            raw.extend(rule(parsed, config))

    by_file = {sf.path: sf for sf in files}
    findings: list[Finding] = []
    n_suppressed = 0
    seen: set[tuple] = set()
    for f in raw:
        key = (f.path, f.line, f.col, f.code)
        if key in seen:
            continue
        seen.add(key)
        sf = by_file.get(f.path)
        if sf is not None and f.code in sf.suppressed.get(f.line, ()):
            n_suppressed += 1
            continue
        findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return findings, n_suppressed


def lint_paths(paths: Iterable[str | pathlib.Path],
               config: LintConfig | None = None
               ) -> tuple[list[Finding], int]:
    files = [load_file(p) for p in collect_files(paths)]
    return lint_sources(files, config)
