"""The RL001-RL006 rule implementations.

Everything here is deliberately *flow-insensitive*: rules reason about
names and line order inside one scope (plus, for RL002, a conservative
name-matched call graph across the serve package).  That misses nothing
the repo actually does — the hazards these rules police are structural
("a donated name is read again", "a jit is built per loop iteration"),
not data-flow subtleties — and it keeps every rule auditable in one
screen of code.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .engine import Finding, LintConfig, SourceFile

# --------------------------------------------------------------------- utils


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def import_map(tree: ast.Module) -> dict[str, str]:
    """Local alias -> canonical dotted module/name (``jnp`` ->
    ``jax.numpy``, ``jit`` -> ``jax.jit``)."""
    m: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    m[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    m[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                m[a.asname or a.name] = f"{node.module}.{a.name}"
    return m


def resolve(name: str | None, imports: dict[str, str]) -> str | None:
    """Canonicalise a dotted name through the module's import aliases."""
    if name is None:
        return None
    head, _, rest = name.partition(".")
    full = imports.get(head)
    if full is None:
        return name
    return f"{full}.{rest}" if rest else full


def functions(tree: ast.Module) -> Iterator[tuple[str, ast.AST]]:
    """Every (qualname, node) function/method in the module, including
    nested ones (qualified ``Class.method`` / ``outer.inner``)."""

    def walk(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                yield qual, child
                yield from walk(child, qual)
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, child.name)
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def _const_positions(node: ast.expr | None) -> tuple[int, ...]:
    """donate_argnums value -> positional indices (int or tuple of ints)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


# --------------------------------------------------- RL001 use-after-donation

_JIT_NAMES = ("jax.jit", "jax.pmap")


def _donate_positions_of_expr(value: ast.expr, imports: dict[str, str],
                              config: LintConfig) -> tuple[int, ...]:
    """Donated positions if ``value`` evaluates to a donating callable."""
    if not isinstance(value, ast.Call):
        return ()
    fname = resolve(dotted(value.func), imports)
    if fname in _JIT_NAMES:
        for kw in value.keywords:
            if kw.arg == "donate_argnums":
                return _const_positions(kw.value)
        return ()
    tail = (dotted(value.func) or "").rsplit(".", 1)[-1]
    return tuple(config.donating_factories.get(tail, ()))


def check_use_after_donation(sf: SourceFile,
                             config: LintConfig) -> Iterator[Finding]:
    """RL001: a name passed at a donated position of a donating jit is
    read again in the same scope before being rebound (or handed off via
    the ``pool.adopt()`` pattern, which rebinds ``<pool>.caches``)."""
    tree = sf.tree
    imports = import_map(tree)
    donors: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = dotted(node.targets[0])
            if tgt:
                pos = _donate_positions_of_expr(node.value, imports, config)
                if pos:
                    donors[tgt] = pos

    def call_positions(call: ast.Call) -> tuple[int, ...]:
        name = dotted(call.func)
        if name is not None:
            if name in donors:
                return donors[name]
            tail = name.rsplit(".", 1)[-1]
            if tail in config.donating_factories:
                return tuple(config.donating_factories[tail])
        # immediate application: jax.jit(f, donate_argnums=..)(args) or
        # self._fused_step()(args)
        if isinstance(call.func, ast.Call):
            return _donate_positions_of_expr(call.func, imports, config)
        return ()

    for qual, fn in functions(tree):
        donations = []      # (line, donated dotted name, callee repr)
        loads = []          # (line, col, dotted name)
        stores = []         # (line, dotted name)
        kills = []          # (line, dotted name) from <p>.adopt(...)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = dotted(node.func) or "<call>"
                if name.endswith(".adopt"):
                    kills.append((node.lineno,
                                  name[:-len(".adopt")] + ".caches"))
                # The donation takes effect when the call completes, so
                # a multi-line call's own argument lines never read a
                # donated value: compare against the call's last line.
                end = node.end_lineno or node.lineno
                for pos in call_positions(node):
                    if pos < len(node.args):
                        arg = dotted(node.args[pos])
                        if arg:
                            donations.append((end, arg, name))
            elif isinstance(node, (ast.Name, ast.Attribute)):
                name = dotted(node)
                if name is None:
                    continue
                if isinstance(node.ctx, ast.Store):
                    stores.append((node.lineno, name))
                elif isinstance(node.ctx, ast.Load):
                    loads.append((node.lineno, node.col_offset, name))

        for dline, dname, callee in donations:
            rebinds = [line for line, s in stores + kills
                       if line >= dline and (s == dname
                                             or dname.startswith(s + "."))]
            for line, col, lname in loads:
                if line <= dline:
                    continue
                if lname != dname and not lname.startswith(dname + "."):
                    continue
                if any(dline <= r <= line for r in rebinds):
                    continue
                yield Finding(
                    sf.path, line, col, "RL001",
                    f"use-after-donation: '{lname}' is read after being "
                    f"donated to '{callee}' at line {dline} (in '{qual}'); "
                    f"rebind the donated output (adopt()) before reading")


# ------------------------------------------------ RL002 hot-path host syncs

_SYNC_CALLS = {
    "jax.device_get": "jax.device_get (device->host transfer)",
    "jax.block_until_ready": "jax.block_until_ready (device sync)",
    "numpy.asarray": "np.asarray (device->host copy when given a jax "
                     "array)",
    "numpy.array": "np.array (device->host copy when given a jax array)",
}
_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}


def _is_shape_like(arg: ast.expr) -> bool:
    """int()/float() over .shape/.ndim/len() is host metadata, not a
    device sync."""
    for node in ast.walk(arg):
        if isinstance(node, ast.Attribute) and node.attr in _SHAPE_ATTRS:
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "len":
            return True
    return False


def _sync_findings(sf: SourceFile, fn: ast.AST, qual: str, root: str,
                   imports: dict[str, str]) -> Iterator[Finding]:
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        fname = resolve(dotted(node.func), imports)
        desc = None
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            desc = ".item() (device->host scalar sync)"
        elif fname in _SYNC_CALLS:
            desc = _SYNC_CALLS[fname]
        elif isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and len(node.args) == 1 \
                and isinstance(node.args[0], (ast.Call, ast.Subscript)) \
                and not _is_shape_like(node.args[0]):
            desc = (f"{node.func.id}() on a (possibly device) value "
                    f"(implicit device->host sync)")
        if desc:
            yield Finding(
                sf.path, node.lineno, node.col_offset, "RL002",
                f"implicit host sync on the serve hot path: {desc} in "
                f"'{qual}', reachable from '{root}'")


def check_host_sync(files: list[SourceFile],
                    config: LintConfig) -> Iterator[Finding]:
    """RL002: host syncs inside functions reachable from the hot-path
    roots, via the conservative call graph in callgraph.py."""
    from .callgraph import hot_groups, reachable

    for group in hot_groups(files, config):
        for sf, qual, fn, root in reachable(group, config):
            yield from _sync_findings(sf, fn, qual, root,
                                      import_map(sf.tree))


# ------------------------------------------------- RL003 recompile hazards

_COMPILE_CALLS = ("jax.jit", "jax.pmap")


def check_recompile_in_loop(sf: SourceFile,
                            config: LintConfig) -> Iterator[Finding]:
    """RL003: ``jax.jit``/``jax.pmap`` constructed inside a loop body
    (or comprehension) pays a fresh trace+compile per iteration — the
    PR-5 eager-scatter incident cost 181ms of XLA time on the first
    serve tick for exactly this class of mistake."""
    imports = import_map(sf.tree)
    findings: list[Finding] = []

    class V(ast.NodeVisitor):
        depth = 0

        def _loop(self, node):
            self.depth += 1
            self.generic_visit(node)
            self.depth -= 1

        visit_For = visit_While = visit_AsyncFor = _loop
        visit_ListComp = visit_SetComp = visit_DictComp = _loop
        visit_GeneratorExp = _loop

        def visit_Call(self, node: ast.Call):
            fname = resolve(dotted(node.func), imports)
            if self.depth > 0 and fname in _COMPILE_CALLS:
                findings.append(Finding(
                    sf.path, node.lineno, node.col_offset, "RL003",
                    f"recompile hazard: {fname} constructed inside a "
                    f"loop body compiles on every iteration; hoist it "
                    f"(or cache per compiled shape) outside the loop"))
            self.generic_visit(node)

    V().visit(sf.tree)
    yield from findings


# ----------------------------------------------------- RL004 tracer leaks

_TRACE_ENTRY = (
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.fori_loop",
    "jax.lax.while_loop", "jax.lax.scan", "jax.lax.cond", "jax.lax.switch",
    "jax.lax.map",
)


def _traced_function_names(tree: ast.Module,
                           imports: dict[str, str]) -> set[str]:
    traced: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fname = resolve(dotted(node.func), imports)
            if fname in _TRACE_ENTRY:
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        traced.add(arg.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dname = resolve(dotted(dec), imports)
                if dname in _TRACE_ENTRY:
                    traced.add(node.name)
                elif isinstance(dec, ast.Call):
                    if resolve(dotted(dec.func), imports) in _TRACE_ENTRY:
                        traced.add(node.name)
                    else:   # functools.partial(jax.jit, ...)
                        for a in dec.args:
                            if resolve(dotted(a), imports) in _TRACE_ENTRY:
                                traced.add(node.name)
    return traced


def check_tracer_leak(sf: SourceFile,
                      config: LintConfig) -> Iterator[Finding]:
    """RL004: a store to ``self.*`` or a ``global`` from inside a
    function that jax traces (jitted, or a fori_loop/scan/while body):
    the traced value outlives the trace as a leaked tracer, and the
    side effect silently does not happen per step once compiled."""
    imports = import_map(sf.tree)
    traced = _traced_function_names(sf.tree, imports)
    if not traced:
        return
    for qual, fn in functions(sf.tree):
        parts = qual.split(".")
        if not any(p in traced for p in parts):
            continue
        globals_decl = {n for node in ast.walk(fn)
                        if isinstance(node, ast.Global)
                        for n in node.names}
        for node in ast.walk(fn):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for tgt in targets:
                name = dotted(tgt)
                if name is None:
                    continue
                if name.startswith("self."):
                    yield Finding(
                        sf.path, tgt.lineno, tgt.col_offset, "RL004",
                        f"tracer leak: assignment to '{name}' inside "
                        f"traced function '{qual}' — the traced value "
                        f"escapes the trace and the store will not "
                        f"re-run per compiled step")
                elif name in globals_decl:
                    yield Finding(
                        sf.path, tgt.lineno, tgt.col_offset, "RL004",
                        f"tracer leak: assignment to module-level "
                        f"'{name}' inside traced function '{qual}'")


# ------------------------------------------- RL005 blocking calls in async

_ASYNC_BLOCKING = {
    "time.sleep": "time.sleep blocks the event loop; use "
                  "'await asyncio.sleep'",
    "jax.device_get": "synchronous device->host transfer blocks the "
                      "event loop; drain off-loop or bound it",
    "jax.block_until_ready": "synchronous device wait blocks the event "
                             "loop",
}


def _sync_queue_names(tree: ast.Module, imports: dict[str, str]) -> set[str]:
    """Names bound to synchronous ``queue.Queue``-family objects."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.value, ast.Call):
            vname = resolve(dotted(node.value.func), imports)
            if vname in ("queue.Queue", "queue.LifoQueue",
                         "queue.PriorityQueue", "queue.SimpleQueue"):
                tgt = dotted(node.targets[0])
                if tgt:
                    out.add(tgt)
    return out


def check_async_blocking(sf: SourceFile,
                         config: LintConfig) -> Iterator[Finding]:
    """RL005: blocking calls inside ``async def`` — the serve loop runs
    on the event loop, and one blocking call stalls every concurrent
    stream (ticks, submissions, cancellations)."""
    imports = import_map(sf.tree)
    queues = _sync_queue_names(sf.tree, imports)
    for qual, fn in functions(sf.tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fname = resolve(dotted(node.func), imports)
            if fname in _ASYNC_BLOCKING:
                yield Finding(
                    sf.path, node.lineno, node.col_offset, "RL005",
                    f"blocking call in async function '{qual}': "
                    f"{_ASYNC_BLOCKING[fname]}")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "get" \
                    and dotted(node.func.value) in queues \
                    and not any(kw.arg == "timeout"
                                for kw in node.keywords) \
                    and not node.args:
                yield Finding(
                    sf.path, node.lineno, node.col_offset, "RL005",
                    f"blocking call in async function '{qual}': "
                    f"unbounded queue.Queue.get() parks the event loop "
                    f"forever; use asyncio.Queue or a timeout")


# --------------------------------------- RL006 decision-key instability

_UNHASHABLE = (ast.Dict, ast.Set, ast.List, ast.ListComp, ast.SetComp,
               ast.DictComp, ast.GeneratorExp)


def _contains_id_call(node: ast.AST) -> ast.Call | None:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                and n.func.id == "id" and len(n.args) == 1:
            return n
    return None


def check_decision_key_stability(sf: SourceFile,
                                 config: LintConfig) -> Iterator[Finding]:
    """RL006: ``id()``-derived or unhashable components flowing into a
    ``DecisionKey``.  ``id()`` is process-lifetime identity — a key
    built from it changes every restart, so persisted calibrations can
    never be found again (the PR-2 stable-t0-key fix, made a rule)."""
    for qual, fn in list(functions(sf.tree)) + [("<module>", sf.tree)]:
        tainted: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and _contains_id_call(node.value) is not None:
                for tgt in node.targets:
                    name = dotted(tgt)
                    if name:
                        tainted.add(name)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func) or ""
            if name.rsplit(".", 1)[-1] != "DecisionKey":
                continue
            parts = list(node.args) + [kw.value for kw in node.keywords]
            for part in parts:
                if _contains_id_call(part) is not None:
                    yield Finding(
                        sf.path, part.lineno, part.col_offset, "RL006",
                        f"decision-key instability: id()-derived "
                        f"component in DecisionKey (in '{qual}') — "
                        f"process identity is not a stable cache key")
                    continue
                hit = next((n for n in ast.walk(part)
                            if isinstance(n, _UNHASHABLE)), None)
                if hit is not None:
                    yield Finding(
                        sf.path, part.lineno, part.col_offset, "RL006",
                        f"decision-key instability: unhashable "
                        f"{type(hit).__name__.lower()} component in "
                        f"DecisionKey (in '{qual}') — cache keys must "
                        f"be hashable and stable across runs")
                    continue
                for n in ast.walk(part):
                    if isinstance(n, ast.Name) and n.id in tainted \
                            and isinstance(n.ctx, ast.Load):
                        yield Finding(
                            sf.path, n.lineno, n.col_offset, "RL006",
                            f"decision-key instability: '{n.id}' is "
                            f"id()-derived and flows into DecisionKey "
                            f"(in '{qual}')")
                        break


# ------------------------------------------------------------- registry

PER_FILE_RULES = (
    ("RL001", check_use_after_donation),
    ("RL003", check_recompile_in_loop),
    ("RL004", check_tracer_leak),
    ("RL005", check_async_blocking),
    ("RL006", check_decision_key_stability),
)

PROJECT_RULES = (
    ("RL002", check_host_sync),
)
