"""HLO reshard/copy auditor for the fused decode loop.

PR 7 pinned the mesh-sharded pool's placement with a sharding
constraint plus explicit ``out_shardings`` precisely because GSPMD is
free to elect a different layout for a ``while`` carry — and a reshard
*inside* the decode loop body re-pays cache-pool-sized collectives
every iteration, silently turning a memory-bound step into a
link-bound one.  The lint pack (``repro.analysis.lint``) cannot see
that hazard: it lives in the partitioner, not in Python source.  This
module closes the gap by auditing the *compiled* artifact: lower the
live fused step, find every ``while`` loop body in the
post-partitioning HLO text, and fail if the body contains collective
traffic the sharding plan does not predict.

What the plan predicts for the decode body (measured on the 4x2
host-emulated serving mesh — see tests/test_analysis.py):

* ``all-reduce`` — tensor-parallel matmul partial sums over 'model';
  legitimate whenever ``model_parallel > 1`` (13 of them for the
  reduced qwen3 config: one per projection/MLP reduction).
* tiny ``all-gather`` — the greedy argmax runs over the vocab-sharded
  logits, so each lane gathers a per-shard (max, argmax) pair across
  'model': result bytes are per-lane scalars (8 B observed).  Anything
  over ``small_gather_max`` is a resharded buffer, not an argmax lane:
  the deliberate replicate-the-pool injection gathers the full
  per-device cache row (16 KiB on the same config) — three orders of
  magnitude over the threshold.
* nothing else.  ``reduce-scatter`` / ``all-to-all`` /
  ``collective-permute`` in the body always mean the partitioner moved
  the carry; on a single device (no mesh) *any* collective is a bug.

Plain ``copy`` ops inside the body are counted and reported (the
donated carry legitimately materialises row copies ahead of
``dynamic-update-slice``), but are not a failure by themselves —
copy-based resharding on one device cannot be told apart from those by
text alone, which is exactly why the strict-mode runtime guards exist.

CLI::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.analysis.hlo_audit \\
        --arch qwen3-0.6b --reduced --mesh 4,2

exits non-zero on violations (the CI ``hlo-audit`` gate), and
``--inject-reshard`` flips the deliberate mid-loop reshard on to prove
the gate can fail.
"""
from __future__ import annotations

import dataclasses
import json
import re

from .roofline import _COLL_RE, _SHAPE_RE, _shape_bytes

# Computation definitions start at column 0: ``%name (params) -> ty {``
# (the entry computation carries an ``ENTRY`` prefix) and end at the
# first closing brace back at column 0.
_COMP_RE = re.compile(r"^(?:ENTRY )?(%?[\w.\-]+) \(", re.M)
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_REF_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_COPY_RE = re.compile(r"\bcopy\(")

# The argmax lane gathers per-lane (max, argmax) scalars across the
# vocab shards — bytes, not buffers.  A gathered cache row is KiB+.
SMALL_GATHER_MAX = 1024


def computations(hlo_text: str) -> dict[str, str]:
    """Split dumped HLO module text into named computation bodies."""
    out: dict[str, str] = {}
    for m in _COMP_RE.finditer(hlo_text):
        name = m.group(1).lstrip("%")
        end = hlo_text.find("\n}", m.start())
        out[name] = hlo_text[m.start():end + 2 if end >= 0 else None]
    return out


def loop_body_texts(hlo_text: str) -> dict[str, str]:
    """``{body_name: text}`` for every ``while`` loop body, including
    computations the body references (``calls=``/``to_apply=`` fusions,
    nested loops) — a collective hidden in a called computation still
    runs every iteration."""
    comps = computations(hlo_text)
    out: dict[str, str] = {}
    for text in comps.values():
        for line in text.splitlines():
            if not _WHILE_RE.search(line):
                continue
            b = _BODY_RE.search(line)
            if b is None:
                continue
            root = b.group(1)
            seen: set[str] = set()
            stack = [root]
            while stack:
                name = stack.pop()
                if name in seen or name not in comps:
                    continue
                seen.add(name)
                stack.extend(r.group(1)
                             for r in _REF_RE.finditer(comps[name]))
            out[root] = "\n".join(comps[n] for n in sorted(seen))
    return out


@dataclasses.dataclass(frozen=True)
class LoopOp:
    """One collective (or copy) op found inside a loop body."""

    body: str
    kind: str
    result_bytes: int
    text: str


def _scan_ops(body_name: str, body_text: str,
              op_re: re.Pattern, kind: str | None = None) -> list[LoopOp]:
    ops = []
    for line in body_text.splitlines():
        m = op_re.search(line)
        if m is None:
            continue
        eq = line.rfind("=", 0, m.start())
        if eq < 0:
            continue            # operand reference, not a definition
        size = sum(_shape_bytes(d, s)
                   for d, s in _SHAPE_RE.findall(line[eq:m.start()]))
        ops.append(LoopOp(body_name, kind or m.group(1), int(size),
                          line.strip()))
    return ops


@dataclasses.dataclass
class AuditPolicy:
    """What the sharding plan predicts inside the decode loop body."""

    model_parallel: int = 1
    small_gather_max: int = SMALL_GATHER_MAX

    def violation(self, op: LoopOp) -> str | None:
        """None when the plan predicts ``op``; else the reason it fails."""
        if self.model_parallel > 1:
            if op.kind == "all-reduce":
                return None     # TP partial-sum reductions
            if op.kind == "all-gather" \
                    and op.result_bytes <= self.small_gather_max:
                return None     # vocab-sharded argmax lanes
            if op.kind == "all-gather":
                return (f"all-gather of {op.result_bytes} B in the loop "
                        f"body (> {self.small_gather_max} B): a resharded "
                        f"buffer, not an argmax lane")
            return (f"{op.kind} in the loop body: never part of the "
                    f"decode sharding plan")
        return (f"{op.kind} in the loop body of an unsharded step: no "
                f"collective is predicted without a mesh")


@dataclasses.dataclass
class AuditReport:
    n_bodies: int
    collectives: list[LoopOp]
    violations: list[tuple[LoopOp, str]]
    copy_count: int
    copy_bytes: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for op in self.collectives:
            out[op.kind] = out.get(op.kind, 0) + 1
        return out

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "n_loop_bodies": self.n_bodies,
            "collective_counts": self.counts(),
            "copy_count": self.copy_count,
            "copy_bytes": self.copy_bytes,
            "violations": [
                {"body": op.body, "kind": op.kind,
                 "result_bytes": op.result_bytes,
                 "reason": reason, "hlo": op.text}
                for op, reason in self.violations],
        }


def audit_hlo(hlo_text: str, policy: AuditPolicy) -> AuditReport:
    """Audit every ``while`` body in ``hlo_text`` against ``policy``."""
    collectives: list[LoopOp] = []
    violations: list[tuple[LoopOp, str]] = []
    copy_count = copy_bytes = 0
    bodies = loop_body_texts(hlo_text)
    for name, text in bodies.items():
        for op in _scan_ops(name, text, _COLL_RE):
            collectives.append(op)
            reason = policy.violation(op)
            if reason is not None:
                violations.append((op, reason))
        for op in _scan_ops(name, text, _COPY_RE, kind="copy"):
            copy_count += 1
            copy_bytes += op.result_bytes
    return AuditReport(n_bodies=len(bodies), collectives=collectives,
                       violations=violations, copy_count=copy_count,
                       copy_bytes=copy_bytes)


def audit_scheduler(sched, *, inject_reshard: bool = False,
                    small_gather_max: int = SMALL_GATHER_MAX
                    ) -> AuditReport:
    """Lower the scheduler's *live* fused decode step and audit it.

    ``inject_reshard=True`` rebuilds the step with the deliberate
    mid-loop reshard (``decode_loop._inject_reshard``) — the failure
    demonstration; the audited step is a separate jit, the scheduler's
    own dispatch path is untouched.
    """
    import jax.numpy as jnp

    from ..serve.decode_loop import (make_fused_decode_step,
                                     make_paged_decode_step)

    if not sched._fused:
        raise ValueError("hlo-audit needs the fused decode path "
                         "(dispatch_depth != None)")
    paged = bool(getattr(sched, "paged", False))
    if inject_reshard:
        if paged:
            step = make_paged_decode_step(
                sched.cfg, page_size=sched.pool.page_size,
                max_len=sched.max_len, kernel_tuner=sched.kernel_tuner,
                max_depth=sched.max_dispatch_depth,
                cache_shardings=sched.pool.shardings,
                _inject_reshard=True)
        else:
            step = make_fused_decode_step(
                sched.cfg, window=sched.window,
                kernel_tuner=sched.kernel_tuner,
                max_depth=sched.max_dispatch_depth,
                cache_shardings=sched.pool.shardings,
                _inject_reshard=True)
    else:
        step = sched._fused_step()
    n = sched.pool.n_slots
    pt = (sched.pool.page_table_array(),) if paged else ()
    spec_d = sched._spec_depth if getattr(sched, "_spec", False) else 1
    if spec_d >= 2 and not inject_reshard:
        # Speculative draft/verify step: same donation and loop-body
        # discipline as the plain fused step, plus the history ring —
        # audited with the live depth's compiled executable.
        lowered = sched._spec_step(spec_d).lower(
            sched.params, sched.pool.caches, *pt, sched._decode_hist(),
            jnp.zeros(n, jnp.int32), sched.pool.positions_array(),
            jnp.zeros(n, jnp.int32))
    else:
        lowered = step.lower(
            sched.params, sched.pool.caches, *pt, jnp.zeros(n, jnp.int32),
            sched.pool.positions_array(), jnp.zeros(n, jnp.int32))
    model_parallel = 1
    if sched.mesh is not None:
        model_parallel = int(dict(sched.mesh.shape).get("model", 1))
    return audit_hlo(lowered.compile().as_text(),
                     AuditPolicy(model_parallel=model_parallel,
                                 small_gather_max=small_gather_max))


def format_report(report: AuditReport) -> str:
    lines = [f"hlo-audit: {report.n_bodies} loop body(ies), "
             f"collectives={report.counts() or '{}'}, "
             f"copies={report.copy_count} "
             f"({report.copy_bytes} B result)"]
    for op, reason in report.violations:
        lines.append(f"  VIOLATION [{op.body}] {reason}")
        lines.append(f"    {op.text[:140]}")
    lines.append("hlo-audit: " + ("clean" if report.ok else
                                  f"{len(report.violations)} violation(s)"))
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.hlo_audit",
        description="audit the fused decode loop's compiled HLO for "
                    "unpredicted reshard traffic")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (default: on — the audit is "
                         "structural, not a throughput run)")
    ap.add_argument("--mesh", default="off",
                    help="'DATA,MODEL' device counts, or 'off'")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="audit the paged fused step (page-table "
                         "gathers + flat-store scatters in the body)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--speculate", type=int, default=0,
                    help="audit the speculative draft/verify step at "
                         "this width instead of the plain fused step "
                         "(0 = off; incompatible with "
                         "--inject-reshard)")
    ap.add_argument("--inject-reshard", action="store_true",
                    help="deliberately reshard the pool inside the loop "
                         "body (the audit must then FAIL — gate "
                         "self-test)")
    ap.add_argument("--out", default=None,
                    help="write the report as JSON to this path")
    args = ap.parse_args(argv)

    import jax

    from ..configs import get_config
    from ..core import SequentialExecutor, adaptive
    from ..core.acc import AdaptiveCoreChunk
    from ..models import init_params
    from ..serve import ServeScheduler

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.mesh != "off":
        from ..launch.mesh import make_serve_mesh

        data, model = (int(x) for x in args.mesh.split(","))
        mesh = make_serve_mesh(data, model)
    sched = ServeScheduler(
        cfg, params, n_slots=args.slots, max_len=args.max_len,
        executor=adaptive(SequentialExecutor(), AdaptiveCoreChunk()),
        dispatch_depth=args.depth, mesh=mesh,
        paged=args.paged, page_size=args.page_size,
        speculate=args.speculate if args.speculate >= 2 else None)
    # The paged store is replicated over 'data' (prefix sharing — see
    # launch/sharding.paged_cache_specs), so the plan predicts one
    # all-gather of the per-step lane updates: (slots, Hkv_shard, D)
    # rows per attn layer, not scalars.  Raise the small-gather budget
    # to one lane-update row set; a gathered *pool* is still MiB+.
    gmax = SMALL_GATHER_MAX
    if args.paged and mesh is not None:
        model_par = int(dict(mesh.shape).get("model", 1))
        gmax = max(gmax, 4 * args.slots * cfg.head_dim_ *
                   -(-cfg.n_kv_heads // model_par))
    report = audit_scheduler(sched, inject_reshard=args.inject_reshard,
                             small_gather_max=gmax)
    print(format_report(report))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.to_dict(), f, indent=2)
    if report.n_bodies == 0:
        print("hlo-audit: no while loop found in the fused step "
              "(trip count folded?) — refusing to pass an empty audit")
        return 1
    return 0 if report.ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
