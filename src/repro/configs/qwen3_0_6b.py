"""qwen3-0.6b — qk-norm, GQA, 151936 vocab, tied embeddings.
[hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128,
    qk_norm=True, tie_embeddings=True,
    act="silu", ffn_gated=True,
    long_context_ok=False,
    source="hf:Qwen/Qwen3-8B; hf",
)
