"""mixtral-8x22b — MoE 8e top-2, GQA kv=8, SWA.  [arXiv:2401.04088; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    n_experts=8, experts_per_token=2,
    attn_window=4096,            # SWA bounds the KV state
    act="silu", ffn_gated=True,
    long_context_ok=True,        # window-bounded KV
    source="arXiv:2401.04088; hf",
)
