"""llama-3.2-vision-11b — decoder with image cross-attention every 5th
layer; vision frontend is a stub (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    block_pattern=("attn", "attn", "attn", "cross_attn", "attn"),
    frontend="vision", num_frontend_tokens=1601,
    act="silu", ffn_gated=True,
    long_context_ok=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
