"""zamba2-1.2b — Mamba2 backbone + shared attention block every 6th
position (shared weights).  [arXiv:2411.15242; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                   "shared_attn"),
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    long_context_ok=True,          # Mamba2 O(1) state
    long_context_window=4096,      # shared attn windowed in long shapes
    source="arXiv:2411.15242; hf",
)
