"""Config registry: one module per assigned architecture."""
from . import base
from .base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                   ArchConfig, ShapeConfig, shape_applicable)

_ARCH_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "mixtral-8x22b": "mixtral_8x22b",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-350m": "xlstm_350m",
    "granite-34b": "granite_34b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
