"""grok-1-314b — MoE 8e top-2, GQA kv=8.  [hf:xai-org/grok-1; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    n_experts=8, experts_per_token=2,
    act="gelu", ffn_gated=True,
    long_context_ok=False,  # full attention: 512K KV unbounded
    source="hf:xai-org/grok-1; unverified",
)
