"""musicgen-medium — decoder-only transformer over EnCodec tokens
(the EnCodec frontend is the stub: token ids are the input).
[arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    frontend="audio",
    act="gelu", ffn_gated=False,
    long_context_ok=False,
    source="arXiv:2306.05284; hf",
)
