"""granite-34b — deep/narrow MQA code model (gpt-bigcode style MLP).
[arXiv:2405.04324; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    act="gelu", ffn_gated=False,
    long_context_ok=False,
    source="arXiv:2405.04324; hf",
)
