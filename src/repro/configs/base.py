"""Architecture and shape configuration schema.

Every assigned architecture is one ``ArchConfig`` in its own module under
``repro.configs``; the four assigned input shapes are ``ShapeConfig``
presets.  ``reduced()`` produces the CPU-smoke-test variant of any arch
(same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention details
    head_dim: int | None = None            # default d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: int | None = None         # SWA window (None = full)
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 2
    capacity_factor: float = 1.25

    # layer pattern, cycled over the depth. kinds:
    #   attn (self-attn + ffn/moe), mamba2, slstm, mlstm,
    #   shared_attn (zamba2 shared transformer block),
    #   cross_attn (vlm image cross-attention + ffn)
    block_pattern: tuple[str, ...] = ("attn",)

    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # modality frontends (stubs: precomputed embeddings via input_specs)
    frontend: str | None = None            # vision | audio | None
    num_frontend_tokens: int = 0           # e.g. image patch tokens

    # misc
    act: str = "silu"
    ffn_gated: bool = True                 # GLU (3 mats) vs plain MLP (2)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # long-context support: archs whose state is bounded (SSM / SWA) can
    # run the long_500k cell; pure full-attention archs cannot.
    long_context_ok: bool = False
    # documented deviation: window applied to attn blocks in long shapes
    long_context_window: int | None = None

    source: str = ""

    # ---------------- derived ----------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> list[str]:
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return max(self.d_inner // self.ssm_head_dim, 1)

    # ---- parameter counts (for MODEL_FLOPS = 6·N·D) ----
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim_
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def _ffn_params(self) -> int:
        return (3 if self.ffn_gated else 2) * self.d_model * self.d_ff

    def _moe_params(self, active: bool) -> int:
        e = self.experts_per_token if active else self.n_experts
        return e * self._ffn_params() + self.d_model * self.n_experts

    def _mamba_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)   # z, x, B, C, dt
        conv = (di + 2 * n) * self.conv_width
        out = di * d
        return in_proj + conv + out + 2 * h  # + A, D per head

    def _mlstm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        h = self.ssm_heads
        # q,k,v + i,f gates + output gate + out-projection
        return d * 3 * di + d * 2 * h + d * di + di * d

    def _slstm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        return 4 * d * di + di * d  # z,i,f,o + out

    def layer_params(self, kind: str) -> int:
        if kind == "attn":
            ff = self._moe_params(False) if self.n_experts else self._ffn_params()
            return self._attn_params() + ff
        if kind in ("shared_attn", "cross_attn"):
            return self._attn_params() + self._ffn_params()
        if kind == "mamba2":
            return self._mamba_params()
        if kind == "mlstm":
            return self._mlstm_params()
        if kind == "slstm":
            return self._slstm_params()
        raise ValueError(kind)

    def layer_active_params(self, kind: str) -> int:
        if kind == "attn" and self.n_experts:
            return self._attn_params() + self._moe_params(True)
        return self.layer_params(kind)

    def param_count(self) -> int:
        kinds = self.layer_kinds()
        shared_counted = False
        total = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for k in kinds:
            if k == "shared_attn":
                if not shared_counted:
                    total += self.layer_params(k)
                    shared_counted = True
                total += self.d_model * self.d_model  # per-use projection
            else:
                total += self.layer_params(k)
        return total

    def active_param_count(self) -> int:
        kinds = self.layer_kinds()
        shared_counted = False
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for k in kinds:
            if k == "shared_attn":
                if not shared_counted:
                    total += self.layer_params(k)
                    shared_counted = True
                total += self.d_model * self.d_model
            else:
                total += self.layer_active_params(k)
        return total

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pattern_period = len(self.block_pattern)
        n_layers = max(pattern_period, 2)
        d_model = 64
        n_heads = 4
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else n_heads
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            capacity_factor=8.0,   # drop-free at smoke-test sizes
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            attn_window=min(self.attn_window, 16) if self.attn_window else None,
            num_frontend_tokens=min(self.num_frontend_tokens, 8),
            long_context_window=(16 if self.long_context_window else None),
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason) for an (arch × shape) cell — encodes the
    long_500k sub-quadratic requirement (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: 512K KV state unbounded; "
                       "skipped per assignment (see DESIGN.md)")
    return True, ""
