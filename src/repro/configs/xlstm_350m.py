"""xlstm-350m — mLSTM + sLSTM blocks (7:1).  [arXiv:2405.04517; unverified]
d_ff=0: all capacity lives in the recurrent blocks' projections."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_state=0, ssm_head_dim=512, ssm_expand=2,  # 4 heads of 512 in d_inner
    long_context_ok=True,          # recurrent O(1) state
    source="arXiv:2405.04517; unverified",
)
