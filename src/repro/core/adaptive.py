"""``AdaptiveExecutor`` — the paper's adaptivity fused into the executor.

The v1 API made callers thread the acc execution-parameters object through
every algorithm call (``par.on(ex).with_(AdaptiveCoreChunk())``).  HPX's
Smart Executors instead *are* the adaptation: you hand the algorithm an
executor and the runtime machinery hides behind it.  ``AdaptiveExecutor``
is that executor: it wraps any backend, carries an ``AdaptiveCoreChunk``
as its ``params`` annotation, and overloads the three customization points
via the existing attribute-lookup dispatch (core/customization.py rule 2),
so

    par.on(adaptive(HostParallelExecutor()))

gives paper-style adaptation with zero algorithm-signature changes and
makes the *same* core/chunk decisions as an explicitly-passed acc object
(asserted by tests/test_executor_v2.py).

Execution functions delegate to the wrapped executor; ``inner`` is public
so ``unwrap_executor`` / ``mesh_executor_of`` see through the wrapper.
"""
from __future__ import annotations

from typing import Any, Hashable

from .acc import AdaptiveCoreChunk
from .executor import ExecutorBase, Future
from .feedback import OnlineFeedback
from .properties import ExecutorAnnotations, PropertySupport


class AdaptiveExecutor(ExecutorBase, PropertySupport):
    """Wrap ``inner`` with acc-driven core/chunk adaptation.

    Every bulk chunk and tagged continuation is wall-clocked and fed to an
    ``OnlineFeedback`` recorder (core/feedback.py) that smooths the
    observation into the acc object's ``CalibrationCache`` — callers get
    drift-tracking t_iter for free just by running work through the
    executor.  Pass ``feedback=None`` explicitly to disable telemetry.
    """

    _SENTINEL = object()

    def __init__(self, inner: Any, params: Any = None,
                 feedback: OnlineFeedback | None | object = _SENTINEL):
        self.inner = inner
        self._annotations = ExecutorAnnotations(
            params=params if params is not None else AdaptiveCoreChunk())
        if feedback is AdaptiveExecutor._SENTINEL:
            cache = getattr(self.params, "cache", None)
            feedback = OnlineFeedback(cache) if cache is not None else None
        self.feedback = feedback

    @property
    def params(self) -> Any:
        """The execution-parameters object this executor adapts with."""
        return self.annotations.params

    def with_params(self, params: Any):
        """Rebinding params must also rebind the feedback recorder: the
        timings have to land in the cache the *new* acc object reads, not
        the one the clone inherited from the original."""
        clone = super().with_params(params)
        cache = getattr(params, "cache", None)
        clone.feedback = OnlineFeedback(cache) if cache is not None else None
        return clone

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"AdaptiveExecutor({self.inner!r})"

    # -- execution functions: delegate to the wrapped backend ---------------
    def num_units(self) -> int:
        return self.inner.num_units()

    def sync_execute(self, fn, *args) -> Any:
        return self.inner.sync_execute(fn, *args)

    def async_execute(self, fn, *args) -> Future:
        return self.inner.async_execute(fn, *args)

    def bulk_async_execute(self, fn, chunks) -> list[Future]:
        if self.feedback is not None:
            fn = self.feedback.timed_chunk_fn(fn)
        return self.inner.bulk_async_execute(fn, chunks)

    def then_execute(self, fn, future: Future) -> Future:
        if self.feedback is not None:
            fn = self.feedback.timed_continuation(fn)
        return self.inner.then_execute(fn, future)

    # -- customization points (executor-level overloads; the dispatch rule
    # -- calls these without a leading params/executor argument) ------------
    def measure_iteration(self, body: Any, count: int,
                          key: Hashable | None = None) -> float:
        return self.params.measure_iteration(self, body, count, key=key)

    def processing_units_count(self, t_iter: float, count: int) -> int:
        return self.params.processing_units_count(self, t_iter, count)

    def get_chunk_size(self, t_iter: float, cores: int, count: int) -> int:
        return self.params.get_chunk_size(self, t_iter, cores, count)


def adaptive(executor: Any, params: Any = None) -> AdaptiveExecutor:
    """``par.on(adaptive(ex))`` — the one-word opt-in to adaptation."""
    if isinstance(executor, AdaptiveExecutor):
        return executor if params is None else AdaptiveExecutor(
            executor.inner, params)
    return AdaptiveExecutor(executor, params)
