"""The ``ExecutionModel`` engine — one decide→execute→observe→refine loop.

The paper's contribution is not any single heuristic but an *execution
model*: a runtime-metric-driven strategy that decides execution
parameters uniformly behind the executor API.  Before this module the
repo had four parallel decision stacks that each reimplemented that loop
with incompatible keys and conventions:

* ``core/acc.AdaptiveCoreChunk`` + ``overhead_law.decide`` — algorithm
  core counts and chunk sizes;
* ``core/adaptive.AdaptiveExecutor`` + ``core/feedback.OnlineFeedback``
  — executor-level drift tracking (EMA over observed chunk wall-clock);
* ``kernels/autotune.KernelTuner`` — measured Pallas block search;
* ``train/autotune.choose_plan`` / the serve scheduler's per-tick picks
  — train/serve planning.

``ExecutionModel`` owns the loop once; the former silos are *policies*
registered on it:

* **prior**   — ``AnalyticOverheadLaw``: the paper's closed form
  (Eqs 1-10, ``overhead_law.decide``) as the analytic seed;
* **search**  — ``MeasuredBlockSearch``: cold-call-excluded best-of-N
  wall-clock over a legal candidate neighbourhood (the loop that was
  ``KernelTuner._resolve``);
* **refine**  — ``OnlineEMA``: exponential smoothing of observed chunk
  timings back into the calibration store (the loop that was
  ``OnlineFeedback`` → ``CalibrationCache.smooth_t_iter``).

Every query goes through one typed IR:

* ``DecisionKey``   — workload kind + shape bucket + dtype + hardware;
* ``Decision``      — cores / chunk / block plan / batch width, plus
  *provenance* (``analytic | measured | online``) and the inputs that
  produced it;
* ``DecisionTrace`` — append-only explainable record of every decision
  (``--explain-decisions`` on the launch CLIs dumps it).

Provenance is monotone: once a key has measured data it never reports
``analytic`` again, and once it has online observations it never reports
``measured`` again (the calibration store only gains information; the
engine additionally clamps against the best level it has ever reported
for the key).  All state persists through one ``CalibrationCache``
(schema v3) so algorithm, kernel, serve and train decisions share a
single store.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter, deque
from typing import Any, Callable, Hashable, Iterator, Sequence

from . import overhead_law
from .calibration import DEFAULT_SMOOTHING, CalibrationCache
from .overhead_law import AccDecision

# Self-speculative decoding priors (the ``serve_spec_depth`` decision).
# The acceptance prior seeds the analytic decision before any verify has
# drained; the width cost is the marginal fraction of a fixed decode
# step one extra verify position costs.  On a weight-streaming-bound
# accelerator that marginal is nearly free (the extra position rides the
# same weight reads); on a dispatch-overhead-bound host the draft /
# emit / history bookkeeping is a real per-round tax — the prior sits
# at the conservative end so the argmax only widens the verify when
# acceptance genuinely pays for it.  Below the backoff floor
# speculation is disabled outright.
DEFAULT_SPEC_ACCEPT = 0.5
DEFAULT_SPEC_WIDTH_COST = 0.25
MIN_SPEC_ACCEPT = 0.05

# Provenance levels, weakest to strongest.  A decision's provenance says
# what class of evidence backed it: a closed-form estimate, a one-shot
# measurement, or a continuously-refined online observation.
ANALYTIC = "analytic"
MEASURED = "measured"
ONLINE = "online"
PROVENANCE_LEVELS = (ANALYTIC, MEASURED, ONLINE)


def provenance_rank(level: str) -> int:
    """Position of ``level`` in the upgrade order (unknown maps to 0)."""
    try:
        return PROVENANCE_LEVELS.index(level)
    except ValueError:
        return 0


def provenance_max(a: str | None, b: str | None) -> str:
    """The stronger of two provenance levels (None counts as analytic)."""
    a = a or ANALYTIC
    b = b or ANALYTIC
    return a if provenance_rank(a) >= provenance_rank(b) else b


def hardware_key() -> str:
    """Stable id of the accelerator this process runs on.

    Measured winners and calibrations are only valid on the hardware
    that produced them: a block tuned in interpret mode on a CPU says
    nothing about a v5e.  (Moved here from kernels/autotune so every
    policy shares one definition.)
    """
    try:
        import jax

        devs = jax.devices()
        kind = getattr(devs[0], "device_kind", "unknown")
        return f"{jax.default_backend()}:{kind}:{len(devs)}"
    except Exception:  # pragma: no cover - no backend at all
        return "unknown"


# ---------------------------------------------------------------------------
# The typed Decision IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecisionKey:
    """What a decision is *for*: workload kind + shape bucket + dtype +
    hardware.  ``cache_key()`` is the stable hashable the calibration
    store indexes by — a key adopted from a legacy workload key
    (``wrap``) keeps that key's *exact* cache identity (``raw``), so
    persisted v1/v2 entries keep resolving whatever shape the original
    key had (tuple, string, anything hashable)."""

    kind: str
    shape: tuple = ()
    dtype: str = ""
    hardware: str = ""
    # Set by wrap(): the legacy key verbatim.  When present it IS the
    # cache identity — typed fields above only label the trace.
    raw: Hashable | None = None

    def cache_key(self) -> Hashable:
        if self.raw is not None:
            return self.raw
        key: tuple = (self.kind,) + tuple(self.shape)
        if self.dtype:
            key += (self.dtype,)
        if self.hardware:
            key += (self.hardware,)
        return key

    @classmethod
    def wrap(cls, key: Hashable) -> "DecisionKey":
        """Adopt a legacy workload key (plain tuple, string, any
        hashable) into the IR without changing its cache identity."""
        if isinstance(key, DecisionKey):
            return key
        if isinstance(key, tuple) and key and isinstance(key[0], str):
            return cls(kind=key[0], shape=tuple(key[1:]), raw=key)
        return cls(kind=str(key), raw=key)

    def __str__(self) -> str:
        parts = ", ".join(str(s) for s in self.shape)
        text = f"{self.kind}({parts})"
        if self.dtype:
            text += f" {self.dtype}"
        if self.hardware:
            text += f" @{self.hardware}"
        return text


@dataclasses.dataclass(frozen=True)
class Decision:
    """One resolved decision: the execution parameters plus where they
    came from.  ``inputs`` is the (name, value) record that makes the
    decision explainable — everything the policy consumed."""

    key: DecisionKey
    policy: str                     # registered policy that produced it
    provenance: str                 # analytic | measured | online
    cores: int = 1                  # processing units / batch width
    chunk: int = 0                  # elements per task (0: not a chunked op)
    block_plan: tuple = ()          # Pallas blocks, when a kernel decision
    batch_width: int | None = None  # serve/train width when distinct
    acc: AccDecision | None = None  # full Overhead-Law record when present
    inputs: tuple = ()              # ((name, value), ...)

    def input(self, name: str, default: Any = None) -> Any:
        for k, v in self.inputs:
            if k == name:
                return v
        return default

    def explain(self) -> str:
        """One human-readable line: key, result, policy, inputs."""
        result = []
        if self.block_plan:
            result.append(f"block={self.block_plan}")
        else:
            result.append(f"cores={self.cores} chunk={self.chunk}")
        if self.batch_width is not None:
            result.append(f"width={self.batch_width}")
        shown = []
        for k, v in self.inputs:
            if k == "timings":  # candidate sweep: summarise, don't dump
                v = f"<{len(v)} measured>"
            shown.append(f"{k}={_fmt(v)}")
        return (f"[{self.policy}/{self.provenance:8s}] {self.key}: "
                + " ".join(result)
                + ("  " + " ".join(shown) if shown else ""))


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    seq: int
    decision: Decision


class DecisionTrace:
    """Append-only, bounded record of every decision the engine made.

    Bounded because a serving loop decides every tick forever; the
    ``dropped`` counter says how many early entries aged out, so a dump
    is never silently mistaken for the full history."""

    def __init__(self, maxlen: int = 4096):
        self._entries: deque[TraceEntry] = deque(maxlen=maxlen)
        self._seq = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def record(self, decision: Decision) -> TraceEntry:
        with self._lock:
            if len(self._entries) == self._entries.maxlen:
                self.dropped += 1
            entry = TraceEntry(self._seq, decision)
            self._seq += 1
            self._entries.append(entry)
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def __iter__(self) -> Iterator[TraceEntry]:
        return iter(list(self._entries))

    def entries(self, kind: str | None = None) -> list[TraceEntry]:
        out = list(self._entries)
        if kind is not None:
            out = [e for e in out if e.decision.key.kind == kind]
        return out

    def explain(self, kind: str | None = None,
                limit: int | None = None) -> str:
        entries = self.entries(kind)
        kinds = Counter(e.decision.key.kind for e in entries)
        header = (f"decision trace: {len(entries)} decisions"
                  + (f" (+{self.dropped} aged out)" if self.dropped else "")
                  + " — "
                  + ", ".join(f"{n} {k}" for k, n in sorted(kinds.items())))
        if limit is not None:
            entries = entries[-limit:]
        lines = [f"  #{e.seq:04d} {e.decision.explain()}" for e in entries]
        return "\n".join([header] + lines)


# ---------------------------------------------------------------------------
# Policies (the former silos, now pluggable)
# ---------------------------------------------------------------------------

class AnalyticOverheadLaw:
    """Analytic prior policy: the paper's Overhead Law, Eqs 1-10.

    This is the single in-repo gateway to ``overhead_law.decide`` — every
    cores/chunk decision (algorithms, serve ticks, train plans,
    customization-point defaults) flows through here.
    """

    name = "overhead-law"

    def decide(self, *, t_iter: float, count: int, t0: float,
               max_cores: int,
               eff: float = overhead_law.DEFAULT_EFFICIENCY,
               chunks_per_core: int = overhead_law.DEFAULT_CHUNKS_PER_CORE,
               snap_cores: Callable[[int], int] | None = None
               ) -> AccDecision:
        d = overhead_law.decide(
            t_iter=t_iter, n_elements=count, t0=t0, max_cores=max_cores,
            eff=eff, chunks_per_core=chunks_per_core)
        if snap_cores is not None and d.n_cores > 1:
            # Backend constraint (e.g. mesh shardings need a divisor of
            # the data extent): snap, then recompute the derived fields.
            cores = max(int(snap_cores(d.n_cores)), 1)
            if cores != d.n_cores:
                import math

                chunk = overhead_law.chunk_size(count, cores,
                                                chunks_per_core)
                d = dataclasses.replace(
                    d, n_cores=cores, chunk_elems=chunk,
                    n_chunks=math.ceil(count / chunk),
                    predicted_time=overhead_law.predicted_time(
                        d.t1, cores, t0),
                    predicted_speedup=overhead_law.speedup(d.t1, cores, t0),
                    predicted_efficiency=overhead_law.efficiency(
                        d.t1, cores, t0),
                )
        return d


class MeasuredBlockSearch:
    """Measured-search policy (the loop that was ``KernelTuner``'s).

    ``run`` callables execute the real kernel once for a candidate on
    synthetic data of the right shape and must synchronise internally
    (``jax.block_until_ready``).  Every probe runs inside an eager
    escape hatch so the clock times execution, not tracing, even when
    the consumer resolves plans mid-trace of an outer ``jax.jit``.
    """

    name = "measured-search"

    def __init__(self, repeats: int = 3):
        self.repeats = max(int(repeats), 1)

    @staticmethod
    def _eager():
        """Escape any ambient trace for the duration of a probe.

        ``eval_context`` restores a clean top-level context (unlike
        ``ensure_compile_time_eval``, it does not leak eager evaluation
        into the Pallas kernel's own trace); fall back to the latter if
        a future jax drops it.
        """
        import jax

        ctx = getattr(jax.core, "eval_context", None)
        return ctx() if ctx is not None else jax.ensure_compile_time_eval()

    def measure(self, run: Callable[..., None], cand: tuple,
                repeats: int | None = None) -> float:
        repeats = self.repeats if repeats is None else max(int(repeats), 1)
        with self._eager():
            run(*cand)                   # cold call: compile, untimed
            best = float("inf")
            for _ in range(repeats):
                t = time.perf_counter()
                run(*cand)
                best = min(best, time.perf_counter() - t)
        return best

    def search(self, candidates: Sequence[tuple],
               run: Callable[..., None],
               repeats: int | None = None
               ) -> tuple[tuple, float, tuple]:
        """Best-of-``repeats`` wall-clock over ``candidates``; returns
        (winner, winner_seconds, ((candidate, seconds), ...))."""
        timings = tuple((cand, self.measure(run, cand, repeats))
                        for cand in candidates)
        winner, seconds = min(timings, key=lambda cs: cs[1])
        return winner, seconds, timings


class OnlineEMA:
    """Online refinement policy (the loop that was ``OnlineFeedback`` →
    ``smooth_t_iter``): fold observed per-chunk wall-clock back into the
    calibration store with exponential smoothing, so the *next* decision
    sees the drifted reality instead of a one-shot calibration."""

    name = "online-ema"

    def __init__(self, alpha: float = DEFAULT_SMOOTHING):
        self.alpha = alpha

    def refine(self, cache: CalibrationCache, key: tuple, elems: int,
               seconds: float, alpha: float | None = None) -> float | None:
        if elems <= 0 or seconds <= 0.0:
            return None
        per_elem = seconds / max(int(elems), 1)
        value = cache.smooth_t_iter(
            key, per_elem, self.alpha if alpha is None else alpha)
        cache.note_provenance(key, ONLINE)
        return value


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ExecutionModel:
    """One decide→execute→observe→refine engine over one calibration
    store.  Construct directly for an isolated engine, or use
    ``ExecutionModel.of(cache)`` to share the engine (and its trace)
    among every consumer of that cache — which is how the acc object,
    the executor feedback layer, the kernel tuner and the serve/train
    planners end up in a single trace."""

    def __init__(self, cache: CalibrationCache | None = None, *,
                 hardware: str | None = None, trace_limit: int = 4096):
        self.cache = cache if cache is not None else CalibrationCache()
        self._hardware = hardware   # resolved lazily: hardware_key()
        self.trace = DecisionTrace(trace_limit)
        self.policies: dict[str, Any] = {}
        self.register_policy("prior", AnalyticOverheadLaw())
        self.register_policy("search", MeasuredBlockSearch())
        self.register_policy("refine", OnlineEMA())
        self._lock = threading.Lock()
        self._reported: dict[tuple, str] = {}   # provenance high-water
        self.decisions = 0
        self.cache_hits = 0     # tuned lookups answered from the store
        self.searches = 0       # measured candidate sweeps
        self.observations = 0   # online refinements folded in

    @property
    def hardware(self) -> str:
        """This process's accelerator id (resolved on first use so merely
        constructing an engine never touches the jax backend)."""
        if self._hardware is None:
            self._hardware = hardware_key()
        return self._hardware

    @classmethod
    def of(cls, cache: CalibrationCache) -> "ExecutionModel":
        """The engine bound to ``cache`` (created and attached on first
        use).  Everyone who shares the cache shares the engine."""
        model = getattr(cache, "_execution_model", None)
        if model is None:
            model = cls(cache)
            cache._execution_model = model
        return model

    def register_policy(self, slot: str, policy: Any) -> Any:
        """Register ``policy`` under ``slot`` (``prior`` / ``search`` /
        ``refine`` are the built-in slots; new subsystems may add their
        own and query them via ``self.policies``)."""
        self.policies[slot] = policy
        return policy

    # -- provenance ----------------------------------------------------------
    def provenance_of(self, key: DecisionKey | Hashable) -> str:
        """Strongest evidence level available for ``key`` — the max of
        what the store records and what this engine has ever reported
        (so provenance never downgrades within a process either)."""
        k = DecisionKey.wrap(key).cache_key()
        stored = self.cache.provenance(k)
        with self._lock:
            return provenance_max(stored, self._reported.get(k))

    def _finish(self, decision: Decision) -> Decision:
        k = decision.key.cache_key()
        with self._lock:
            self._reported[k] = provenance_max(
                self._reported.get(k), decision.provenance)
            self.decisions += 1
        self.trace.record(decision)
        return decision

    # -- queries -------------------------------------------------------------
    def cores_chunk(self, key: DecisionKey | Hashable, *, t_iter: float,
                    count: int, t0: float, max_cores: int,
                    eff: float = overhead_law.DEFAULT_EFFICIENCY,
                    chunks_per_core: int =
                    overhead_law.DEFAULT_CHUNKS_PER_CORE,
                    snap_cores: Callable[[int], int] | None = None,
                    evidence: Sequence[Hashable] = (),
                    inputs: tuple = ()) -> Decision:
        """Cores + chunk for a workload: the analytic prior policy over
        ``t_iter`` (which may itself be measured or online-refined —
        provenance reflects the strongest evidence behind the key).
        ``evidence`` names extra workload keys whose calibrations fed
        the ``t_iter`` input (e.g. a serve tick blends the prefill and
        decode keys), so their provenance counts too."""
        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        d = prior.decide(t_iter=t_iter, count=count, t0=t0,
                         max_cores=max_cores, eff=eff,
                         chunks_per_core=chunks_per_core,
                         snap_cores=snap_cores)
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=d.n_cores, chunk=d.chunk_elems, acc=d,
            inputs=(("t_iter", t_iter), ("count", count), ("t0", t0),
                    ("max_cores", max_cores)) + tuple(inputs)))

    def dispatch_depth(self, key: DecisionKey | Hashable, *,
                       host_overhead_s: float, device_step_s: float,
                       max_depth: int,
                       eff: float = overhead_law.DEFAULT_EFFICIENCY,
                       evidence: Sequence[Hashable] = (),
                       inputs: tuple = ()) -> Decision:
        """Dispatch depth for a fused device loop (decision kind
        ``serve_dispatch_depth``): how many iterations (decoded tokens)
        one device dispatch should carry so the fixed host overhead per
        dispatch amortises to the efficiency target.

        This is the paper's chunk-size floor re-read along the *time*
        axis: ``host_overhead_s`` is the ``T0`` paid once per dispatch
        (scheduler bookkeeping, engine queries, jit dispatch, the drain
        round-trip), ``device_step_s`` the per-iteration ``t_iter``, and
        the depth is the smallest ``k`` whose device work meets the
        ``T_opt = E/(1-E) * T0`` floor — at the default E=0.95, the
        dispatch must carry 19x its own overhead.  Clamped to
        ``[1, max_depth]`` (the compiled loop's static bound).

        The inputs are expected to come from calibrated/smoothed store
        entries; ``evidence`` names their keys so the decision's
        provenance reflects the strongest level backing them (online
        once the serve loop has timed real dispatches).
        """
        import math

        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        max_depth = max(int(max_depth), 1)
        if device_step_s > 0.0 and host_overhead_s > 0.0:
            depth = math.ceil(
                overhead_law.t_opt(host_overhead_s, eff) / device_step_s)
        elif host_overhead_s <= 0.0:
            depth = 1            # free dispatches: no need to fuse
        else:
            depth = max_depth    # unknown device time: amortise fully
        depth = min(max(depth, 1), max_depth)
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=1, chunk=depth,
            inputs=(("host_overhead_s", host_overhead_s),
                    ("device_step_s", device_step_s),
                    ("max_depth", max_depth), ("eff", eff))
            + tuple(inputs)))

    def admission_width(self, key: DecisionKey | Hashable, *,
                        queue_depth: int, free_slots: int,
                        host_tick_s: float, request_cost_s: float,
                        max_width: int, slack_s: float | None = None,
                        eff: float = overhead_law.DEFAULT_EFFICIENCY,
                        evidence: Sequence[Hashable] = (),
                        inputs: tuple = ()) -> Decision:
        """Admission width for a serving tick (decision kind
        ``serve_admission``): how many queued requests to admit into free
        cache slots *this* tick.

        This is Eq. 7's "leave units free" applied at the request level:
        slots are the processing units, the waiting queue is the
        workload, ``host_tick_s`` is the fixed cost every admission round
        pays (the measured ``serve_host_tick`` T0), and
        ``request_cost_s`` is one admitted request's prefill bill (the
        online-refined ``serve_prefill`` t_iter times its prompt).  The
        Overhead-Law prior yields the widest admission that keeps the
        tick efficient — admitting an entire burst at once parks
        requests in slots where their prefills stall the decode lanes
        and, under EDF, locks the pool against later, more urgent
        arrivals.

        ``slack_s`` is the head-of-queue deadline slack: when waiting
        another throttled tick would plausibly cost the deadline
        (slack inside two admission rounds), the width opens up to every
        free slot — deadline pressure beats efficiency.  Clamped to
        ``[1, min(free_slots, queue_depth, max_width)]`` (a tick with
        queued work and a free slot always admits at least one request:
        throttling must never become starvation).

        Both timing inputs are expected to come from the calibration
        store; ``evidence`` names their keys so provenance upgrades to
        online once the serve loop has timed real ticks and prefills.
        """
        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        cap = max(min(int(free_slots), int(queue_depth), int(max_width)), 1)
        d = prior.decide(t_iter=max(request_cost_s, 0.0),
                         count=max(int(queue_depth), 1),
                         t0=max(host_tick_s, 0.0), max_cores=cap, eff=eff,
                         chunks_per_core=1)
        width = min(max(d.n_cores, 1), cap)
        urgent = slack_s is not None and \
            slack_s <= 2.0 * (host_tick_s + request_cost_s)
        if urgent:
            width = cap
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=width, batch_width=width, acc=d,
            inputs=(("queue_depth", queue_depth),
                    ("free_slots", free_slots),
                    ("host_tick_s", host_tick_s),
                    ("request_cost_s", request_cost_s),
                    ("slack_s", slack_s), ("urgent", urgent))
            + tuple(inputs)))

    def mesh_batch(self, key: DecisionKey | Hashable, *,
                   demand: int, n_replicas: int, slots_per_replica: int,
                   host_tick_s: float, device_step_s: float,
                   eff: float = overhead_law.DEFAULT_EFFICIENCY,
                   evidence: Sequence[Hashable] = (),
                   inputs: tuple = ()) -> Decision:
        """Per-device batch width for a mesh-sharded serve loop (decision
        kind ``serve_mesh_batch``): how many decode lanes each
        data-parallel replica should keep active, so that
        ``global_batch = n_replicas * per_device_batch``.

        This is the paper's cores question at the next hardware scale:
        replicas took the place of cores when the serving path moved onto
        a device mesh, and the per-replica slot count is the resource the
        executor allocates.  The Overhead-Law prior reads the per-replica
        workload (``ceil(demand / n_replicas)`` requests) against the
        per-dispatch fixed cost: ``host_tick_s`` is the T0 every fused
        dispatch pays once for the whole mesh, ``device_step_s`` the
        measured per-token device time of the fused loop (the online-
        refined ``serve_decode_fused`` entry), and the width is Eq. 7's
        core count with slots-per-replica as the unit pool — opening
        every lane of an idle mesh is exactly the "more units than the
        workload can keep efficient" mistake the law prices.

        The key's ``hardware`` field is expected to carry the mesh shape
        (e.g. ``"cpu-8x...|mesh=4x2"``) so decisions made on one topology
        never back another.  Provenance follows ``evidence`` (the
        host-tick and fused-step timing keys): analytic until the serve
        loop has timed real dispatches, online after — never downgrading.
        """
        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        n_replicas = max(int(n_replicas), 1)
        slots_per_replica = max(int(slots_per_replica), 1)
        per_replica = max(-(-int(demand) // n_replicas), 1)  # ceil div
        d = prior.decide(t_iter=max(device_step_s, 0.0),
                         count=per_replica,
                         t0=max(host_tick_s, 0.0),
                         max_cores=slots_per_replica, eff=eff,
                         chunks_per_core=1)
        width = min(max(d.n_cores, 1), slots_per_replica)
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=width, batch_width=width * n_replicas, acc=d,
            inputs=(("demand", demand), ("n_replicas", n_replicas),
                    ("slots_per_replica", slots_per_replica),
                    ("host_tick_s", host_tick_s),
                    ("device_step_s", device_step_s), ("eff", eff))
            + tuple(inputs)))

    def page_size(self, key: DecisionKey | Hashable, *,
                  candidates: Sequence[int], max_len: int,
                  page_mgmt_s: float, prefill_token_s: float,
                  evidence: Sequence[Hashable] = (),
                  inputs: tuple = ()) -> Decision:
        """KV page size for a paged slot pool (decision kind
        ``serve_page_size``): how many token rows one page should hold.

        This is the paper's chunk-size question applied to *memory
        layout*.  A page is a chunk of cache rows, and the same two
        opposing costs price it: ``page_mgmt_s`` is the measured
        per-page fixed overhead a request pays on the host (table
        updates, refcounts, allocation — the ``T0`` of the Overhead Law,
        observed from the pool's ``ensure_writable``/table-build time),
        so small pages multiply it by ``max_len / ps``; and a prompt's
        tail page is half empty on average, so large pages waste
        ``ps / 2`` rows of prefill writes and prefix-shareable
        granularity, priced at the online-refined per-token prefill time
        ``prefill_token_s``.  The pick minimises

            cost(ps) = (max_len / ps) * page_mgmt_s
                     + (ps / 2)      * prefill_token_s

        over the candidate set — analytic until the serve loop has
        observed real page-management and prefill timings (the
        ``evidence`` keys), online after.  With no timing signal at all
        the middle candidate wins (pure prior).  The chosen size rides
        in ``chunk``.
        """
        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        cands = sorted({max(int(c), 1) for c in candidates})
        if not cands:
            raise ValueError("page_size needs at least one candidate")
        if page_mgmt_s <= 0.0 and prefill_token_s <= 0.0:
            ps = cands[len(cands) // 2]
            costs = ()
        else:
            scored = [(max_len / c * max(page_mgmt_s, 0.0)
                       + c / 2.0 * max(prefill_token_s, 0.0), c)
                      for c in cands]
            _, ps = min(scored)
            costs = tuple((c, round(s, 9)) for s, c in scored)
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=1, chunk=ps,
            inputs=(("max_len", max_len),
                    ("page_mgmt_s", page_mgmt_s),
                    ("prefill_token_s", prefill_token_s),
                    ("candidates", tuple(cands)),
                    ("costs", costs)) + tuple(inputs)))

    def prefill_interleave(self, key: DecisionKey | Hashable, *,
                           pending_chunks: int, decode_window_s: float,
                           chunk_cost_s: float, max_chunks: int,
                           evidence: Sequence[Hashable] = (),
                           inputs: tuple = ()) -> Decision:
        """Prefill/decode interleave ratio for a fused serve tick
        (decision kind ``serve_prefill_interleave``): how many prefill
        chunk-ops to run in the window one fused decode dispatch keeps
        the device busy.

        While a fused decode dispatch is in flight the host is free —
        that window is ``decode_window_s`` (the online-refined fused
        per-token time times the dispatch depth and active lanes).  Each
        prefill chunk costs ``chunk_cost_s`` of blocking host+device
        time; running more chunks than fit the window stalls the decode
        lanes when the next dispatch finds no queued work (the
        ``prefill_stall_s`` the throughput benchmark surfaces), while
        running fewer starves admission.  The ratio is simply how many
        chunks fit:

            r = clamp(floor(decode_window_s / chunk_cost_s),
                      1, min(pending_chunks, max_chunks))

        — at least one chunk always runs (prefill must never starve), at
        most what is actually pending.  An unknown chunk cost opens the
        cap: with nothing measured yet there is nothing to protect.
        Provenance follows the ``evidence`` keys (fused-step and prefill
        timings).  The ratio rides in ``chunk``.
        """
        import math

        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        cap = max(min(int(pending_chunks), int(max_chunks)), 1)
        if chunk_cost_s > 0.0 and decode_window_s > 0.0:
            r = int(math.floor(decode_window_s / chunk_cost_s))
        else:
            r = cap
        r = min(max(r, 1), cap)
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=1, chunk=r,
            inputs=(("pending_chunks", pending_chunks),
                    ("decode_window_s", decode_window_s),
                    ("chunk_cost_s", chunk_cost_s),
                    ("max_chunks", max_chunks)) + tuple(inputs)))

    def spec_depth(self, key: DecisionKey | Hashable, *,
                   candidates: Sequence[int], accept_rate: float,
                   step_s: float = 0.0,
                   width_cost: float = DEFAULT_SPEC_WIDTH_COST,
                   min_accept: float = MIN_SPEC_ACCEPT,
                   max_depth: int = 8,
                   current: int | None = None,
                   evidence: Sequence[Hashable] = (),
                   inputs: tuple = ()) -> Decision:
        """Speculation depth for a self-speculative fused decode loop
        (decision kind ``serve_spec_depth``): how many positions one
        draft-and-verify round should carry.

        This is the Overhead Law applied to the *model itself*, and the
        engine's first stochastic decision input: every verify round
        pays a fixed cost (the weight-streaming-bound decode step — the
        round's ``T0``) whether it emits one token or ``d``, and
        widening the verify by a draft costs only ``width_cost`` of
        that fixed step (the batch dim rides the same weight reads).
        With per-draft acceptance rate ``a``, a round of depth ``d``
        emits the longest matching prefix plus the corrected token:

            E(d, a)   = 1 + a + a^2 + ... + a^(d-1)   (expected tokens)
            cost(d)   = 1 + width_cost * (d - 1)      (relative round)
            score(d)  = E(d, a) / cost(d)             (tokens per round)

        and the pick is the argmax over the candidate set — ``d = 1``
        (speculation off) wins by construction whenever acceptance
        cannot pay the verify width, and is *forced* when the EMA'd
        acceptance collapses below ``min_accept`` (adaptive backoff:
        drafting noise must not tax the steady state).  ``accept_rate``
        is expected to come from the drain-time ``serve_spec_accept``
        EMA (analytic prior before any spec dispatch has drained);
        ``step_s`` is contextual (the measured per-round seconds behind
        the throughput claim, recorded for ``--explain-decisions``).

        ``current`` enables one-step hysteresis: acceptance observed at
        depth ``d`` is censored at ``d - 1`` accepted drafts, so a
        saturated reading (every draft accepted) says nothing about how
        much *deeper* runs would fare — extrapolating the geometric
        E(d, a) several ladder rungs up routinely overshoots, then
        crashes to backoff when the wider width's real acceptance lands.
        With ``current`` set, the pick moves at most one candidate rung
        per decision (collapse backoff still drops straight to 1), so
        each widening is validated by a drain at the new width before
        the next.  Provenance follows ``evidence``.  The chosen depth
        rides in ``chunk``.
        """
        dkey = DecisionKey.wrap(key)
        prior: AnalyticOverheadLaw = self.policies["prior"]
        max_depth = max(int(max_depth), 1)
        cands = sorted({min(max(int(c), 1), max_depth)
                        for c in candidates} | {1})
        a = min(max(float(accept_rate), 0.0), 0.999)
        backoff = a < min_accept
        if backoff:
            depth = 1
            scores = ()
        else:
            scored = [(sum(a ** i for i in range(c))
                       / (1.0 + width_cost * (c - 1)), c)
                      for c in cands]
            # max() prefers the shallower depth on exact ties (the
            # cheaper compile and smaller rollback window).
            depth = max(scored, key=lambda sc: (sc[0], -sc[1]))[1]
            scores = tuple((c, round(s, 6)) for s, c in scored)
            if current is not None:
                cur = min(max(int(current), 1), max_depth)
                ci = max(i for i, c in enumerate(cands) if c <= cur)
                pi = cands.index(depth)
                ni = ci + (1 if pi > ci else -1 if pi < ci else 0)
                if cands[ni] != depth:
                    inputs = (("unclamped", depth),) + tuple(inputs)
                    depth = cands[ni]
        provenance = self.provenance_of(dkey)
        for ekey in evidence:
            provenance = provenance_max(provenance,
                                        self.provenance_of(ekey))
        return self._finish(Decision(
            key=dkey, policy=prior.name, provenance=provenance,
            cores=1, chunk=depth,
            inputs=(("accept_rate", round(a, 4)),
                    ("width_cost", width_cost),
                    ("step_s", step_s),
                    ("backoff", backoff),
                    ("candidates", tuple(cands)),
                    ("scores", scores))
            + (() if current is None else (("current", int(current)),))
            + tuple(inputs)))

    def default_cores_chunk(self, count: int, max_cores: int) -> AccDecision:
        """The customization-point *default* decision (paper: "splits the
        work into equally sized chunks while utilizing all available
        processing units"): the Overhead Law degenerates to exactly that
        at zero measured cost and one chunk per core.  Untraced — it is
        the absence of a policy, not a policy."""
        return default_cores_chunk(count, max_cores,
                                   prior=self.policies["prior"])

    def tuned_blocks(self, key: DecisionKey | Hashable,
                     candidates: Sequence[tuple],
                     run: Callable[..., None], fields: tuple[str, ...], *,
                     repeats: int | None = None) -> Decision:
        """Measured winner for a kernel block key: from the store when a
        legal persisted record exists, else a candidate sweep through
        the measured-search policy, persisted for every later process
        sharing the store."""
        dkey = DecisionKey.wrap(key)
        k = dkey.cache_key()
        search: MeasuredBlockSearch = self.policies["search"]
        rec = self.cache.tuned(k)
        winner: tuple | None = None
        if rec is not None:
            try:
                winner = tuple(int(rec[f]) for f in fields)
                if any(v <= 0 for v in winner):
                    winner = None  # illegal block: re-measure
            except (KeyError, TypeError, ValueError):
                winner = None      # torn/foreign record: re-measure
        if winner is not None:
            with self._lock:
                self.cache_hits += 1
            return self._finish(Decision(
                key=dkey, policy=search.name, provenance=MEASURED,
                block_plan=winner,
                inputs=(("prior", tuple(candidates[0])),
                        ("measured", False), ("from_store", True))))
        winner, seconds, timings = search.search(candidates, run, repeats)
        with self._lock:
            self.searches += 1
        record = {f: int(v) for f, v in zip(fields, winner, strict=True)}
        record.update(hw=dkey.hardware or self.hardware, seconds=seconds,
                      candidates=len(candidates))
        self.cache.set_tuned(k, record)
        self.cache.note_provenance(k, MEASURED)
        return self._finish(Decision(
            key=dkey, policy=search.name, provenance=MEASURED,
            block_plan=winner,
            inputs=(("prior", tuple(candidates[0])),
                    ("measured", True), ("seconds", seconds),
                    ("candidates", len(candidates)),
                    ("timings", timings))))

    def observe(self, key: DecisionKey | Hashable, elems: int,
                seconds: float, alpha: float | None = None) -> float | None:
        """Fold one observed chunk timing into the store (online
        refinement stage).  Returns the smoothed per-element time now
        backing decisions for ``key``.  Observations are counted but not
        traced — they refine inputs; decisions consume them."""
        refine: OnlineEMA = self.policies["refine"]
        k = DecisionKey.wrap(key).cache_key()
        value = refine.refine(self.cache, k, elems, seconds, alpha)
        if value is not None:
            with self._lock:
                self.observations += 1
        return value

    def measured_t_iter(self, key: DecisionKey | Hashable,
                        measure: Callable[[], float]) -> float:
        """Memoised one-shot t_iter measurement (paper Section 4.2),
        recorded as ``measured`` provenance for the key."""
        k = DecisionKey.wrap(key).cache_key()
        value = self.cache.t_iter(k, measure)
        self.cache.note_provenance(k, MEASURED)
        return value

    def smoothed_t_iter(self, key: DecisionKey | Hashable) -> float | None:
        """Current (possibly online-refined) t_iter for ``key``."""
        return self.cache.peek_t_iter(DecisionKey.wrap(key).cache_key())

    def t0(self, key: DecisionKey | Hashable,
           measure: Callable[[], float]) -> float:
        """Memoised T0 calibration through the shared store."""
        k = DecisionKey.wrap(key).cache_key()
        value = self.cache.t0(k, measure)
        self.cache.note_provenance(k, MEASURED)
        return value

    def note(self, key: DecisionKey | Hashable, *, policy: str,
             cores: int = 1, chunk: int = 0, block_plan: tuple = (),
             batch_width: int | None = None,
             acc: AccDecision | None = None,
             inputs: tuple = ()) -> Decision:
        """Trace a derived decision a consumer finalised outside the
        built-in policies (e.g. the train planner's divisor snapping) so
        the dump still attributes the *final* numbers."""
        dkey = DecisionKey.wrap(key)
        return self._finish(Decision(
            key=dkey, policy=policy, provenance=self.provenance_of(dkey),
            cores=cores, chunk=chunk, block_plan=tuple(block_plan),
            batch_width=batch_width, acc=acc, inputs=tuple(inputs)))

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {"decisions": self.decisions,
                    "cache_hits": self.cache_hits,
                    "searches": self.searches,
                    "observations": self.observations,
                    "trace_len": len(self.trace),
                    "hardware": self.hardware}

    def explain(self, kind: str | None = None,
                limit: int | None = None) -> str:
        s = self.stats()
        header = (f"ExecutionModel[{s['hardware']}]: "
                  f"{s['decisions']} decisions, {s['searches']} searches, "
                  f"{s['cache_hits']} store hits, "
                  f"{s['observations']} observations")
        return header + "\n" + self.trace.explain(kind=kind, limit=limit)


_DEFAULT_PRIOR = AnalyticOverheadLaw()


def default_cores_chunk(count: int, max_cores: int, *,
                        prior: AnalyticOverheadLaw | None = None
                        ) -> AccDecision:
    """The shared customization-point default (see
    ``ExecutionModel.default_cores_chunk``): all available units, equal
    chunks, via the same Overhead-Law policy every engine uses — the
    defaults in core/customization.py delegate here instead of
    reimplementing the formulas."""
    prior = prior if prior is not None else _DEFAULT_PRIOR
    return prior.decide(t_iter=0.0, count=max(int(count), 1), t0=0.0,
                        max_cores=max(int(max_cores), 1),
                        chunks_per_core=1)


_DECISION_OVERHEAD_S: float | None = None


def decision_overhead_s() -> float:
    """Measured seconds per engine decision on this host, memoised.

    The decision-engine microbench (benchmarks/executor_overhead.py)
    inlined: an isolated engine answers a warm ``cores_chunk`` query in
    a tight loop.  Consumers (the serve scheduler's fused-dispatch
    seeding) use it as the *analytic* component of the host-overhead
    estimate before any real tick has been timed — a scheduler tick
    makes a handful of engine queries, so its host floor is a small
    multiple of this number.
    """
    global _DECISION_OVERHEAD_S
    if _DECISION_OVERHEAD_S is None:
        engine = ExecutionModel(CalibrationCache())
        key = DecisionKey("microbench", ())

        def query():
            engine.cores_chunk(key, t_iter=1e-6, count=4096, t0=1e-5,
                               max_cores=4)

        for _ in range(8):
            query()              # warm: caches, code paths
        n = 64
        start = time.perf_counter()
        for _ in range(n):
            query()
        _DECISION_OVERHEAD_S = (time.perf_counter() - start) / n
    return _DECISION_OVERHEAD_S
