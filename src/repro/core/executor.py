"""Executors: where chunks actually run (v2, HPX-faithful surface).

Three backends share one protocol of four *execution functions*, mirroring
``hpx::parallel::execution``:

* ``sync_execute(fn, *args)``          — run one task, return its value;
* ``async_execute(fn, *args)``         — run one task, return a ``Future``;
* ``bulk_async_execute(fn, chunks)``   — one task per chunk, list of futures;
* ``then_execute(fn, future)``         — continuation: run ``fn`` on the
  future's value through this executor, return the chained future.

``bulk_sync_execute`` (the v1 sync surface, deprecated in the v2 API
release) has been **removed**: accessing it raises ``AttributeError``
with a pointer to the ``when_all(bulk_async_execute(...))`` spelling.

Backends:

* ``SequentialExecutor``  — in-order, inline, no parallel overhead.
* ``HostParallelExecutor``— a thread pool over jit-compiled chunk thunks.
  XLA releases the GIL during computation, so on a multi-core host this is
  genuine parallelism; it is the faithful analogue of HPX's thread pool and
  the backend used for the paper-figure wall-clock benchmarks.  Supports
  ``with`` for deterministic pool shutdown.
* ``MeshExecutor``        — a JAX device mesh.  It does not run Python
  thunks per chunk (that would serialize an SPMD program); bulk execution
  raises ``UnsupportedOperation`` pointing at the shard_map backend in
  algorithms/detail.py.  It carries the mesh and exposes the unit count and
  sub-mesh selection used by that backend and the training/serving loops.

Executors may overload customization points simply by defining methods of
the same name (see core/customization.py); ``AdaptiveExecutor``
(core/adaptive.py) is the executor that does.  Properties/annotations
(``with_priority`` / ``with_hint`` / ``with_params``) come from the
``PropertySupport`` mixin (core/properties.py).
"""
from __future__ import annotations

import concurrent.futures as _cf
import dataclasses
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from .future import Future
from .properties import PropertySupport


class UnsupportedOperation(RuntimeError):
    """An execution function this executor cannot meaningfully provide."""


@dataclasses.dataclass(frozen=True)
class Chunk:
    """Half-open element range [start, start + size) assigned to one task."""

    start: int
    size: int


def make_chunks(count: int, chunk_elems: int) -> list[Chunk]:
    """Split ``count`` elements into tasks of ``chunk_elems`` (last partial)."""
    if count <= 0:
        return []
    chunk_elems = max(int(chunk_elems), 1)
    return [
        Chunk(start, min(chunk_elems, count - start))
        for start in range(0, count, chunk_elems)
    ]


@runtime_checkable
class Executor(Protocol):
    def num_units(self) -> int: ...

    def sync_execute(self, fn: Callable[..., Any], *args: Any) -> Any: ...

    def async_execute(self, fn: Callable[..., Any], *args: Any) -> Future: ...

    def bulk_async_execute(
        self, fn: Callable[[Chunk], Any], chunks: Sequence[Chunk]
    ) -> list[Future]: ...

    def then_execute(
        self, fn: Callable[[Any], Any], future: Future
    ) -> Future: ...


class ExecutorBase:
    """Default execution functions, all derived from ``async_execute``
    (inline, on the calling thread).  Backends override the primitives
    they can do better — exactly HPX's executor-customization design."""

    def sync_execute(self, fn: Callable[..., Any], *args: Any) -> Any:
        return self.async_execute(fn, *args).result()

    def async_execute(self, fn: Callable[..., Any], *args: Any) -> Future:
        return Future.from_call(fn, *args)

    def bulk_async_execute(self, fn, chunks) -> list[Future]:
        return [self.async_execute(fn, c) for c in chunks]

    def then_execute(self, fn: Callable[[Any], Any], future: Future) -> Future:
        return future.then(fn, executor=self)

    # -- removed v1 surface --------------------------------------------------
    def __getattr__(self, name: str):
        # Only reached when normal attribute lookup fails.  The v1
        # bulk_sync_execute shim (deprecated through the v2 API release)
        # is gone; fail hard with the migration pointer instead of a
        # generic AttributeError.
        if name == "bulk_sync_execute":
            raise AttributeError(
                "bulk_sync_execute was removed from the executor API; use "
                "when_all(executor.bulk_async_execute(fn, chunks)).result() "
                "(repro.core.when_all)")
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")


class SequentialExecutor(ExecutorBase, PropertySupport):
    """Runs every task in order on the calling thread; futures come back
    already resolved (``seq`` policy)."""

    def num_units(self) -> int:
        return 1


class HostParallelExecutor(ExecutorBase, PropertySupport):
    """Thread pool over chunk thunks (HPX thread-pool analogue).

    ``max_workers`` bounds the pool; the *effective* unit count for a given
    workload is decided by the execution-parameters object (e.g. acc) via
    the chunk count of each bulk call — the pool never runs more chunks
    concurrently than it has workers.

    Use as a context manager for deterministic pool shutdown::

        with HostParallelExecutor(max_workers=4) as ex:
            futs = ex.bulk_async_execute(thunk, chunks)
            outs = when_all(futs).result()

    ``__del__`` remains as a best-effort backstop only.
    """

    def __init__(self, max_workers: int | None = None):
        import os

        self._max_workers = max_workers or (os.cpu_count() or 1)
        self._pool: _cf.ThreadPoolExecutor | None = None
        self._owns_pool = True

    def __copy__(self) -> "HostParallelExecutor":
        # Property annotation clones share the pool but must not tear it
        # down when garbage-collected (only explicit shutdown/__exit__ or
        # the owning instance's __del__ may).
        clone = type(self).__new__(type(self))
        clone.__dict__.update(self.__dict__)
        clone._owns_pool = False
        return clone

    def num_units(self) -> int:
        return self._max_workers

    def _ensure_pool(self) -> _cf.ThreadPoolExecutor:
        if self._pool is None:
            self._pool = _cf.ThreadPoolExecutor(max_workers=self._max_workers)
            self._owns_pool = True
        return self._pool

    def async_execute(self, fn, *args) -> Future:
        return Future(self._ensure_pool().submit(fn, *args))

    def bulk_async_execute(self, fn, chunks) -> list[Future]:
        if len(chunks) <= 1:
            # Degenerate bulk: inline, no dispatch overhead.
            return [Future.from_call(fn, c) for c in chunks]
        pool = self._ensure_pool()
        return [Future(pool.submit(fn, c)) for c in chunks]

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "HostParallelExecutor":
        self._ensure_pool()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    def __del__(self):  # pragma: no cover - best-effort cleanup
        try:
            if getattr(self, "_owns_pool", True):
                self.shutdown()
        except Exception:
            pass


class MeshExecutor(ExecutorBase, PropertySupport):
    """Executor view of a JAX device mesh.

    ``data_axes`` are the axes over which a data-parallel workload may be
    spread; ``num_units`` is their total extent.  ``submesh_size(n)`` maps
    an acc core-count decision onto a realisable device count (a divisor of
    the full extent, so shardings stay regular).

    Bulk execution of Python thunks is *not* provided: running one thunk
    per chunk on the driver would serialize what shard_map runs SPMD, which
    is a silent performance bug, so ``bulk_async_execute`` /
    ``bulk_sync_execute`` raise ``UnsupportedOperation``.  Single-task
    ``sync_execute`` / ``async_execute`` / ``then_execute`` run inline on
    the driver (they launch whole jitted SPMD programs, not per-chunk
    work).
    """

    def __init__(self, mesh, data_axes: tuple[str, ...] = ("data",)):
        self.mesh = mesh
        self.data_axes = tuple(a for a in data_axes if a in mesh.shape)
        n = 1
        for a in self.data_axes:
            n *= mesh.shape[a]
        self._units = n

    def num_units(self) -> int:
        return self._units

    def submesh_size(self, n_cores: int) -> int:
        """Largest divisor of the data extent that is <= n_cores (>= 1)."""
        n_cores = max(min(int(n_cores), self._units), 1)
        for d in range(n_cores, 0, -1):
            if self._units % d == 0:
                return d
        return 1

    def bulk_async_execute(self, fn, chunks):
        raise UnsupportedOperation(
            "MeshExecutor does not run per-chunk Python thunks (that would "
            "serialize an SPMD program on the driver). Use the shard_map "
            "backend: repro.algorithms.detail.mesh_map / mesh_reduce / "
            "mesh_scan over an acc-sized sub-mesh.")

    def bulk_sync_execute(self, fn, chunks):
        # Deliberately not the deprecation shim: fail loudly either way.
        self.bulk_async_execute(fn, chunks)


def unwrap_executor(executor: Any) -> Any:
    """Innermost executor of a wrapper chain (``inner`` attributes)."""
    seen = set()
    while id(executor) not in seen:
        seen.add(id(executor))
        inner = getattr(executor, "inner", None)
        if inner is None:
            return executor
        executor = inner
    return executor


def mesh_executor_of(executor: Any) -> MeshExecutor | None:
    """The ``MeshExecutor`` behind ``executor`` (itself or through
    wrappers such as ``AdaptiveExecutor``), or None."""
    ex = unwrap_executor(executor)
    return ex if isinstance(ex, MeshExecutor) else None
