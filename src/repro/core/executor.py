"""Executors: where chunks actually run.

Three backends share one protocol:

* ``SequentialExecutor``  — in-order, no parallel overhead (``seq`` policy).
* ``HostParallelExecutor``— a thread pool over jit-compiled chunk thunks.
  XLA releases the GIL during computation, so on a multi-core host this is
  genuine parallelism; it is the faithful analogue of HPX's thread pool and
  the backend used for the paper-figure wall-clock benchmarks.
* ``MeshExecutor``        — a JAX device mesh.  It does not run Python
  thunks per chunk; instead it carries the mesh and exposes the unit count
  and sub-mesh selection used by the shard_map-based algorithm backend and
  the training/serving loops.

Executors may overload customization points simply by defining methods of
the same name (see core/customization.py); none of these defaults do, so
all adaptivity lives in the execution-parameters objects (core/acc.py).
"""
from __future__ import annotations

import concurrent.futures as _cf
import dataclasses
from typing import Any, Callable, Protocol, Sequence, runtime_checkable


@dataclasses.dataclass(frozen=True)
class Chunk:
    """Half-open element range [start, start + size) assigned to one task."""

    start: int
    size: int


def make_chunks(count: int, chunk_elems: int) -> list[Chunk]:
    """Split ``count`` elements into tasks of ``chunk_elems`` (last partial)."""
    if count <= 0:
        return []
    chunk_elems = max(int(chunk_elems), 1)
    return [
        Chunk(start, min(chunk_elems, count - start))
        for start in range(0, count, chunk_elems)
    ]


@runtime_checkable
class Executor(Protocol):
    def num_units(self) -> int: ...

    def bulk_sync_execute(
        self, fn: Callable[[Chunk], Any], chunks: Sequence[Chunk]
    ) -> list[Any]: ...


class SequentialExecutor:
    """Runs every chunk in order on the calling thread."""

    def num_units(self) -> int:
        return 1

    def bulk_sync_execute(self, fn, chunks):
        return [fn(c) for c in chunks]


class HostParallelExecutor:
    """Thread pool over chunk thunks (HPX thread-pool analogue).

    ``max_workers`` bounds the pool; the *effective* unit count for a given
    workload is decided by the execution-parameters object (e.g. acc) and
    passed per-call via ``bulk_sync_execute``'s implicit chunk count — the
    pool never runs more chunks concurrently than it has workers.
    """

    def __init__(self, max_workers: int | None = None):
        import os

        self._max_workers = max_workers or (os.cpu_count() or 1)
        self._pool: _cf.ThreadPoolExecutor | None = None

    def num_units(self) -> int:
        return self._max_workers

    def _ensure_pool(self) -> _cf.ThreadPoolExecutor:
        if self._pool is None:
            self._pool = _cf.ThreadPoolExecutor(max_workers=self._max_workers)
        return self._pool

    def bulk_sync_execute(self, fn, chunks):
        if len(chunks) <= 1:
            return [fn(c) for c in chunks]
        pool = self._ensure_pool()
        return list(pool.map(fn, chunks))

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __del__(self):  # pragma: no cover - best-effort cleanup
        try:
            self.shutdown()
        except Exception:
            pass


class MeshExecutor:
    """Executor view of a JAX device mesh.

    ``data_axes`` are the axes over which a data-parallel workload may be
    spread; ``num_units`` is their total extent.  ``submesh_size(n)`` maps
    an acc core-count decision onto a realisable device count (a divisor of
    the full extent, so shardings stay regular).
    """

    def __init__(self, mesh, data_axes: tuple[str, ...] = ("data",)):
        self.mesh = mesh
        self.data_axes = tuple(a for a in data_axes if a in mesh.shape)
        n = 1
        for a in self.data_axes:
            n *= mesh.shape[a]
        self._units = n

    def num_units(self) -> int:
        return self._units

    def submesh_size(self, n_cores: int) -> int:
        """Largest divisor of the data extent that is <= n_cores (>= 1)."""
        n_cores = max(min(int(n_cores), self._units), 1)
        for d in range(n_cores, 0, -1):
            if self._units % d == 0:
                return d
        return 1

    def bulk_sync_execute(self, fn, chunks):
        # Mesh execution happens inside jit/shard_map; running Python thunks
        # per chunk would defeat SPMD.  Sequential fallback for generic use.
        return [fn(c) for c in chunks]
