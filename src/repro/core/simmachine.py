"""Calibrated machine model for reproducing the paper's figures.

The evaluation machines (40-core Skylake, 48-core EPYC) are not available
in this container (1 CPU core), so the wall-clock experiments of Figures
1-4 are reproduced against this discrete-event model:

* a parallel region costs ``t0`` once (the Overhead Law's constant),
* each scheduled chunk costs ``t_task`` (per-task scheduling overhead —
  this is what makes *excessive* chunking lose, paper Section 5),
* each element costs ``t_iter`` (memory- or compute-bound, calibrated),
* each chunk's runtime gets deterministic multiplicative jitter (system
  noise / cache effects; what makes over-decomposition *win*),
* chunks are placed by greedy earliest-finish list scheduling, which is
  the standard model of HPX's work stealing.

The model is deliberately simple — it contains the Overhead Law as its
noise-free, zero-task-cost limit, so tests can check both the closed-form
equations and the richer figure shapes against one artefact.
"""
from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from .executor import make_chunks
from .overhead_law import AccDecision


@dataclasses.dataclass(frozen=True)
class SimMachine:
    name: str
    cores: int
    t0: float            # parallel-region overhead, base (s)
    t_task: float        # per-scheduled-chunk overhead (s)
    jitter: float        # std-dev of multiplicative chunk noise (0 = exact)
    t0_percore: float = 0.4e-6   # region overhead grows with woken cores
    seed: int = 0

    def t0_for(self, n_cores: int) -> float:
        """Region overhead when opening a region across n cores — this is
        what the empty-task benchmark measures (at full width)."""
        return self.t0 + self.t0_percore * max(n_cores, 1)

    def run(self, *, t_iter: float, count: int, n_cores: int,
            chunk_elems: int, saturation_cores: int | None = None) -> float:
        """Simulated wall-clock seconds for one parallel-for invocation.

        ``saturation_cores``: for memory-bound bodies, the core count at
        which the socket bandwidth saturates — beyond it, per-element time
        inflates by n/saturation (total throughput capped).  This is what
        limits the paper's adjacent-difference to ~10× on 40 cores."""
        if n_cores <= 1:
            return t_iter * count
        if saturation_cores is not None and n_cores > saturation_cores:
            t_iter = t_iter * (n_cores / saturation_cores)
        chunks = make_chunks(count, chunk_elems)
        rng = np.random.RandomState(
            (self.seed * 1000003 + count * 131 + n_cores * 17
             + chunk_elems) % (2**31 - 1))
        noise = (1.0 + self.jitter * np.abs(rng.standard_normal(len(chunks)))
                 if self.jitter > 0 else np.ones(len(chunks)))
        durations = [self.t_task + c.size * t_iter * float(n)
                     for c, n in zip(chunks, noise, strict=True)]
        # Greedy earliest-finish placement (work-stealing model).
        heap = [0.0] * min(n_cores, len(chunks))
        heapq.heapify(heap)
        for d in durations:
            t = heapq.heappop(heap)
            heapq.heappush(heap, t + d)
        return self.t0_for(n_cores) + max(heap)

    def speedup(self, *, t_iter: float, count: int, n_cores: int,
                chunks_per_core: int,
                saturation_cores: int | None = None) -> float:
        t1 = t_iter * count
        chunk = max(math.ceil(count / max(n_cores * chunks_per_core, 1)), 1)
        tn = self.run(t_iter=t_iter, count=count, n_cores=n_cores,
                      chunk_elems=chunk, saturation_cores=saturation_cores)
        return t1 / tn if tn > 0 else 1.0

    def run_decision(self, d: AccDecision,
                     saturation_cores: int | None = None) -> float:
        return self.run(t_iter=d.t_iter, count=d.n_elements,
                        n_cores=d.n_cores, chunk_elems=d.chunk_elems,
                        saturation_cores=saturation_cores)


# The paper's machines, with overheads of the order HPX reports
# (lightweight user-level tasks: microsecond-scale region costs).
SKYLAKE_40 = SimMachine(name="intel-skylake-40c", cores=40,
                        t0=2e-6, t_task=0.3e-6, jitter=0.05,
                        t0_percore=0.4e-6)
EPYC_48 = SimMachine(name="amd-epyc-48c", cores=48,
                     t0=2.5e-6, t_task=0.35e-6, jitter=0.05,
                     t0_percore=0.4e-6)
