"""tag_invoke-style customization points (paper Section 4.1/4.2).

HPX dispatches its algorithm-internal hooks through ``tag_invoke``: a
callable tag object finds, via ADL, an overload supplied by either the
*execution parameters* object or the *executor*, falling back to a default.
Python has no ADL; the equivalent dispatch rule here is attribute lookup,
in priority order:

    1. a method named after the tag on the execution-parameters object,
    2. a method named after the tag on the executor,
    3. the registered default implementation.

This preserves the property the paper leans on: new behaviour (the acc
object) plugs into the unchanged algorithm implementations purely by
defining the three methods — no algorithm code changes.
"""
from __future__ import annotations

from typing import Any, Callable


class CustomizationPoint:
    """A named, overloadable hook ("tag" in tag_invoke terms)."""

    def __init__(self, name: str, default: Callable[..., Any]):
        self.name = name
        self._default = default

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<customization point {self.name}>"

    def __call__(self, params: Any, executor: Any, *args: Any, **kw: Any) -> Any:
        impl = getattr(params, self.name, None)
        if callable(impl):
            return impl(executor, *args, **kw)
        impl = getattr(executor, self.name, None)
        if callable(impl):
            return impl(*args, **kw)
        return self._default(params, executor, *args, **kw)


# ---------------------------------------------------------------------------
# Defaults (paper: "The default implementations for these customization
# points splits the work into equally sized chunks while utilizing all
# available processing units.")
#
# Both defaults delegate to the ExecutionModel's analytic prior policy
# at zero measured cost and one chunk per core — the Overhead Law
# degenerates to exactly the paper's default there (all units, equal
# chunks, never more units than chunks).  One formula, one owner;
# previously these were a drifting reimplementation of the same math.
# ---------------------------------------------------------------------------

def _default_measure_iteration(params, executor, body, count: int) -> float:
    """Default: no measurement — report zero cost so the default policy
    (all units, equal chunks) is used unchanged."""
    return 0.0


def _default_units(executor) -> int:
    units = getattr(executor, "num_units", None)
    if callable(units):
        return max(int(units()), 1)
    return 1


def _default_processing_units_count(params, executor, t_iter: float, count: int) -> int:
    from .model import default_cores_chunk

    return default_cores_chunk(count, _default_units(executor)).n_cores


def _default_get_chunk_size(params, executor, t_iter: float, cores: int, count: int) -> int:
    from .model import default_cores_chunk

    return default_cores_chunk(count, max(int(cores), 1)).chunk_elems


measure_iteration = CustomizationPoint(
    "measure_iteration", _default_measure_iteration)
processing_units_count = CustomizationPoint(
    "processing_units_count", _default_processing_units_count)
get_chunk_size = CustomizationPoint(
    "get_chunk_size", _default_get_chunk_size)
