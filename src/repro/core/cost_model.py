"""Analytic cost model: the mesh-side ``measure_iteration``.

On the production mesh we cannot (and should not) wall-clock a sample chunk
per workload — instead the per-element time is derived from the workload's
arithmetic intensity through the hardware roofline, and ``T0`` from the
collective path.  The outputs feed the *same* Overhead-Law solver as the
measured host numbers, which is the point: one model, two measurement
backends.
"""
from __future__ import annotations

import dataclasses

from .hardware import HardwareSpec


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Per-element cost of one loop body."""

    flops_per_elem: float
    bytes_per_elem: float
    name: str = "workload"

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_elem / max(self.bytes_per_elem, 1e-30)


# The paper's two benchmark bodies -----------------------------------------
# adjacent_difference: out[i] = in[i] - in[i-1]  -> 1 flop, 2 loads + 1 store
ADJACENT_DIFFERENCE = WorkloadProfile(
    flops_per_elem=1.0, bytes_per_elem=3 * 8, name="adjacent_difference")
# artificial work: K fused multiply-adds per element, negligible traffic
def artificial_work(k: int = 256) -> WorkloadProfile:
    return WorkloadProfile(
        flops_per_elem=2.0 * k, bytes_per_elem=2 * 8,
        name=f"artificial_work_{k}")


def t_iter_analytic(profile: WorkloadProfile, hw: HardwareSpec) -> float:
    """Roofline per-element time: max(compute term, memory term)."""
    return max(profile.flops_per_elem / hw.peak_flops,
               profile.bytes_per_elem / hw.mem_bw)


def t0_analytic(hw: HardwareSpec, n_units: int | None = None,
                sync_bytes: float = 0.0) -> float:
    """Overhead of opening a parallel region across ``n_units``:
    launch + collective latency + bandwidth term for any synchronised
    payload (e.g. a psum of ``sync_bytes``)."""
    t = hw.t0_parallel(n_units)
    if sync_bytes > 0:
        t += sync_bytes / hw.link_bw
    return t


# --- Roofline terms for compiled computations (used by analysis/) ---------

def time_compute(flops: float, hw: HardwareSpec, chips: int = 1) -> float:
    return flops / (chips * hw.peak_flops)


def time_memory(bytes_accessed: float, hw: HardwareSpec, chips: int = 1) -> float:
    return bytes_accessed / (chips * hw.mem_bw)


def time_collective(collective_bytes: float, hw: HardwareSpec,
                    chips: int = 1) -> float:
    return collective_bytes / (chips * hw.link_bw)


def model_flops_dense(n_params: float, tokens: float, training: bool = True) -> float:
    """6·N·D for training; 2·N·D for a forward/serve step."""
    return (6.0 if training else 2.0) * n_params * tokens


def model_flops_moe(n_active_params: float, tokens: float,
                    training: bool = True) -> float:
    return (6.0 if training else 2.0) * n_active_params * tokens
