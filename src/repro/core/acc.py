"""``adaptive_core_chunk_size`` (acc) — the paper's contribution.

An execution-parameters object that overloads the three customization
points (it simply defines methods with the tag names; see
core/customization.py for the dispatch rule):

* ``measure_iteration``       — wall-clock a sample chunk (host) or evaluate
  the analytic roofline (mesh / WorkloadProfile), cached per workload key;
* ``processing_units_count``  — Eq. 7, clamped to the executor's units;
* ``get_chunk_size``          — Eq. 10 with the T_m floor.

``decide`` exposes the full decision record for the training loop, the
serving engine, and the Pallas tuner, which need more than the three
scalar answers.

Since the ExecutionModel unification (core/model.py) this object is a
*front-end*: it gathers the runtime metrics (T0, t_iter) and asks the
engine bound to its calibration cache for the decision, so every
core/chunk choice lands in one explainable trace with provenance.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Hashable

from . import calibration, overhead_law
from .cost_model import WorkloadProfile, t0_analytic, t_iter_analytic
from .executor import Executor, mesh_executor_of
from .hardware import TPU_V5E, HardwareSpec
from .model import DecisionKey, ExecutionModel


@dataclasses.dataclass
class AdaptiveCoreChunk:
    """Execution-parameters object implementing the paper's acc policy."""

    efficiency: float = overhead_law.DEFAULT_EFFICIENCY
    chunks_per_core: int = overhead_law.DEFAULT_CHUNKS_PER_CORE
    hardware: HardwareSpec = TPU_V5E      # used for analytic backends
    t0_override: float | None = None      # tests / reproducibility
    cache: calibration.CalibrationCache = dataclasses.field(
        default_factory=calibration.CalibrationCache)
    # The workload key most recently passed to measure_iteration: the
    # paper's call sequence (measure → units → chunk) runs the three
    # customization points back-to-back with fixed signatures, so the
    # key seen at measurement time is stashed here to label the decision
    # in the engine trace.  Single decision loop per acc object by
    # construction (scheduler tick / plan() call); not a concurrency
    # hazard in practice, and only trace labels ride on it.
    _last_workload_key: Hashable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def model(self) -> ExecutionModel:
        """The decision engine bound to this object's calibration cache
        (shared with the feedback layer and the kernel tuner)."""
        return ExecutionModel.of(self.cache)

    # -- T0 ---------------------------------------------------------------
    def calibrate_t0(self, executor: Executor) -> float:
        if self.t0_override is not None:
            return self.t0_override
        if mesh_executor_of(executor) is not None:
            return t0_analytic(self.hardware, executor.num_units())
        # Key by backend type + width, not object identity: identical
        # executors share one calibration and the entry survives process
        # restarts through CalibrationCache persistence.
        from .executor import unwrap_executor

        inner = unwrap_executor(executor)
        key = ("t0", type(inner).__name__, max(executor.num_units(), 1))
        return self.model.t0(
            key, lambda: calibration.measure_t0_empty_task(executor))

    # -- customization point: measure_iteration ----------------------------
    def measure_iteration(self, executor: Executor, body: Any,
                          count: int, key: Hashable | None = None) -> float:
        """Seconds per element for ``body``.

        ``body`` is either a ``WorkloadProfile`` (analytic path) or a
        callable ``body(start, size)`` chunk thunk (measured path).
        Measured once per workload key, then cached (paper Section 4.2).
        """
        self._last_workload_key = key
        if isinstance(body, WorkloadProfile):
            # Analytic seed, but online feedback wins once present: a keyed
            # profile workload whose chunks have been timed (core/feedback)
            # reads the smoothed observation instead of the roofline guess.
            if key is not None:
                smoothed = self.model.smoothed_t_iter(key)
                if smoothed is not None:
                    return smoothed
            return t_iter_analytic(body, self.hardware)
        k = key if key is not None else ("t_iter", getattr(body, "__name__", id(body)))
        self._last_workload_key = k
        return self.model.measured_t_iter(
            k, lambda: calibration.measure_iteration_wallclock(body, count))

    # -- customization point: processing_units_count ------------------------
    def processing_units_count(self, executor: Executor, t_iter: float,
                               count: int) -> int:
        d = self.decide(executor, t_iter, count)
        return d.n_cores

    # -- customization point: get_chunk_size --------------------------------
    def get_chunk_size(self, executor: Executor, t_iter: float,
                       cores: int, count: int) -> int:
        if cores <= 1:
            return count
        t0 = self.calibrate_t0(executor)
        chunk = overhead_law.chunk_size(count, cores, self.chunks_per_core)
        if t_iter > 0:
            t_m = overhead_law.t_opt(t0, self.efficiency) / self.chunks_per_core
            chunk = max(chunk, min(math.ceil(t_m / t_iter), count))
        return chunk

    # -- full decision -------------------------------------------------------
    def decide(self, executor: Executor, t_iter: float, count: int,
               key: Hashable | None = None,
               evidence: tuple = ()) -> overhead_law.AccDecision:
        """The full Overhead-Law decision, made by the ExecutionModel
        engine (one trace entry per call).  ``key`` labels the trace
        entry; without one, the key stashed by the most recent
        ``measure_iteration`` call — the paper's call sequence — or a
        generic algorithm key is used.  ``evidence`` lists extra
        workload keys whose calibrations fed ``t_iter``."""
        t0 = self.calibrate_t0(executor)
        max_cores = max(executor.num_units(), 1)
        mexec = mesh_executor_of(executor)
        if key is None:
            key = self._last_workload_key
        dkey = (DecisionKey.wrap(key) if key is not None
                else DecisionKey("algorithm", (count,)))
        decision = self.model.cores_chunk(
            dkey, t_iter=t_iter, count=count, t0=t0, max_cores=max_cores,
            eff=self.efficiency, chunks_per_core=self.chunks_per_core,
            # Mesh shardings need a divisor of the data extent.
            snap_cores=mexec.submesh_size if mexec is not None else None,
            evidence=evidence)
        return decision.acc

    def decide_for_profile(self, executor: Executor, profile: WorkloadProfile,
                           count: int, key: Hashable | None = None
                           ) -> overhead_law.AccDecision:
        """Decision from an analytic profile; with a ``key``, smoothed
        online-feedback timings (if any) override the roofline estimate."""
        return self.decide(
            executor, self.measure_iteration(executor, profile, count,
                                             key=key), count, key=key)


@dataclasses.dataclass
class StaticCoreChunk:
    """The baseline: fixed core count and chunks-per-core (OpenMP-static /
    HPX-default semantics).  Used by benchmarks as the non-adaptive
    comparison lines in the paper's figures."""

    cores: int
    chunks_per_core: int = 1

    def measure_iteration(self, executor, body, count, key=None) -> float:
        return 0.0  # static: no measurement needed

    def processing_units_count(self, executor, t_iter: float, count: int) -> int:
        return min(self.cores, max(executor.num_units(), 1))

    def get_chunk_size(self, executor, t_iter: float, cores: int,
                       count: int) -> int:
        return max(math.ceil(count / max(cores * self.chunks_per_core, 1)), 1)


# Convenience instance mirroring the paper's default configuration.
acc = AdaptiveCoreChunk
