"""Strict runtime mode: turn latent hot-path hazards into hard errors.

The static pass (``repro.analysis.lint``) catches donation and host-sync
hazards it can see in source; this module catches the ones it can't —
at runtime, where they actually bite.  Two enforcers:

* **Poison-on-donate**: ``SlotKVCachePool`` marks its cache tree as
  donated the moment it is handed to a donating dispatch.  Until
  ``adopt()`` rebinds the pool, any read of ``pool.caches`` raises
  ``DonatedCacheError`` instead of returning arrays whose device
  buffers XLA has already aliased away (reading those produces either a
  deleted-buffer crash deep in jaxlib or — worse — silently stale
  rows, which is exactly the failure mode rule RL001 exists for).

* **Transfer guard**: ``hot_dispatch_guard()`` arms
  ``jax.transfer_guard_device_to_host("disallow")`` around the serve
  tick and the training step, so any *implicit* device→host transfer
  (``float(arr)``, ``np.asarray(arr)``, printing a device array) fails
  loudly.  Explicit ``jax.device_get`` stays permitted — the drain's
  one sanctioned round-trip per dispatch still works; only accidental
  syncs trip the guard.  Caveat: on the CPU backend device→host reads
  are zero-copy and the guard never fires, so this enforcer only bites
  on real accelerators; the poison proxy above is active everywhere,
  which is why the test suite leans on it.

Enablement: set ``REPRO_STRICT=1`` in the environment (the test suite
does, via ``tests/conftest.py``), or call :func:`enable` (what the
``--strict`` flag on ``launch/serve`` and ``launch/train`` does).  When
disabled, every hook here is a no-op and the hot path pays nothing.
"""
from __future__ import annotations

import contextlib
import os

_FORCED = False


class DonatedCacheError(RuntimeError):
    """A donated cache tree was read before ``adopt()`` rebound it."""

    def __init__(self, consumer: str):
        self.consumer = consumer
        super().__init__(
            f"pool.caches was donated to {consumer!r} and not yet "
            f"re-adopted — its device buffers are aliased into the "
            f"dispatch's outputs and must not be read (RL001)")


class StalePageError(RuntimeError):
    """A page table references a page that was freed back to the pool.

    The paged KV pool (``serve/kv_cache.PagedKVCachePool``) poisons a
    page the moment its refcount drops to zero — whether it was released
    with its slot, evicted from the prefix cache, or left behind as a
    copy-on-write source.  Until the page is re-acquired from the free
    list, any dispatch whose page table still maps it would read rows
    that a *different* request may already be writing — the paged
    analogue of the donated-buffer read RL001 exists for.  The pool
    validates every table it hands to a gather and raises this instead
    of silently serving a reused page."""

    def __init__(self, slot: int, page: int):
        self.slot = slot
        self.page = page
        super().__init__(
            f"slot {slot}'s page table maps page {page}, which was "
            f"freed back to the pool and not re-acquired — a gather "
            f"through this table would read rows now owned by another "
            f"request (RL001, paged)")


def enabled() -> bool:
    """Strict mode is on via ``REPRO_STRICT=1`` or :func:`enable`."""
    return _FORCED or os.environ.get("REPRO_STRICT", "") == "1"


def enable() -> None:
    """Force strict mode on for this process (the ``--strict`` flag)."""
    global _FORCED
    _FORCED = True


@contextlib.contextmanager
def hot_dispatch_guard():
    """Disallow implicit device→host transfers inside the block.

    Wraps the serve scheduler's ``tick()`` and the fault-tolerant
    trainer's step call.  A no-op unless strict mode is enabled, so the
    guard costs nothing in production profiles.
    """
    if not enabled():
        yield
        return
    import jax

    with jax.transfer_guard_device_to_host("disallow"):
        yield
