"""Execution policies mirroring hpx::execution / std::execution.

``seq``/``par``/``unseq``/``par_unseq`` singletons; ``.on(executor)`` binds
an executor, ``.with_(params)`` binds an execution-parameters object (the
acc object, a static-chunk object, ...).  Algorithms receive a policy as
their first argument, exactly like the C++ parallel algorithms.

``with_`` is one instance of the general executor-property mechanism
(core/properties.py): it is ``prefer(with_params, policy, params)``, which
resolves through the frozen-dataclass field and so round-trips through
``dataclasses.replace``.  ``with_priority`` / ``with_hint`` forward to the
bound executor's property hooks.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from . import properties


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    kind: str                      # "seq" | "par" | "unseq" | "par_unseq"
    executor: Any = None
    params: Any = None

    def on(self, executor: Any) -> "ExecutionPolicy":
        return dataclasses.replace(self, executor=executor)

    def with_(self, params: Any) -> "ExecutionPolicy":
        return properties.prefer(properties.with_params, self, params)

    def with_priority(self, priority: str) -> "ExecutionPolicy":
        return self._annotate_executor(properties.with_priority, priority)

    def with_hint(self, hint: Any) -> "ExecutionPolicy":
        return self._annotate_executor(properties.with_hint, hint)

    def _annotate_executor(self, prop, value) -> "ExecutionPolicy":
        if self.executor is None:
            raise ValueError(
                f"policy has no bound executor to annotate; call "
                f".on(executor) before .with_{prop.name}()")
        return dataclasses.replace(
            self, executor=properties.require(prop, self.executor, value))

    @property
    def allows_parallel(self) -> bool:
        return self.kind in ("par", "par_unseq")

    @property
    def allows_vectorization(self) -> bool:
        return self.kind in ("unseq", "par_unseq")

    def resolve_executor(self):
        """Executor to use: bound one, else a policy-appropriate default."""
        if self.executor is not None:
            return self.executor
        from .executor import HostParallelExecutor, SequentialExecutor

        if self.allows_parallel:
            return HostParallelExecutor()
        return SequentialExecutor()

    def resolve_params(self, executor: Any = None):
        """Execution-parameters object: the policy-bound one, else one
        annotated onto the (resolved) executor, else None.  This is the
        hook that lets ``AdaptiveExecutor`` carry the acc object."""
        if self.params is not None:
            return self.params
        return properties.params_of(
            executor if executor is not None else self.executor)


seq = ExecutionPolicy("seq")
par = ExecutionPolicy("par")
unseq = ExecutionPolicy("unseq")
par_unseq = ExecutionPolicy("par_unseq")
