"""Execution policies mirroring hpx::execution / std::execution.

``seq``/``par``/``unseq``/``par_unseq`` singletons; ``.on(executor)`` binds
an executor, ``.with_(params)`` binds an execution-parameters object (the
acc object, a static-chunk object, ...).  Algorithms receive a policy as
their first argument, exactly like the C++ parallel algorithms.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    kind: str                      # "seq" | "par" | "unseq" | "par_unseq"
    executor: Any = None
    params: Any = None

    def on(self, executor: Any) -> "ExecutionPolicy":
        return dataclasses.replace(self, executor=executor)

    def with_(self, params: Any) -> "ExecutionPolicy":
        return dataclasses.replace(self, params=params)

    @property
    def allows_parallel(self) -> bool:
        return self.kind in ("par", "par_unseq")

    @property
    def allows_vectorization(self) -> bool:
        return self.kind in ("unseq", "par_unseq")

    def resolve_executor(self):
        """Executor to use: bound one, else a policy-appropriate default."""
        if self.executor is not None:
            return self.executor
        from .executor import HostParallelExecutor, SequentialExecutor

        if self.allows_parallel:
            return HostParallelExecutor()
        return SequentialExecutor()


seq = ExecutionPolicy("seq")
par = ExecutionPolicy("par")
unseq = ExecutionPolicy("unseq")
par_unseq = ExecutionPolicy("par_unseq")
