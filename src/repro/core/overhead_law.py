"""The paper's "Overhead Law" (Section 3) as pure functions.

Model: a loop that takes ``T1`` seconds sequentially runs in

    T_N = T1 / N + T0                                        (Eq. 1)

on ``N > 1`` units, where ``T0`` is a *fixed* serial overhead paid only
when parallelism is attempted (distinct from Amdahl: the serial part is
not a fraction of the work; distinct from Gustafson: it does not grow
with the work).

Derived quantities:

    S(N)  = T1 / (T1/N + T0)                                 (Eq. 3)
    E(N)  = S / N                                            (Eq. 5)
    N     = (1-E)/E * T1/T0                                  (Eq. 7)
    T_opt = E/(1-E) * T0        (= 19*T0 at E=0.95)
    N_C   = T1 / T_opt                                       (Eq. 8)
    T_m   = T1 / (N_C * C)                                   (Eq. 9)
    N_CH  = N_E / (N_C * C)                                  (Eq. 10)

All functions are scalar, side-effect free, and unit-agnostic (seconds in,
seconds out).  ``AccDecision`` bundles the full adaptive decision used by
the acc execution-parameters object (core/acc.py).
"""
from __future__ import annotations

import dataclasses
import math

DEFAULT_EFFICIENCY = 0.95
DEFAULT_CHUNKS_PER_CORE = 8  # C in Eq. 9/10, from the paper's experiments


def predicted_time(t1: float, n: int, t0: float) -> float:
    """Eq. 1.  For n == 1 the overhead is *not* paid (sequential path)."""
    if n <= 1:
        return t1
    return t1 / n + t0


def speedup(t1: float, n: int, t0: float) -> float:
    """Eq. 3 (valid for n > 1; returns 1.0 at n == 1 by construction)."""
    tn = predicted_time(t1, n, t0)
    return t1 / tn if tn > 0 else float("inf")


def efficiency(t1: float, n: int, t0: float) -> float:
    """Eq. 5: E = S / N."""
    return speedup(t1, n, t0) / max(n, 1)


def parallel_fraction(t1: float, t0: float) -> float:
    """The Amdahl-comparable fraction p = T1 / (T0 + T1) (paper Eq. 4)."""
    return t1 / (t0 + t1) if (t0 + t1) > 0 else 1.0


def t_opt(t0: float, eff: float = DEFAULT_EFFICIENCY) -> float:
    """Work per core that sustains efficiency ``eff``:  T_opt = E/(1-E)*T0.

    At the paper's E = 0.95 this is exactly 19 * T0.
    """
    if not (0.0 < eff < 1.0):
        raise ValueError(f"efficiency must be in (0, 1), got {eff}")
    return eff / (1.0 - eff) * t0


def optimal_cores(t1: float, t0: float, eff: float = DEFAULT_EFFICIENCY) -> float:
    """Eq. 7:  N = (1-E)/E * T1/T0  (== T1 / T_opt).  Unclamped, real-valued."""
    if t0 <= 0:
        return float("inf")
    return (1.0 - eff) / eff * (t1 / t0)


def chunk_size(
    n_elements: int,
    n_cores: int,
    chunks_per_core: int = DEFAULT_CHUNKS_PER_CORE,
) -> int:
    """Eq. 10:  N_CH = N_E / (N_C * C), rounded up, at least 1."""
    denom = max(n_cores * chunks_per_core, 1)
    return max(math.ceil(n_elements / denom), 1)


@dataclasses.dataclass(frozen=True)
class AccDecision:
    """The full adaptive decision for one workload.

    Produced by ``decide``; consumed by executors, the training loop
    (microbatching), serving, and the Pallas block-size tuner.
    """

    n_elements: int
    t_iter: float            # measured/estimated seconds per element
    t1: float                # sequential time for the whole workload
    t0: float                # calibrated parallelisation overhead
    n_cores: int             # processing units to use (clamped)
    n_cores_unclamped: float  # raw Eq. 7 value, before clamping
    chunk_elems: int         # elements per task (Eq. 10, floored at T_m)
    n_chunks: int            # resulting task count
    predicted_time: float    # Eq. 1 at the decision point
    predicted_speedup: float
    predicted_efficiency: float
    efficiency_target: float
    chunks_per_core: int

    @property
    def parallel(self) -> bool:
        return self.n_cores > 1


def decide(
    *,
    t_iter: float,
    n_elements: int,
    t0: float,
    max_cores: int,
    eff: float = DEFAULT_EFFICIENCY,
    chunks_per_core: int = DEFAULT_CHUNKS_PER_CORE,
) -> AccDecision:
    """The complete acc policy (paper Section 3 + Section 5).

    1. ``T1 = t_iter * n_elements``.
    2. ``N_C`` from Eq. 7, clamped to ``[1, max_cores]`` ("unless it is
       more than the maximum available cores, in which case the maximum
       available cores are used").  If even 2 cores cannot reach the
       efficiency target the workload runs sequentially (Eq. 1 is only
       defined for N > 1).
    3. Chunk size from Eq. 10, floored so each chunk carries at least
       ``T_m = T_opt / C`` worth of work.
    """
    if n_elements <= 0:
        raise ValueError("n_elements must be positive")
    if t_iter < 0 or t0 < 0:
        raise ValueError("times must be non-negative")

    t1 = t_iter * n_elements
    raw = optimal_cores(t1, t0, eff) if t0 > 0 else float(max_cores)
    cores = int(min(max(math.floor(raw), 1), max_cores))
    if cores < 2:
        cores = 1

    if cores == 1:
        chunk = n_elements
        n_chunks = 1
    else:
        chunk = chunk_size(n_elements, cores, chunks_per_core)
        # Floor: a chunk must carry at least T_m = T_opt / C of work.
        if t_iter > 0:
            min_elems = math.ceil(t_opt(t0, eff) / chunks_per_core / t_iter)
            chunk = max(chunk, min(min_elems, n_elements))
        n_chunks = math.ceil(n_elements / chunk)
        cores = min(cores, n_chunks)  # never more units than tasks

    t_pred = predicted_time(t1, cores, t0)
    return AccDecision(
        n_elements=n_elements,
        t_iter=t_iter,
        t1=t1,
        t0=t0,
        n_cores=cores,
        n_cores_unclamped=raw,
        chunk_elems=chunk,
        n_chunks=n_chunks,
        predicted_time=t_pred,
        predicted_speedup=t1 / t_pred if t_pred > 0 else 1.0,
        predicted_efficiency=(t1 / t_pred / cores) if t_pred > 0 else 1.0,
        efficiency_target=eff,
        chunks_per_core=chunks_per_core,
    )
