"""Executor properties / annotations (``hpx::experimental::prefer``).

HPX attaches scheduling metadata to executors through *properties*: a
property tag applied to an executor yields a new executor carrying the
annotation, and ``prefer`` degrades gracefully when the target does not
support the property (``require`` does not).  The dispatch rule here
mirrors the customization-point rule in core/customization.py — attribute
lookup instead of ADL:

    1. a ``with_<name>`` method on the target (executor or policy),
    2. a dataclass field ``<name>`` on the target (``dataclasses.replace``),
    3. otherwise: ``prefer`` returns the target unchanged,
                  ``require`` raises ``UnsupportedProperty``.

``ExecutionPolicy.with_(params)`` is one instance of this mechanism
(property ``params`` via rule 2); executors gain ``with_priority`` /
``with_hint`` / ``with_params`` through the ``PropertySupport`` mixin,
which stores a frozen ``ExecutorAnnotations`` record so annotated clones
round-trip through ``dataclasses.replace`` and never mutate the original.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any


class UnsupportedProperty(TypeError):
    """``require`` on a target that has no hook for the property."""


@dataclasses.dataclass(frozen=True)
class ExecutorAnnotations:
    """The annotation record a ``PropertySupport`` executor carries.

    ``priority`` and ``hint`` are scheduling *preferences* — recorded,
    queryable, and forwarded, but an executor may ignore them (exactly
    ``prefer``'s contract).  ``params`` is load-bearing: an
    execution-parameters object annotated onto an executor is picked up by
    the algorithm planner whenever the policy itself binds none (this is
    how ``AdaptiveExecutor`` fuses the acc object into the executor).
    """

    priority: str = "normal"        # "low" | "normal" | "high"
    hint: Any = None                # free-form scheduling hint
    params: Any = None              # execution-parameters object


_DEFAULT_ANNOTATIONS = ExecutorAnnotations()


class ExecutorProperty:
    """A named property tag.  Calling the tag is ``prefer``:
    ``with_priority(ex, "high")`` == ``prefer(with_priority, ex, "high")``.
    """

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<executor property {self.name}>"

    def __call__(self, target: Any, value: Any) -> Any:
        return prefer(self, target, value)


def _hook(prop: ExecutorProperty, target: Any):
    meth = getattr(target, f"with_{prop.name}", None)
    if callable(meth):
        return lambda value: meth(value)
    if dataclasses.is_dataclass(target) and any(
            f.name == prop.name for f in dataclasses.fields(target)):
        return lambda value: dataclasses.replace(target, **{prop.name: value})
    return None


def prefer(prop: ExecutorProperty, target: Any, value: Any) -> Any:
    """Apply ``prop`` if ``target`` supports it, else return it unchanged."""
    hook = _hook(prop, target)
    return hook(value) if hook is not None else target


def require(prop: ExecutorProperty, target: Any, value: Any) -> Any:
    """Apply ``prop``; raise ``UnsupportedProperty`` if unsupported."""
    hook = _hook(prop, target)
    if hook is None:
        raise UnsupportedProperty(
            f"{type(target).__name__} does not support property "
            f"'{prop.name}' (no with_{prop.name} method or field)")
    return hook(value)


with_priority = ExecutorProperty("priority")
with_hint = ExecutorProperty("hint")
with_params = ExecutorProperty("params")


class PropertySupport:
    """Mixin: frozen-annotation storage + the three standard properties.

    ``with_*`` return a shallow clone carrying the new annotations; the
    original executor is untouched.  Clones of pooled executors share the
    pool (annotation is metadata, not a new resource).
    """

    _annotations: ExecutorAnnotations | None = None

    @property
    def annotations(self) -> ExecutorAnnotations:
        return self._annotations or _DEFAULT_ANNOTATIONS

    def _with_annotations(self, **changes: Any):
        clone = copy.copy(self)
        clone._annotations = dataclasses.replace(self.annotations, **changes)
        return clone

    def with_priority(self, priority: str):
        return self._with_annotations(priority=priority)

    def with_hint(self, hint: Any):
        return self._with_annotations(hint=hint)

    def with_params(self, params: Any):
        return self._with_annotations(params=params)


def params_of(executor: Any) -> Any:
    """The execution-parameters object annotated onto ``executor`` (or one
    of its wrappers), if any.  Walks ``inner`` chains so an annotation on a
    wrapping executor is visible through the wrapper stack."""
    seen = set()
    while executor is not None and id(executor) not in seen:
        seen.add(id(executor))
        ann = getattr(executor, "annotations", None)
        if isinstance(ann, ExecutorAnnotations) and ann.params is not None:
            return ann.params
        executor = getattr(executor, "inner", None)
    return None
