"""Online-feedback telemetry: executors report observed chunk wall-clock
back into the calibration cache.

The paper measures t_iter once per workload and trusts it forever
(Section 4.2).  Under a serving load that assumption breaks: per-token
cost drifts with sequence length, cache occupancy, co-tenants and thermal
state.  ``OnlineFeedback`` closes the loop — every chunk an
``AdaptiveExecutor`` runs is timed and handed to the ``ExecutionModel``
engine's online-refinement policy (core/model.py), which smooths it into
the same ``CalibrationCache`` entry the acc policy reads and upgrades
the key's provenance to ``online``, so the *next* decision sees the
drifted reality.  This class is the executor-side *collector*; the EMA
itself is the engine's ``refine`` policy.

Producers tag work with a workload key:

    thunk.__workload_key__ = ("serve_prefill", cfg.name)
    thunk.__workload_elems__ = 128        # for then_execute continuations

``bulk_async_execute`` infers the element count from each ``Chunk``;
``then_execute`` (single continuation, no chunk) needs the explicit
``__workload_elems__`` tag.  **Untagged work passes through untimed**:
instrumenting anonymous thunks would merge unrelated workloads under one
junk key and — worse — perturb the very probes ``measure_t0_empty_task``
dispatches through the same executor to calibrate T0.

Timed thunks must synchronise internally (``jax.block_until_ready``):
an async dispatch would record launch cost, not compute, as t_iter.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Hashable

from .calibration import DEFAULT_SMOOTHING, CalibrationCache
from .model import ExecutionModel

WORKLOAD_KEY_ATTR = "__workload_key__"
WORKLOAD_ELEMS_ATTR = "__workload_elems__"


def tag_workload(fn: Callable, key: Hashable,
                 elems: int | None = None) -> Callable:
    """Annotate ``fn`` so executors attribute its timings to ``key``."""
    fn.__workload_key__ = key
    if elems is not None:
        fn.__workload_elems__ = int(elems)
    return fn


def workload_key_of(fn: Callable) -> Hashable | None:
    """The telemetry key ``fn`` was tagged with, or None (untimed)."""
    return getattr(fn, WORKLOAD_KEY_ATTR, None)


@dataclasses.dataclass(frozen=True)
class Observation:
    """One timed chunk: ``seconds`` of wall-clock over ``elems`` elements."""

    key: Hashable
    elems: int
    seconds: float

    @property
    def per_elem(self) -> float:
        return self.seconds / max(self.elems, 1)


class OnlineFeedback:
    """Collects chunk timings and smooths them into a calibration cache.

    A recent-observation ring is kept for inspection (benchmarks print
    it; tests assert convergence) — the cache itself only ever holds the
    smoothed scalar per key.
    """

    def __init__(self, cache: CalibrationCache | None = None,
                 alpha: float = DEFAULT_SMOOTHING, history: int = 512):
        self.cache = cache if cache is not None else CalibrationCache()
        self.model = ExecutionModel.of(self.cache)
        self.alpha = alpha
        self.observations: collections.deque[Observation] = \
            collections.deque(maxlen=history)

    def observe(self, key: Hashable, elems: int,
                seconds: float) -> float | None:
        """Record one chunk timing; returns the new smoothed t_iter."""
        if elems <= 0 or seconds <= 0.0:
            return None
        obs = Observation(key, int(elems), float(seconds))
        self.observations.append(obs)
        return self.model.observe(key, obs.elems, obs.seconds,
                                  alpha=self.alpha)

    def t_iter(self, key: Hashable) -> float | None:
        """The smoothed per-element time currently backing ``key``."""
        return self.model.smoothed_t_iter(key)

    def count(self, key: Hashable | None = None) -> int:
        if key is None:
            return len(self.observations)
        return sum(1 for o in self.observations if o.key == key)

    # -- instrumentation helpers used by AdaptiveExecutor --------------------
    def timed_chunk_fn(self, fn: Callable[[Any], Any]) -> Callable[[Any], Any]:
        """Wrap a *tagged* bulk chunk thunk: time each call, attribute
        ``chunk.size`` elements to its workload key.  Untagged thunks
        pass through untouched, and so does any individual call whose
        chunk object carries no ``.size``: attributing a default element
        count (e.g. 1) would divide real seconds by a fake denominator
        and poison the smoothed per-element time for every later
        decision on that key."""
        key = workload_key_of(fn)
        if key is None:
            return fn

        def timed(chunk):
            size = getattr(chunk, "size", None)
            if size is None:
                return fn(chunk)
            t = time.perf_counter()
            out = fn(chunk)
            self.observe(key, size, time.perf_counter() - t)
            return out

        timed.__name__ = getattr(fn, "__name__", "chunk_fn")
        return timed

    def timed_continuation(self, fn: Callable[[Any], Any]
                           ) -> Callable[[Any], Any]:
        """Wrap a ``then_execute`` continuation if it carries an element
        count; untagged continuations pass through untimed (their element
        count is unknowable here)."""
        elems = getattr(fn, WORKLOAD_ELEMS_ATTR, None)
        key = workload_key_of(fn)
        if not elems or key is None:
            return fn

        def timed(value):
            t = time.perf_counter()
            out = fn(value)
            self.observe(key, elems, time.perf_counter() - t)
            return out

        timed.__name__ = getattr(fn, "__name__", "continuation")
        return timed
