"""Hardware descriptions used by the adaptive cost model.

The paper calibrates a single overhead constant ``T0`` on the machine it
runs on (40-core Skylake / 48-core EPYC).  On a TPU mesh the analogous
constants are the per-invocation launch latency and the collective path
(ICI hops + link bandwidth).  Both are captured here so the Overhead-Law
solver (``overhead_law.py``) can run either against measured numbers or
against these analytic constants.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Aggregated machine model for one "processing unit" pool.

    Attributes
    ----------
    name:            human-readable identifier.
    num_units:       processing units available (cores or chips).
    peak_flops:      peak FLOP/s per unit (bf16 for TPU, AVX-512 fp64-ish
                     notional for the CPU presets — only ratios matter).
    mem_bw:          HBM/DRAM bandwidth per unit, bytes/s.
    link_bw:         interconnect bandwidth per unit, bytes/s (ICI for TPU,
                     inter-socket for CPU presets).
    launch_overhead: fixed cost of dispatching one parallel region, seconds.
                     This is the paper's ``T0`` seed; on the host backend it
                     is re-measured at runtime (calibration.py).
    hop_latency:     per-hop latency of the interconnect, seconds.
    vmem_bytes:      fast scratch per unit (VMEM for TPU, L2 for CPU).
    """

    name: str
    num_units: int
    peak_flops: float
    mem_bw: float
    link_bw: float
    launch_overhead: float
    hop_latency: float
    vmem_bytes: int

    def t0_parallel(self, n_units: int | None = None) -> float:
        """Analytic ``T0``: serial overhead paid only when parallelising.

        Launch cost plus the latency of the synchronising collective across
        ``n_units`` (log-hops on a torus/tree).  This is the mesh-side
        analogue of HPX's "benchmark on an empty thread".
        """
        import math

        n = self.num_units if n_units is None else max(int(n_units), 1)
        hops = math.ceil(math.log2(n)) if n > 1 else 0
        return self.launch_overhead + hops * self.hop_latency


# --- TPU v5e: the production target (per-chip numbers) -------------------
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    num_units=256,                 # one pod slice (16x16)
    peak_flops=197e12,             # bf16
    mem_bw=819e9,                  # HBM
    link_bw=50e9,                  # per ICI link
    launch_overhead=5e-6,          # XLA dispatch
    hop_latency=1e-6,              # ICI hop
    vmem_bytes=128 * 1024 * 1024,  # ~128 MiB VMEM
)

# --- The paper's two evaluation machines (for figure reproduction) -------
INTEL_SKYLAKE_40C = HardwareSpec(
    name="intel-skylake-40c",
    num_units=40,
    peak_flops=2.4e9 * 32,         # 2.4 GHz * notional 32 flop/cycle
    mem_bw=128e9 / 40,             # ~128 GB/s socket pair shared
    link_bw=10e9,
    launch_overhead=15e-6,         # HPX parallel region overhead (order)
    hop_latency=0.5e-6,
    vmem_bytes=1 * 1024 * 1024,    # L2
)

AMD_EPYC_48C = HardwareSpec(
    name="amd-epyc-48c",
    num_units=48,
    peak_flops=2.0e9 * 32,
    mem_bw=160e9 / 48,
    link_bw=12e9,
    launch_overhead=15e-6,
    hop_latency=0.6e-6,
    vmem_bytes=1 * 1024 * 1024,
)


def this_host(num_units: int | None = None) -> HardwareSpec:
    """Spec for the machine we are actually running on (calibrated later)."""
    import os

    n = num_units if num_units is not None else (os.cpu_count() or 1)
    return HardwareSpec(
        name="host",
        num_units=n,
        peak_flops=50e9,
        mem_bw=20e9,
        link_bw=10e9,
        launch_overhead=20e-6,
        hop_latency=1e-6,
        vmem_bytes=1 * 1024 * 1024,
    )
