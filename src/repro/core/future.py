"""Futures for the v2 executor API (``hpx::future`` analogue).

A thin, thread-safe wrapper over ``concurrent.futures.Future`` adding the
two combinators HPX builds its execution model on:

* ``Future.then(fn)``        — continuation chaining (``hpx::future::then``);
* ``when_all(futures)``      — join a set of futures into one.

Executors return these from ``async_execute`` / ``bulk_async_execute`` and
consume them in ``then_execute``; algorithm code never blocks on a single
task, only on the joined ``when_all`` future at a genuine barrier.

Deviation from HPX noted for reviewers: ``when_all(fs).result()`` yields
the list of *values* (in the order the futures were passed), not a list of
futures — Python has no ``future.unwrap()`` idiom and every call site wants
the values.
"""
from __future__ import annotations

import concurrent.futures as _cf
import threading
from typing import Any, Callable, Iterable, Sequence


class Future:
    """A value that will exist later; may already be resolved ("ready")."""

    __slots__ = ("_inner",)

    def __init__(self, inner: _cf.Future | None = None):
        self._inner = inner if inner is not None else _cf.Future()

    # -- construction -------------------------------------------------------
    @classmethod
    def ready(cls, value: Any) -> "Future":
        """An already-resolved future (``hpx::make_ready_future``)."""
        f = _cf.Future()
        f.set_result(value)
        return cls(f)

    @classmethod
    def exceptional(cls, exc: BaseException) -> "Future":
        f = _cf.Future()
        f.set_exception(exc)
        return cls(f)

    @classmethod
    def from_call(cls, fn: Callable[..., Any], *args: Any) -> "Future":
        """Run ``fn`` immediately on the calling thread, capture the
        outcome.  The inline-execution building block for synchronous
        executors."""
        f = _cf.Future()
        try:
            f.set_result(fn(*args))
        except Exception as e:  # noqa: BLE001 - exceptions travel via future
            f.set_exception(e)
        return cls(f)

    # -- state --------------------------------------------------------------
    def done(self) -> bool:
        return self._inner.done()

    def result(self, timeout: float | None = None) -> Any:
        return self._inner.result(timeout)

    # HPX spelling.
    get = result

    def set_result(self, value: Any) -> None:
        self._inner.set_result(value)

    def set_exception(self, exc: BaseException) -> None:
        self._inner.set_exception(exc)

    # -- combinators --------------------------------------------------------
    def then(self, fn: Callable[[Any], Any], executor: Any = None) -> "Future":
        """``fn(self.result())`` as a new Future.

        With ``executor`` the continuation is dispatched through
        ``executor.async_execute`` (i.e. may run on a pool thread);
        without, it runs inline on whichever thread resolves this future
        (or the caller's, if already resolved).  Exceptions — from this
        future or from ``fn`` — propagate to the returned future.
        """
        out = Future()

        def _fire(inner: _cf.Future) -> None:
            try:
                value = inner.result()
            except Exception as e:  # noqa: BLE001
                out.set_exception(e)
                return
            if executor is None:
                _chain_call(out, fn, value)
            else:
                try:
                    nxt = executor.async_execute(fn, value)
                except Exception as e:  # noqa: BLE001
                    out.set_exception(e)
                    return
                nxt._inner.add_done_callback(lambda g: _transfer(g, out))

        self._inner.add_done_callback(_fire)
        return out


def _chain_call(out: Future, fn: Callable[[Any], Any], value: Any) -> None:
    try:
        out.set_result(fn(value))
    except Exception as e:  # noqa: BLE001
        out.set_exception(e)


def _transfer(src: _cf.Future, dst: Future) -> None:
    try:
        dst.set_result(src.result())
    except Exception as e:  # noqa: BLE001
        dst.set_exception(e)


def when_all(futures: Iterable[Future]) -> Future:
    """Join: resolves to the list of values, in argument order, once every
    input future has resolved.  The first exception (in argument order)
    propagates instead."""
    fs: Sequence[Future] = list(futures)
    out = Future()
    if not fs:
        out.set_result([])
        return out
    lock = threading.Lock()
    remaining = [len(fs)]

    def _one_done(_: _cf.Future) -> None:
        with lock:
            remaining[0] -= 1
            if remaining[0]:
                return
        try:
            out.set_result([f.result() for f in fs])
        except Exception as e:  # noqa: BLE001
            out.set_exception(e)

    for f in fs:
        f._inner.add_done_callback(_one_done)
    return out
