"""Runtime calibration of T0 and per-iteration time (paper Section 5).

The paper: "The time (T_i) will be calculated once for each workload, and
then will be used to find T1 ... HPX runs a benchmark on an empty thread to
calculate overhead which is T0."

Host backend: both are wall-clock measured here, once, and cached.
Mesh backend: wall-clock is meaningless on the dry-run container, so the
analytic path (core/cost_model.py) derives the same quantities from
compiled FLOPs/bytes and the hardware constants.  Both paths produce plain
floats consumed by the same Overhead-Law solver.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Hashable

from .executor import Chunk, Executor, make_chunks
from .future import when_all


class CalibrationCache:
    """Per-workload memo: first invocation measures, later ones reuse."""

    def __init__(self):
        self._t_iter: dict[Hashable, float] = {}
        self._t0: dict[Hashable, float] = {}

    def t_iter(self, key: Hashable, measure: Callable[[], float]) -> float:
        if key not in self._t_iter:
            self._t_iter[key] = measure()
        return self._t_iter[key]

    def t0(self, key: Hashable, measure: Callable[[], float]) -> float:
        if key not in self._t0:
            self._t0[key] = measure()
        return self._t0[key]

    def clear(self) -> None:
        self._t_iter.clear()
        self._t0.clear()


GLOBAL_CACHE = CalibrationCache()


def measure_t0_empty_task(executor: Executor, repeats: int = 32) -> float:
    """Time dispatching an empty task through the executor ("empty thread"
    benchmark).  Returns seconds per parallel-region invocation."""

    def empty(_: Chunk) -> None:
        return None

    chunks = make_chunks(max(executor.num_units(), 2), 1)
    # Warm the pool (thread creation is a one-time cost, not T0).
    when_all(executor.bulk_async_execute(empty, chunks)).result()
    start = time.perf_counter()
    for _ in range(repeats):
        when_all(executor.bulk_async_execute(empty, chunks)).result()
    return (time.perf_counter() - start) / repeats


def measure_iteration_wallclock(
    body: Callable[[int, int], Any],
    count: int,
    sample: int | None = None,
    repeats: int = 3,
) -> float:
    """Seconds per element of ``body(start, size)`` (jit'd chunk thunk).

    Runs the body on a sample prefix (default: min(count, 64k)), takes the
    best of ``repeats`` to strip scheduler noise, divides by the sample
    size.  ``body`` must synchronise internally (block_until_ready).
    """
    n = min(count, sample or 65536)
    body(0, n)  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t = time.perf_counter()
        body(0, n)
        best = min(best, time.perf_counter() - t)
    return best / n
