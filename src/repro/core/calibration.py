"""Runtime calibration of T0 and per-iteration time (paper Section 5).

The paper: "The time (T_i) will be calculated once for each workload, and
then will be used to find T1 ... HPX runs a benchmark on an empty thread to
calculate overhead which is T0."

Host backend: both are wall-clock measured here, once, and cached.
Mesh backend: wall-clock is meaningless on the dry-run container, so the
analytic path (core/cost_model.py) derives the same quantities from
compiled FLOPs/bytes and the hardware constants.  Both paths produce plain
floats consumed by the same Overhead-Law solver.

Two additions beyond the paper's one-shot scheme:

* **Online smoothing** (``smooth_t_iter``): observed per-chunk wall-clock
  from the executors (core/feedback.py) is folded back into the cached
  t_iter with an exponential moving average, so acc decisions track drift
  (thermal throttling, co-tenants, data-dependent cost) instead of
  trusting one calibration forever.
* **Disk persistence** (``save``/``load``/``persistent``): calibrations
  survive process restarts as JSON under a cache directory, with a
  versioned key schema (``SCHEMA_VERSION``) so stale formats are ignored
  rather than misread.
* **Tuned-winner records** (``tuned``/``set_tuned``): the kernel block
  autotuner (kernels/autotune.py) persists its measured winners — small
  JSON dicts, not scalars — through the same store, so kernel tuning,
  T0 and t_iter share one file, one schema version and one atomic
  writer.  Schema v2 added this table.

Schema v3 (current) unifies the three key conventions into **one
entries table**: each persisted key maps to a typed record carrying
whichever quantities exist for it (``t0`` / ``t_iter`` / ``tuned``)
plus its *provenance* level (``measured`` / ``online`` — the
ExecutionModel's evidence ladder; see core/model.py).  v1 and v2 files
still load — their per-table entries migrate into the unified form on
the first save — and files are always written as v3.

This module stays policy-free: it stores and round-trips what the
``ExecutionModel`` engine decides.  ``smooth_t_iter`` is the EMA
primitive the engine's online-refinement policy calls — consumers go
through ``ExecutionModel.observe``, not this method.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Any, Callable, Hashable

from .executor import Chunk, Executor, make_chunks
from .future import when_all

SCHEMA_VERSION = 3

# Provenance upgrade order (mirrors core/model.py, which owns the
# semantics; duplicated as data here to keep this module import-light).
_PROVENANCE_ORDER = ("analytic", "measured", "online")

# Smoothing factor for online t_iter feedback: high enough to converge on
# a drifted workload within a few dozen observations, low enough that one
# noisy chunk cannot swing the next decision.
DEFAULT_SMOOTHING = 0.25


def _key_str(key: Hashable) -> str:
    """Stable textual form of a calibration key.

    Keys are small hashables (strings / tuples of strings and ints); their
    ``repr`` round-trips identically within and across processes, which is
    all persistence needs (the JSON file maps key-strings to floats; we
    never parse the string back into a tuple).
    """
    return repr(key)


class CalibrationCache:
    """Per-workload memo: first invocation measures, later ones reuse.

    Internally keyed by ``_key_str(key)`` so in-memory lookups and
    persisted entries share one namespace.  All mutation is lock-guarded:
    the feedback layer records observations from executor pool threads.
    """

    def __init__(self, path: str | None = None):
        self._t_iter: dict[str, float] = {}
        self._t0: dict[str, float] = {}
        self._tuned: dict[str, dict] = {}
        self._provenance: dict[str, str] = {}
        self._lock = threading.Lock()
        self._last_smooth_save = 0.0
        self.path = path
        if path:
            self.load(path)

    # -- memoised measurement ------------------------------------------------
    def t_iter(self, key: Hashable, measure: Callable[[], float]) -> float:
        k = _key_str(key)
        if k not in self._t_iter:
            value = measure()
            with self._lock:
                self._t_iter.setdefault(k, value)
            self._autosave()
        return self._t_iter[k]

    def t0(self, key: Hashable, measure: Callable[[], float]) -> float:
        k = _key_str(key)
        if k not in self._t0:
            value = measure()
            with self._lock:
                self._t0.setdefault(k, value)
            self._autosave()
        return self._t0[k]

    # -- online feedback -----------------------------------------------------
    def peek_t_iter(self, key: Hashable) -> float | None:
        """Current t_iter for ``key`` without triggering a measurement."""
        return self._t_iter.get(_key_str(key))

    def smooth_t_iter(self, key: Hashable, observed: float,
                      alpha: float = DEFAULT_SMOOTHING) -> float:
        """Fold an observed per-element time into the cache (EMA).

        First observation seeds the entry; later ones move it by
        ``alpha``:  new = alpha * observed + (1 - alpha) * old.
        Returns the smoothed value now backing decisions for ``key``.

        Persistence is write-throttled two ways: the JSON file is
        rewritten only when the smoothed value actually moved (> 5%
        relative), and — for keys that keep moving, e.g. the serve
        loop's per-tick host-overhead observations, which jitter more
        than 5% forever — at most once per second.  A converged or
        merely noisy serving loop stops touching disk; observations
        arrive per chunk/tick, on the hot path.  The first observation
        for a key always persists immediately.
        """
        k = _key_str(key)
        now = time.monotonic()
        with self._lock:
            old = self._t_iter.get(k)
            value = observed if old is None else (
                alpha * observed + (1.0 - alpha) * old)
            self._t_iter[k] = value
            moved = old is None or abs(value - old) > 0.05 * abs(old)
            due = old is None or now - self._last_smooth_save >= 1.0
            if moved and due:
                self._last_smooth_save = now
        if moved and due:
            self._autosave()
        return value

    # -- tuned-winner records (kernel block autotuner) -----------------------
    def tuned(self, key: Hashable) -> dict | None:
        """The persisted winner record for ``key``, or None.

        Records are small JSON-able dicts owned by the autotuner (block
        sizes, the measured seconds, the hardware key they were measured
        on) — this layer only stores and round-trips them.
        """
        rec = self._tuned.get(_key_str(key))
        return dict(rec) if rec is not None else None

    def set_tuned(self, key: Hashable, record: dict) -> None:
        """Persist a winner record (overwrites any previous one)."""
        with self._lock:
            self._tuned[_key_str(key)] = dict(record)
        self._autosave()

    # -- provenance ----------------------------------------------------------
    def provenance(self, key: Hashable) -> str | None:
        """The recorded evidence level for ``key`` (None: analytic-only)."""
        return self._provenance.get(_key_str(key))

    def note_provenance(self, key: Hashable, level: str) -> str:
        """Record ``level`` for ``key``, monotone: upgrades persist,
        downgrades are ignored (once a key has online observations it
        never reports weaker evidence again).  Returns the level now in
        effect."""
        if level not in _PROVENANCE_ORDER:
            raise ValueError(f"unknown provenance level {level!r}")
        k = _key_str(key)
        changed = False
        with self._lock:
            old = self._provenance.get(k, _PROVENANCE_ORDER[0])
            if (_PROVENANCE_ORDER.index(level)
                    > _PROVENANCE_ORDER.index(old)):
                # "analytic" is the default and never stored explicitly.
                self._provenance[k] = level
                changed = True
            effective = self._provenance.get(k, _PROVENANCE_ORDER[0])
        if changed:
            self._autosave()
        return effective

    def clear(self) -> None:
        with self._lock:
            self._t_iter.clear()
            self._t0.clear()
            self._tuned.clear()
            self._provenance.clear()

    def __len__(self) -> int:
        return len(self._t_iter) + len(self._t0) + len(self._tuned)

    # -- persistence ---------------------------------------------------------
    @classmethod
    def persistent(cls, cache_dir: str | None = None,
                   name: str = "calibration.json") -> "CalibrationCache":
        """A cache backed by ``cache_dir/name`` (created on first save).

        Default directory: ``$REPRO_CAL_CACHE_DIR`` or
        ``~/.cache/repro-acc``.
        """
        cache_dir = cache_dir or os.environ.get(
            "REPRO_CAL_CACHE_DIR",
            os.path.join(os.path.expanduser("~"), ".cache", "repro-acc"))
        return cls(path=os.path.join(cache_dir, name))

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no path bound to this cache and none given")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with self._lock:
            # v3: one unified table — each key's record carries whichever
            # quantities exist for it plus its provenance level.
            entries: dict[str, dict] = {}
            for k, v in self._t0.items():
                entries.setdefault(k, {})["t0"] = v
            for k, v in self._t_iter.items():
                entries.setdefault(k, {})["t_iter"] = v
            for k, r in self._tuned.items():
                entries.setdefault(k, {})["tuned"] = dict(r)
            for k, p in self._provenance.items():
                if k in entries:
                    entries[k]["provenance"] = p
            blob = {"version": SCHEMA_VERSION, "entries": entries}
        # Atomic replace so a crashed writer never leaves a torn file.
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        return path

    def load(self, path: str | None = None) -> bool:
        """Merge entries from ``path``; returns True if anything loaded.

        Accepts schema v1/v2 (three per-quantity tables) and v3 (one
        unified entries table) — older files migrate in place: loading
        a v1/v2 file and saving writes v3.  Missing files and unknown
        versions are treated as an empty cache (calibration re-measures;
        never an error)."""
        path = path or self.path
        if not path or not os.path.exists(path):
            return False
        try:
            with open(path) as f:
                blob = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        if not isinstance(blob, dict) or blob.get("version") not in (
                1, 2, SCHEMA_VERSION):
            return False
        with self._lock:
            if blob.get("version") == SCHEMA_VERSION:
                entries = blob.get("entries", {})
                if not isinstance(entries, dict):
                    return False
                for k, rec in entries.items():
                    if not isinstance(rec, dict):
                        continue
                    k = str(k)
                    if isinstance(rec.get("t0"), (int, float)):
                        self._t0[k] = float(rec["t0"])
                    if isinstance(rec.get("t_iter"), (int, float)):
                        self._t_iter[k] = float(rec["t_iter"])
                    if isinstance(rec.get("tuned"), dict):
                        self._tuned[k] = dict(rec["tuned"])
                    if rec.get("provenance") in _PROVENANCE_ORDER:
                        self._provenance[k] = rec["provenance"]
                return True
            # v1/v2 migration: per-table stores with no provenance —
            # everything persisted was measured at least once, so the
            # conservative level is "measured" (online upgrades re-earn
            # themselves from live observations).
            for name, store in (("t0", self._t0), ("t_iter", self._t_iter)):
                entries = blob.get(name, {})
                if isinstance(entries, dict):
                    for k, v in entries.items():
                        store[str(k)] = float(v)
                        self._provenance.setdefault(str(k), "measured")
            tuned = blob.get("tuned", {})
            if isinstance(tuned, dict):
                for k, v in tuned.items():
                    if isinstance(v, dict):
                        self._tuned[str(k)] = dict(v)
                        self._provenance.setdefault(str(k), "measured")
        return True

    def _autosave(self) -> None:
        if self.path:
            try:
                self.save(self.path)
            except OSError:  # pragma: no cover - e.g. read-only cache dir
                pass


GLOBAL_CACHE = CalibrationCache()


def measure_t0_empty_task(executor: Executor, repeats: int = 32) -> float:
    """Time dispatching an empty task through the executor ("empty thread"
    benchmark).  Returns seconds per parallel-region invocation."""

    def empty(_: Chunk) -> None:
        return None

    chunks = make_chunks(max(executor.num_units(), 2), 1)
    # Warm the pool (thread creation is a one-time cost, not T0).
    when_all(executor.bulk_async_execute(empty, chunks)).result()
    start = time.perf_counter()
    for _ in range(repeats):
        when_all(executor.bulk_async_execute(empty, chunks)).result()
    return (time.perf_counter() - start) / repeats


def measure_iteration_wallclock(
    body: Callable[[int, int], Any],
    count: int,
    sample: int | None = None,
    repeats: int = 3,
) -> float:
    """Seconds per element of ``body(start, size)`` (jit'd chunk thunk).

    Runs the body on a sample prefix (default: min(count, 64k)), takes the
    best of ``repeats`` to strip scheduler noise, divides by the sample
    size.  ``body`` must synchronise internally (block_until_ready).
    """
    n = min(count, sample or 65536)
    body(0, n)  # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t = time.perf_counter()
        body(0, n)
        best = min(best, time.perf_counter() - t)
    return best / n
