"""Core: the paper's adaptive core/chunk execution model for JAX.

Public surface:
  - overhead_law: Eqs 1-10 as pure functions + AccDecision
  - AdaptiveCoreChunk (acc), StaticCoreChunk: execution-parameters objects
  - customization points: measure_iteration, processing_units_count,
    get_chunk_size (tag_invoke-style dispatch)
  - policies: seq, par, unseq, par_unseq
  - executors: SequentialExecutor, HostParallelExecutor, MeshExecutor
  - hardware specs + analytic cost model + SimMachine
"""
from . import calibration, cost_model, customization, overhead_law
from .acc import AdaptiveCoreChunk, StaticCoreChunk
from .cost_model import (ADJACENT_DIFFERENCE, WorkloadProfile,
                         artificial_work, t0_analytic, t_iter_analytic)
from .customization import (get_chunk_size, measure_iteration,
                            processing_units_count)
from .executor import (Chunk, Executor, HostParallelExecutor, MeshExecutor,
                       SequentialExecutor, make_chunks)
from .hardware import (AMD_EPYC_48C, INTEL_SKYLAKE_40C, TPU_V5E,
                       HardwareSpec, this_host)
from .overhead_law import AccDecision, decide
from .policy import ExecutionPolicy, par, par_unseq, seq, unseq
from .simmachine import EPYC_48, SKYLAKE_40, SimMachine

__all__ = [
    "overhead_law", "customization", "calibration", "cost_model",
    "AdaptiveCoreChunk", "StaticCoreChunk", "AccDecision", "decide",
    "measure_iteration", "processing_units_count", "get_chunk_size",
    "ExecutionPolicy", "seq", "par", "unseq", "par_unseq",
    "Chunk", "Executor", "SequentialExecutor", "HostParallelExecutor",
    "MeshExecutor", "make_chunks",
    "HardwareSpec", "TPU_V5E", "INTEL_SKYLAKE_40C", "AMD_EPYC_48C",
    "this_host", "WorkloadProfile", "ADJACENT_DIFFERENCE",
    "artificial_work", "t_iter_analytic", "t0_analytic",
    "SimMachine", "SKYLAKE_40", "EPYC_48",
]
