"""Core: the paper's adaptive core/chunk execution model for JAX.

Public surface:
  - ExecutionModel (core/model.py): the unified decide→execute→observe→
    refine engine with the typed Decision IR (DecisionKey / Decision /
    DecisionTrace) and pluggable policies
  - overhead_law: Eqs 1-10 as pure functions + AccDecision
  - AdaptiveCoreChunk (acc), StaticCoreChunk: execution-parameters objects
  - customization points: measure_iteration, processing_units_count,
    get_chunk_size (tag_invoke-style dispatch)
  - policies: seq, par, unseq, par_unseq
  - executors (v2 async API): SequentialExecutor, HostParallelExecutor,
    MeshExecutor, AdaptiveExecutor / adaptive(); Future, when_all
  - executor properties: prefer/require, with_priority/with_hint/with_params
  - hardware specs + analytic cost model + SimMachine
"""
from . import (calibration, cost_model, customization, feedback, model,
               overhead_law, properties)
from .acc import AdaptiveCoreChunk, StaticCoreChunk
from .adaptive import AdaptiveExecutor, adaptive
from .calibration import CalibrationCache
from .cost_model import (ADJACENT_DIFFERENCE, WorkloadProfile,
                         artificial_work, t0_analytic, t_iter_analytic)
from .customization import (get_chunk_size, measure_iteration,
                            processing_units_count)
from .executor import (Chunk, Executor, ExecutorBase, HostParallelExecutor,
                       MeshExecutor, SequentialExecutor, UnsupportedOperation,
                       make_chunks, mesh_executor_of, unwrap_executor)
from .feedback import OnlineFeedback, tag_workload
from .future import Future, when_all
from .hardware import (AMD_EPYC_48C, INTEL_SKYLAKE_40C, TPU_V5E,
                       HardwareSpec, this_host)
from .model import (Decision, DecisionKey, DecisionTrace, ExecutionModel,
                    hardware_key)
from .overhead_law import AccDecision, decide
from .policy import ExecutionPolicy, par, par_unseq, seq, unseq
from .properties import (ExecutorAnnotations, ExecutorProperty,
                         UnsupportedProperty, params_of, prefer, require,
                         with_hint, with_params, with_priority)
from .simmachine import EPYC_48, SKYLAKE_40, SimMachine

__all__ = [
    "overhead_law", "customization", "calibration", "cost_model",
    "properties", "feedback", "model",
    "ExecutionModel", "Decision", "DecisionKey", "DecisionTrace",
    "hardware_key",
    "CalibrationCache", "OnlineFeedback", "tag_workload",
    "AdaptiveCoreChunk", "StaticCoreChunk", "AccDecision", "decide",
    "measure_iteration", "processing_units_count", "get_chunk_size",
    "ExecutionPolicy", "seq", "par", "unseq", "par_unseq",
    "Chunk", "Executor", "ExecutorBase", "SequentialExecutor",
    "HostParallelExecutor", "MeshExecutor", "AdaptiveExecutor", "adaptive",
    "UnsupportedOperation", "make_chunks", "unwrap_executor",
    "mesh_executor_of",
    "Future", "when_all",
    "ExecutorAnnotations", "ExecutorProperty", "UnsupportedProperty",
    "prefer", "require", "params_of",
    "with_priority", "with_hint", "with_params",
    "HardwareSpec", "TPU_V5E", "INTEL_SKYLAKE_40C", "AMD_EPYC_48C",
    "this_host", "WorkloadProfile", "ADJACENT_DIFFERENCE",
    "artificial_work", "t_iter_analytic", "t0_analytic",
    "SimMachine", "SKYLAKE_40", "EPYC_48",
]
