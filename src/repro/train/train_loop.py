"""Training step with adaptive gradient-accumulation microbatching.

The paper's chunk-size decision drives the microbatch count: the global
batch is the "workload", one microbatch is one "chunk", and
``autotune.choose_accum`` applies Eq. 10 (with the analytic per-token cost
as ``measure_iteration``) to pick how many chunks a step is split into —
large enough to amortise dispatch, small enough to bound activation
memory (the VMEM/HBM analogue of the paper's T_m floor).

``make_train_step`` builds a jit-able pure function
(params, opt_state, batch) → (params, opt_state, metrics); distribution is
applied by the launch layer via in/out shardings (pjit path) or by the
explicit shard_map DP variant with int8 gradient compression
(train/grad_compress.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm
from ..optim import adamw


def make_loss_fn(cfg: ArchConfig, *, attn_impl: str = "chunked",
                 remat: bool = True) -> Callable:
    def loss(params, batch):
        return lm.loss_fn(params, batch, cfg, attn_impl=attn_impl,
                          remat=remat)
    return loss


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, *,
                    accum: int = 1, attn_impl: str = "chunked",
                    remat: bool = True, lr_fn: Callable | None = None,
                    accum_dtype: str = "float32") -> Callable:
    loss_fn = make_loss_fn(cfg, attn_impl=attn_impl, remat=remat)
    adt = jnp.dtype(accum_dtype)

    def train_step(params, opt_state, batch):
        if accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # Split the global batch into `accum` microbatches (chunks) and
            # scan, accumulating gradients in `accum_dtype` (fp32 default;
            # bf16 halves the accumulation buffer — perf-iteration lever).
            def reshape(x):
                b = x.shape[0]
                assert b % accum == 0, (b, accum)
                return x.reshape((accum, b // accum) + x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(adt), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: (g.astype(jnp.float32)
                                            / accum), grads)

        lr = lr_fn(opt_state["step"]) if lr_fn is not None else None
        new_params, new_state, metrics = adamw.update(
            grads, opt_state, params, opt_cfg, lr=lr)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, *, attn_impl: str = "chunked") -> Callable:
    loss_fn = make_loss_fn(cfg, attn_impl=attn_impl, remat=False)

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step
