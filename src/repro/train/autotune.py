"""acc-driven training autotuning: data-parallel width + microbatch count.

This is the paper's executor applied at the training-loop level:

* ``measure_iteration`` → analytic per-token step cost from MODEL_FLOPS
  and the weight/activation traffic through the v5e roofline;
* ``processing_units_count`` → how many mesh devices the step should
  actually occupy (Eq. 7: small workloads leave devices free — elastic
  scaling / multi-tenancy, exactly the paper's "leaves cores available for
  other parallel tasks");
* ``get_chunk_size`` → tokens per microbatch (Eq. 10, C chunks per core),
  floored by the T_m rule so a microbatch still saturates the chip.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, ShapeConfig
from ..core.acc import AdaptiveCoreChunk
from ..core.calibration import CalibrationCache
from ..core.cost_model import WorkloadProfile
from ..core.executor import Executor
from ..core.model import DecisionKey
from ..core.overhead_law import AccDecision
from ..core.properties import params_of
from ..kernels.autotune import KernelTuner


def make_kernel_tuner(cache: CalibrationCache | None = None,
                      **kw) -> KernelTuner:
    """The process's measured Pallas block tuner, bound to the same
    calibration store the acc decisions read.

    Training and serving both build their tuner here (launch/train and
    launch/serve ``--kernel-autotune``): winner keys are
    ``(kernel, shape-bucket, dtype)`` + the hardware key — workload-free
    — so a block tuned while training is reused when the serving path
    later hits the same kernel shape, and vice versa.  One store, one
    search per (kernel, shape, hardware) fleet-wide.
    """
    if cache is None:
        cache = CalibrationCache.persistent()
    return KernelTuner(cache, **kw)


def token_profile(cfg: ArchConfig, *, training: bool = True) -> WorkloadProfile:
    """Per-token cost of one step (per-device view is handled by acc)."""
    n_active = cfg.active_param_count()
    flops = (6.0 if training else 2.0) * n_active
    # weight traffic dominates memory per step at large batch; activations
    # are roughly d_model * n_layers * ~20 bytes/token
    bytes_ = 20.0 * cfg.d_model * cfg.n_layers
    return WorkloadProfile(flops_per_elem=flops, bytes_per_elem=bytes_,
                           name=f"{cfg.name}-{'train' if training else 'serve'}")


def serve_profiles(cfg: ArchConfig) -> tuple[WorkloadProfile, WorkloadProfile]:
    """(prefill, decode) per-token profiles for the serving scheduler.

    Prefill reuses the training-loop profile at forward-only FLOPs (the
    workload element is a prompt token).  Decode is the memory-bound
    regime: each generated token re-reads the active weights, so the
    bytes term is the full 2-byte-per-param weight stream rather than the
    amortised activation traffic.
    """
    prefill = token_profile(cfg, training=False)
    n_active = cfg.active_param_count()
    decode = WorkloadProfile(flops_per_elem=2.0 * n_active,
                             bytes_per_elem=2.0 * n_active,
                             name=f"{cfg.name}-decode")
    return prefill, decode


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    data_parallel: int       # devices the step occupies (acc Eq. 7)
    accum: int               # gradient-accumulation microbatches (Eq. 10)
    microbatch: int          # sequences per microbatch (global)
    decision: AccDecision


def choose_plan(cfg: ArchConfig, shape: ShapeConfig,
                mesh_exec: Executor,
                acc: AdaptiveCoreChunk | None = None,
                *, max_accum: int = 64) -> TrainPlan:
    """``mesh_exec`` may be a ``MeshExecutor`` or any wrapper around one
    (``adaptive(MeshExecutor(mesh))``); with an ``AdaptiveExecutor`` the
    acc object rides on the executor and ``acc=`` can be omitted."""
    acc = acc or params_of(mesh_exec) or AdaptiveCoreChunk()
    profile = token_profile(cfg, training=(shape.kind == "train"))
    tokens = shape.global_batch * shape.seq_len
    key = DecisionKey("train_plan", (cfg.name, shape.name,
                                     shape.global_batch, shape.seq_len))
    d = acc.decide_for_profile(mesh_exec, profile, tokens, key=key)

    dp = d.n_cores
    while dp > 1 and shape.global_batch % dp:
        dp -= 1  # dp must divide the global batch
    # chunk(tokens) -> microbatches: one microbatch must hold >= dp
    # sequences (one per device) and divide the global batch.
    seqs_per_chunk = max(d.chunk_elems // shape.seq_len, 1)
    accum = max(min(shape.global_batch // max(seqs_per_chunk, 1), max_accum), 1)
    while shape.global_batch % accum or (shape.global_batch // accum) % dp:
        accum -= 1  # snap to a divisor compatible with the dp width
    microbatch = shape.global_batch // accum
    # The raw engine decision is already traced (decide_for_profile); the
    # divisor snapping above changes the shipped numbers, so trace those
    # too — the dump must attribute what actually runs.
    acc.model.note(key, policy="train-plan", cores=dp,
                   chunk=microbatch * shape.seq_len, batch_width=dp, acc=d,
                   inputs=(("accum", accum), ("microbatch", microbatch),
                           ("tokens", tokens)))
    return TrainPlan(data_parallel=dp, accum=accum,
                     microbatch=microbatch, decision=d)
