"""Explicit data-parallel training with int8 gradient compression and
error feedback (shard_map variant).

The pjit path lets XLA insert gradient all-reduces; this variant makes the
sync explicit so it can be compressed — the distributed-optimization trick
for collective-bound training steps:

  1. local fp32 grads + error-feedback buffer,
  2. per-leaf int8 quantisation (scale = pmax |g| / 127),
  3. all_to_all(int8) → local reduction → all_gather(int8)
     (a quantised reduce-scatter + all-gather ring: collective bytes drop
     ~4× vs fp32 all-reduce — visible in the HLO roofline term),
  4. residual (g - dequantised(Q(g))) carried to the next step.

The second-stage quantisation (of the reduced sum) is not error-fed; its
error is bounded by 1/127 of the max summed gradient (documented).
"""
from __future__ import annotations

import inspect
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..algorithms.detail import shard_map
from ..configs.base import ArchConfig
from ..optim import adamw
from . import train_loop

# The "don't verify replication" switch was renamed check_rep -> check_vma
# when shard_map moved out of jax.experimental; pass whichever this jax
# spells (the detail.shard_map alias already bridges the module move).
_CHECK_KW = "check_vma" if "check_vma" in \
    inspect.signature(shard_map).parameters else "check_rep"


def _quantize(g: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_allreduce_mean(q: jax.Array, scale: jax.Array, axis: str,
                         n_dev: int) -> jax.Array:
    """Quantised ring all-reduce of a flat int8 vector; returns fp32 mean."""
    n = q.shape[0]
    pad = (-n) % n_dev
    if pad:
        q = jnp.pad(q, (0, pad))
    qs = q.reshape(n_dev, -1)
    # reduce-scatter stage: everyone sends shard i to device i (int8 wire)
    shards = jax.lax.all_to_all(qs, axis, split_axis=0, concat_axis=0,
                                tiled=False)
    local_sum = jnp.sum(shards.astype(jnp.int32), axis=0)       # (m,)
    # requantise the reduced shard for the int8 gather stage
    s2 = jax.lax.pmax(jnp.max(jnp.abs(local_sum)), axis).astype(jnp.float32)
    s2 = jnp.maximum(s2 / 127.0, 1e-12)
    q2 = jnp.clip(jnp.round(local_sum.astype(jnp.float32) / s2),
                  -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis)                      # (n_dev, m)
    out = gathered.reshape(-1).astype(jnp.float32) * s2 * scale / n_dev
    return out[:n]


def make_compressed_dp_train_step(cfg: ArchConfig,
                                  opt_cfg: adamw.AdamWConfig, mesh, *,
                                  axis: str = "data",
                                  attn_impl: str = "chunked",
                                  remat: bool = True) -> Callable:
    """(params, opt_state, ef, batch) → (params, opt_state, ef, metrics).

    params/opt_state replicated; ``ef`` leaves carry a leading device dim
    (the per-device residual); batch sharded over ``axis``."""
    loss_fn = train_loop.make_loss_fn(cfg, attn_impl=attn_impl, remat=remat)
    n_dev = mesh.shape[axis]

    def shard_fn(params, opt_state, ef, batch):
        ef = jax.tree.map(lambda e: e[0], ef)  # strip sharded leading dim
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, ef)

        def sync_leaf(g):
            q, scale = _quantize(g.reshape(-1), axis)
            deq_local = q.astype(jnp.float32) * scale
            ef_new = (g.reshape(-1) - deq_local).reshape(g.shape)
            mean = _int8_allreduce_mean(q, scale, axis, n_dev)
            return mean.reshape(g.shape), ef_new

        flat, tdef = jax.tree.flatten(grads)
        synced, ef_new = zip(*(sync_leaf(g) for g in flat), strict=True)
        g_sync = jax.tree.unflatten(tdef, list(synced))
        ef_new = jax.tree.unflatten(tdef, list(ef_new))
        new_params, new_state, metrics = adamw.update(
            g_sync, opt_state, params, opt_cfg)
        loss = jax.lax.pmean(loss, axis)
        metrics = dict(metrics, loss=loss)
        ef_new = jax.tree.map(lambda e: e[None], ef_new)  # re-add dev dim
        return new_params, new_state, ef_new, metrics

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=(P(), P(), P(axis), P()),
        **{_CHECK_KW: False}))


def init_error_feedback(params, n_dev: int):
    """Per-device residual buffers, leading dim = device axis extent."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_dev,) + p.shape, jnp.float32), params)
