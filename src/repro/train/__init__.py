from .autotune import TrainPlan, choose_plan, token_profile
from .grad_compress import init_error_feedback, make_compressed_dp_train_step
from .train_loop import make_eval_step, make_loss_fn, make_train_step

__all__ = ["make_train_step", "make_eval_step", "make_loss_fn",
           "choose_plan", "TrainPlan", "token_profile",
           "make_compressed_dp_train_step", "init_error_feedback"]
