from .checkpointer import AsyncCheckpointer, latest, restore, save

__all__ = ["save", "restore", "latest", "AsyncCheckpointer"]
