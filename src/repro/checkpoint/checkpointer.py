"""Sharded checkpointing: atomic publish, async save, elastic restore.

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/DONE
Writes go to a temp dir first and are renamed into place; a checkpoint
without DONE is ignored by ``latest`` (crash-safe).  ``AsyncCheckpointer``
runs saves on a background thread (training continues; ``wait()`` before
exit).  Restore maps arrays back onto any pytree structure ("like"), so a
restart may use a different mesh — resharding is a ``device_put`` with
the new shardings (runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step}, f)
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    done = [d for d in sorted(os.listdir(ckpt_dir))
            if d.startswith("step_")
            and os.path.exists(os.path.join(ckpt_dir, d, "DONE"))]
    return os.path.join(ckpt_dir, done[-1]) if done else None


def restore(path: str, like: Any, *, shardings: Any = None) -> tuple[Any, int]:
    """Restore arrays onto the structure of ``like``.  ``shardings`` (same
    structure or a single sharding) triggers device_put — the elastic-
    restart path."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = dict(z.items())
    with open(os.path.join(path, "meta.json")) as f:
        step = json.load(f)["step"]

    paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = arrays[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(tdef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step


class AsyncCheckpointer:
    """Background-thread saver: one in-flight save, newest-wins queue."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # Snapshot to host first (cheap; arrays are already on host for CPU
        # and become a device->host copy on TPU) so training can mutate.
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001 - surfaced in wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
