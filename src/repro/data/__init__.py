from .synthetic import TokenPipeline, batch_shapes, input_specs, make_batch

__all__ = ["TokenPipeline", "batch_shapes", "input_specs", "make_batch"]
