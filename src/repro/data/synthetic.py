"""Synthetic data: batch generation for smoke/e2e runs, and
ShapeDtypeStruct specs for the dry-run (no allocation).

The frontend stubs live here per the assignment: [vlm]/[audio] archs get
precomputed patch/frame embeddings as inputs ("frontend_feats")."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig


def batch_shapes(cfg: ArchConfig, batch: int, seq: int,
                 kind: str = "train") -> dict:
    """Logical shapes/dtypes of one batch (used by input_specs and the
    generator)."""
    shapes = {"tokens": ((batch, seq), jnp.int32)}
    if kind == "train":
        shapes["labels"] = ((batch, seq), jnp.int32)
    if cfg.frontend == "vision":
        shapes["frontend_feats"] = (
            (batch, cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return shapes


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                kind_override: str | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    kind = kind_override or shape.kind
    if kind == "decode":
        # one new token against a seq_len-deep cache
        shapes = batch_shapes(cfg, shape.global_batch, 1, "decode")
    elif kind == "prefill":
        shapes = batch_shapes(cfg, shape.global_batch, shape.seq_len,
                              "prefill")
    else:
        shapes = batch_shapes(cfg, shape.global_batch, shape.seq_len,
                              "train")
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}


def make_batch(cfg: ArchConfig, batch: int, seq: int, *,
               kind: str = "train", seed: int = 0) -> dict:
    rs = np.random.RandomState(seed)
    out = {"tokens": jnp.asarray(
        rs.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)}
    if kind == "train":
        out["labels"] = jnp.asarray(
            rs.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    if cfg.frontend == "vision":
        out["frontend_feats"] = jnp.asarray(
            rs.randn(batch, cfg.num_frontend_tokens, cfg.d_model) * 0.02,
            jnp.dtype(cfg.compute_dtype))
    return out


class TokenPipeline:
    """Host-side synthetic token stream with simple double-buffer prefetch
    (stands in for a real corpus loader; the interface is what matters:
    ``__iter__`` yields device-ready global batches)."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, prefetch: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed = seed
        self.prefetch = prefetch

    def __iter__(self):
        import queue
        import threading

        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def produce():
            step = 0
            while not stop.is_set():
                b = make_batch(self.cfg, self.batch, self.seq,
                               kind="train", seed=self.seed + step)
                q.put(b)
                step += 1

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
