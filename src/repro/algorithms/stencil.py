"""3-point stencil update — the paper's motivating Cauchy-problem kernel
(Section 2: finite-difference evolution of grid data).

out[i] = a*x[i-1] + b*x[i] + c*x[i+1], boundaries copied through.
Host path chunks with a one-element halo on each side; the mesh path
exchanges halos with ppermute.  ``artificial_work`` is the paper's
compute-bound body (Figures 3/4): K fused multiply-adds per element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.future import when_all
from . import detail


def _stencil_once(arr, a, b, c):
    inner = a * arr[:-2] + b * arr[1:-1] + c * arr[2:]
    return jnp.concatenate([arr[:1], inner, arr[-1:]])


def stencil3(policy, x: jax.Array, a: float = 1.0, b: float = -2.0,
             c: float = 1.0) -> jax.Array:
    count = x.shape[0]
    if count < 3:
        return x

    jf_whole = jax.jit(functools.partial(_stencil_once, a=a, b=b, c=c))
    body = detail.measured_body(jf_whole, x)
    p = detail.plan(policy, count, body, key=("stencil3", str(x.dtype)))
    if not p.parallel:
        return jf_whole(x)

    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None:
        cores = p.cores

        def shard_fn(xl):
            from_left = jax.lax.ppermute(
                xl[-1:], "data", [(i, (i + 1) % cores) for i in range(cores)])
            from_right = jax.lax.ppermute(
                xl[:1], "data", [(i, (i - 1) % cores) for i in range(cores)])
            ext = jnp.concatenate([from_left, xl, from_right])
            return _stencil_once(ext, a, b, c)[1:-1]

        out = detail.mesh_map(mexec, p.cores, shard_fn, x)
        # True array boundaries are copied through (the wraparound halos at
        # the outermost shards and any tail padding are overwritten here).
        return out.at[0].set(x[0]).at[-1].set(x[-1])

    # Host path: each chunk reads its halo-extended slice, applies the
    # whole-array stencil (which copies slice boundaries), and keeps the
    # sub-range it owns.  Boundary copies land exactly on the true array
    # boundaries because the outermost slices are not halo-extended there.
    def thunk(ch):
        lo = max(ch.start - 1, 0)
        hi = min(ch.start + ch.size + 1, count)
        off = ch.start - lo
        out = jf_whole(x[lo:hi])[off:off + ch.size]
        jax.block_until_ready(out)
        return out

    outs = when_all(
        p.executor.bulk_async_execute(thunk, p.chunks)).result()
    return jnp.concatenate(outs, axis=0)


def artificial_work(policy, x: jax.Array, iters: int = 256) -> jax.Array:
    """The paper's compute-bound body: ``iters`` fused multiply-adds per
    element (negligible memory traffic relative to FLOPs)."""
    from .for_each import transform

    def body(c):
        def step(carry, _):
            return carry * 1.000000119 + 0.1, None

        out, _ = jax.lax.scan(step, c, None, length=iters)
        return out

    return transform(policy, x, body)
