"""HPX-style parallel algorithms over JAX, driven by execution policies and
the adaptive core/chunk execution-parameters object (the paper's acc)."""
from .adjacent_difference import adjacent_difference
from .for_each import copy, fill, for_each, generate, transform
from .reduce import (all_of, any_of, count_if, max_element, min_element,
                     none_of, reduce, transform_reduce)
from .scan import exclusive_scan, inclusive_scan
from .stencil import artificial_work, stencil3

__all__ = [
    "transform", "for_each", "copy", "fill", "generate",
    "reduce", "transform_reduce", "count_if", "all_of", "any_of", "none_of",
    "min_element", "max_element",
    "inclusive_scan", "exclusive_scan",
    "adjacent_difference", "stencil3", "artificial_work",
]
