"""Prefix sums (paper: "map-reduce-type ... prefix sums").

Chunk-parallel three-phase scan:
  1. scan each chunk locally           (parallel),
  2. exclusive-scan the chunk totals   (serial, n_chunks elements),
  3. combine each chunk with its offset (parallel).

The mesh path does the same with shard-local scans and an all-gather of
shard totals (detail.mesh_scan).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.future import when_all
from . import detail


def _assoc_scan(op, c):
    return jax.lax.associative_scan(op, c)


def inclusive_scan(policy, x: jax.Array, op: Callable = jnp.add) -> jax.Array:
    local = jax.jit(lambda c: _assoc_scan(op, c))
    combine = jax.jit(lambda c, off: op(off, c))

    body = detail.measured_body(local, x)
    p = detail.plan(policy, x.shape[0], body, key=("iscan", str(x.dtype)))
    if not p.parallel:
        return local(x)

    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None:
        identity = _scan_identity(op, x.dtype)
        return detail.mesh_scan(
            mexec, p.cores, x,
            local_scan=lambda c: _assoc_scan(op, c),
            local_total=lambda c: jax.lax.reduce(
                c, identity.astype(c.dtype), op, (0,)),
            apply_offset=lambda s, off: op(off, s),
            identity=identity)

    # Phase 1: local scans (parallel)
    def thunk(c):
        out = local(x[c.start:c.start + c.size])
        jax.block_until_ready(out)
        return out

    scanned = when_all(
        p.executor.bulk_async_execute(thunk, p.chunks)).result()
    # Phase 2: serial exclusive scan of totals
    offsets = []
    carry = None
    for s in scanned:
        offsets.append(carry)
        carry = s[-1] if carry is None else op(carry, s[-1])
    # Phase 3: apply offsets (parallel)
    def apply(args):
        i, off = args
        return scanned[i] if off is None else combine(scanned[i], off)

    outs = when_all(p.executor.bulk_async_execute(
        apply, list(enumerate(offsets)))).result()
    return jnp.concatenate(outs, axis=0)


def exclusive_scan(policy, x: jax.Array, init, op: Callable = jnp.add) -> jax.Array:
    """out[0] = init; out[i] = op(out[i-1], x[i-1])."""
    inc = inclusive_scan(policy, x, op)
    shifted = jnp.concatenate(
        [jnp.asarray([init], dtype=x.dtype), op(jnp.asarray(init, x.dtype), inc[:-1])])
    return shifted


def _scan_identity(op, dtype):
    from .reduce import _identity_for

    return _identity_for(op, dtype, None)
