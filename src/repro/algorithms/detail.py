"""Shared machinery for the parallel-algorithm suite.

Every algorithm follows the paper's call sequence (Listing 1.1):

    t_iter = measure_iteration(params, exec, body, count)
    cores  = processing_units_count(params, exec, t_iter, count)
    chunk  = get_chunk_size(params, exec, t_iter, cores, count)

then executes its chunks on the policy's executor.  Two execution paths:

* host path — chunk thunks dispatched with ``bulk_async_execute`` and
  joined with ``when_all`` (each thunk is a jit-compiled slice
  computation; XLA releases the GIL);
* mesh path — shard_map over an acc-sized sub-mesh (taken when the bound
  executor is — or wraps — a ``MeshExecutor``; see ``mesh_executor_of``).

Execution parameters resolve from the policy first, then from the
executor's ``params`` annotation — that second step is what makes
``par.on(adaptive(ex))`` equivalent to ``par.on(ex).with_(acc)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core import customization as cp
from ..core.executor import (Chunk, MeshExecutor, SequentialExecutor,
                             make_chunks, mesh_executor_of)
from ..core.future import when_all
from ..core.policy import ExecutionPolicy

__all__ = ["Plan", "plan", "measured_body", "run_map_chunks",
           "run_reduce_chunks", "mesh_executor_of", "submesh_1d",
           "pad_to", "mesh_map", "mesh_map_with_left_halo", "mesh_scan",
           "mesh_reduce", "shard_map"]

# jax.shard_map landed in 0.4.35 as experimental and moved to the top
# level later; support both spellings.  Public: the algorithm modules (and
# any out-of-tree mesh backend) should use this instead of jax.shard_map.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map


@dataclasses.dataclass
class Plan:
    executor: Any
    params: Any
    t_iter: float
    cores: int
    chunk_elems: int
    chunks: list[Chunk]

    @property
    def parallel(self) -> bool:
        return self.cores > 1 and len(self.chunks) > 1


def plan(policy: ExecutionPolicy, count: int,
         body: Callable[[int, int], Any] | Any = None,
         key: Any = None) -> Plan:
    """Run the three customization points and build the chunk list.

    The key (explicit, or derived from an analytic profile's name)
    labels the decision in the ExecutionModel trace and is where online
    feedback for this workload accumulates."""
    executor = policy.resolve_executor()
    params = policy.resolve_params(executor)
    if not policy.allows_parallel or count <= 1:
        return Plan(SequentialExecutor(), params, 0.0, 1, max(count, 1),
                    make_chunks(count, max(count, 1)))
    if key is None and getattr(body, "name", None) is not None \
            and not callable(body):
        key = ("algorithm", body.name)   # WorkloadProfile-style bodies
    kw = {"key": key} if (key is not None and params is not None
                          and hasattr(params, "measure_iteration")) else {}
    t_iter = cp.measure_iteration(params, executor, body, count, **kw)
    cores = cp.processing_units_count(params, executor, t_iter, count)
    chunk = cp.get_chunk_size(params, executor, t_iter, cores, count)
    return Plan(executor, params, t_iter, cores, chunk,
                make_chunks(count, chunk))


# ---------------------------------------------------------------------------
# Host path helpers
# ---------------------------------------------------------------------------

def measured_body(jitted_chunk_fn: Callable, *arrays: jax.Array):
    """Wrap a jitted chunk function into the body(start, size) thunk that
    ``measure_iteration`` times.  Synchronises before returning."""

    def body(start: int, size: int):
        out = jitted_chunk_fn(*(a[start:start + size] for a in arrays))
        jax.block_until_ready(out)
        return out

    return body


def run_map_chunks(plan_: Plan, jitted_chunk_fn: Callable,
                   *arrays: jax.Array) -> jax.Array:
    """Elementwise chunked execution + concatenation."""
    if not plan_.parallel:
        return jitted_chunk_fn(*arrays)

    def thunk(c: Chunk):
        out = jitted_chunk_fn(*(a[c.start:c.start + c.size] for a in arrays))
        jax.block_until_ready(out)
        return out

    futs = plan_.executor.bulk_async_execute(thunk, plan_.chunks)
    return jnp.concatenate(when_all(futs).result(), axis=0)


def run_reduce_chunks(plan_: Plan, jitted_partial_fn: Callable,
                      combine: Callable[[Any, Any], Any],
                      *arrays: jax.Array) -> Any:
    """Two-phase reduction: parallel chunk partials, serial combine."""
    if not plan_.parallel:
        return jitted_partial_fn(*arrays)

    def thunk(c: Chunk):
        out = jitted_partial_fn(*(a[c.start:c.start + c.size] for a in arrays))
        jax.block_until_ready(out)
        return out

    partials = when_all(
        plan_.executor.bulk_async_execute(thunk, plan_.chunks)).result()
    acc = partials[0]
    for p in partials[1:]:
        acc = combine(acc, p)
    return acc


# ---------------------------------------------------------------------------
# Mesh path helpers
# ---------------------------------------------------------------------------

def submesh_1d(mexec: MeshExecutor, cores: int) -> jax.sharding.Mesh:
    """A 1-d 'data' mesh over the first ``cores`` devices of the executor's
    mesh (cores already snapped to a divisor by MeshExecutor.submesh_size)."""
    devs = np.asarray(mexec.mesh.devices).reshape(-1)[:cores]
    return jax.sharding.Mesh(devs, ("data",))


def pad_to(x: jax.Array, multiple: int, fill=0):
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad_width = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width, constant_values=fill), n


def mesh_map(mexec: MeshExecutor, cores: int, shard_fn: Callable,
             x: jax.Array, fill=0) -> jax.Array:
    """Elementwise map via shard_map over an acc-chosen sub-mesh."""
    mesh = submesh_1d(mexec, cores)
    xp, n = pad_to(x, cores, fill)
    f = jax.jit(shard_map(shard_fn, mesh=mesh,
                          in_specs=P("data"), out_specs=P("data")))
    return f(xp)[:n]


def mesh_map_with_left_halo(mexec: MeshExecutor, cores: int,
                            shard_fn: Callable, x: jax.Array,
                            fill=0) -> jax.Array:
    """Map where each shard also needs its left neighbour's last element
    (adjacent_difference).  Halo moves by ppermute; shard_fn receives
    (local_block, left_halo_scalar_block) and the global shard index."""
    mesh = submesh_1d(mexec, cores)
    xp, n = pad_to(x, cores, fill)

    def wrapper(xl):
        idx = jax.lax.axis_index("data")
        last = xl[-1:]
        left = jax.lax.ppermute(
            last, "data", [(i, (i + 1) % cores) for i in range(cores)])
        return shard_fn(xl, left, idx)

    f = jax.jit(shard_map(wrapper, mesh=mesh,
                          in_specs=P("data"), out_specs=P("data")))
    return f(xp)[:n]


def mesh_scan(mexec: MeshExecutor, cores: int, x: jax.Array,
              local_scan: Callable, local_total: Callable,
              apply_offset: Callable, identity) -> jax.Array:
    """Distributed prefix sum: shard-local scan, all-gather of shard totals,
    local offset from an exclusive scan of the totals."""
    mesh = submesh_1d(mexec, cores)
    xp, n = pad_to(x, cores, identity)

    def wrapper(xl):
        idx = jax.lax.axis_index("data")
        scanned = local_scan(xl)
        total = local_total(xl)
        totals = jax.lax.all_gather(total, "data")        # (cores,)
        mask = jnp.arange(cores) < idx                     # exclusive
        offset = local_total(jnp.where(mask, totals, identity))
        return apply_offset(scanned, offset)

    f = jax.jit(shard_map(wrapper, mesh=mesh,
                          in_specs=P("data"), out_specs=P("data")))
    return f(xp)[:n]


def mesh_reduce(mexec: MeshExecutor, cores: int, x: jax.Array,
                local_partial: Callable, identity) -> jax.Array:
    """Shard-local partials, returned as a (cores,)-shaped array for the
    caller to combine (reduce-scatter shape; the final combine over
    ``cores`` elements is negligible)."""
    mesh = submesh_1d(mexec, cores)
    xp, _ = pad_to(x, cores, identity)

    def wrapper(xl):
        p = local_partial(xl)
        return jnp.reshape(p, (1,) + p.shape)

    f = jax.jit(shard_map(wrapper, mesh=mesh,
                          in_specs=P("data"), out_specs=P("data")))
    return f(xp)
