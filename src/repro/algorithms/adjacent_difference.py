"""adjacent_difference — the paper's memory-bound benchmark algorithm.

out[0] = x[0];  out[i] = op(x[i], x[i-1])  (op defaults to subtraction).

Chunked execution needs a one-element left halo per chunk; the mesh path
moves the halo with a ppermute (the TPU analogue of the neighbouring
cache-line read on CPU).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.future import when_all
from . import detail


def adjacent_difference(policy, x: jax.Array,
                        op: Callable = jnp.subtract) -> jax.Array:
    count = x.shape[0]
    if count == 0:
        return x

    def whole(c):
        return jnp.concatenate([c[:1], op(c[1:], c[:-1])])

    jf_whole = jax.jit(whole)
    body = detail.measured_body(jf_whole, x)
    p = detail.plan(policy, count, body, key=("adjdiff", str(x.dtype)))
    if not p.parallel:
        return jf_whole(x)

    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None:
        def shard_fn(xl, left, idx):
            first = jnp.where(idx == 0, xl[:1], op(xl[:1], left))
            return jnp.concatenate([first, op(xl[1:], xl[:-1])])

        return detail.mesh_map_with_left_halo(mexec, p.cores, shard_fn, x)

    # Host path: interior chunks read one halo element to their left.
    def interior(c_with_halo):
        return op(c_with_halo[1:], c_with_halo[:-1])

    jf_interior = jax.jit(interior)

    def thunk(c):
        if c.start == 0:
            out = jf_whole(x[:c.size])
        else:
            out = jf_interior(x[c.start - 1:c.start + c.size])
        jax.block_until_ready(out)
        return out

    outs = when_all(
        p.executor.bulk_async_execute(thunk, p.chunks)).result()
    return jnp.concatenate(outs, axis=0)
