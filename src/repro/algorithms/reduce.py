"""Map-reduce-type algorithms (paper Section 1): reduce, transform_reduce,
count_if, all_of / any_of / none_of, min_element / max_element."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.future import when_all
from . import detail


def _plan_for(policy, x, jf_partial, tag):
    body = detail.measured_body(jf_partial, x)
    return detail.plan(policy, x.shape[0], body, key=(tag, str(x.dtype)))


def reduce(policy, x: jax.Array, op: Callable = jnp.add, init=None):
    """Generic associative reduction.  ``op`` is a binary jnp callable;
    common cases (add/min/max) hit fused partials."""
    identity = _identity_for(op, x.dtype, init)

    def partial(c):
        return jax.lax.reduce(c, identity.astype(c.dtype), op, (0,))

    jf = jax.jit(partial)
    p = _plan_for(policy, x, jf, "reduce")
    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None and p.parallel:
        parts = detail.mesh_reduce(mexec, p.cores, x, jf,
                                   identity.astype(x.dtype))
        return jax.lax.reduce(parts, identity.astype(x.dtype), op, (0,))
    out = detail.run_reduce_chunks(p, jf, op, x)
    if init is not None and op in (jnp.add,):
        out = op(out, init)
    return out


def _identity_for(op, dtype, init):
    if op is jnp.add:
        return jnp.zeros((), dtype)
    if op is jnp.multiply:
        return jnp.ones((), dtype)
    if op is jnp.minimum:
        return jnp.array(jnp.inf if jnp.issubdtype(dtype, jnp.floating)
                         else jnp.iinfo(dtype).max, dtype)
    if op is jnp.maximum:
        return jnp.array(-jnp.inf if jnp.issubdtype(dtype, jnp.floating)
                         else jnp.iinfo(dtype).min, dtype)
    if op in (jnp.logical_and,):
        return jnp.array(True)
    if op in (jnp.logical_or,):
        return jnp.array(False)
    if init is not None:
        return jnp.asarray(init, dtype)
    raise ValueError(f"no identity known for {op}; pass init=")


def transform_reduce(policy, x: jax.Array, transform_fn: Callable,
                     op: Callable = jnp.add, init=None):
    identity = _identity_for(op, x.dtype, init)

    def partial(c):
        t = transform_fn(c)
        return jax.lax.reduce(t, identity.astype(t.dtype), op, (0,))

    jf = jax.jit(partial)
    p = _plan_for(policy, x, jf, ("transform_reduce", id(transform_fn)))
    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None and p.parallel:
        parts = detail.mesh_reduce(mexec, p.cores, x, jf, identity)
        return jax.lax.reduce(parts, identity.astype(parts.dtype), op, (0,))
    return detail.run_reduce_chunks(p, jf, op, x)


def count_if(policy, x: jax.Array, pred: Callable):
    return transform_reduce(
        policy, x, lambda c: pred(c).astype(jnp.int32), jnp.add)


def all_of(policy, x: jax.Array, pred: Callable):
    return transform_reduce(policy, x, pred, jnp.logical_and)


def any_of(policy, x: jax.Array, pred: Callable):
    return transform_reduce(policy, x, pred, jnp.logical_or)


def none_of(policy, x: jax.Array, pred: Callable):
    return jnp.logical_not(any_of(policy, x, pred))


def _arg_extreme(policy, x: jax.Array, is_min: bool):
    """(value, index) of the extreme element, chunk-parallel."""
    def partial(c):
        i = jnp.argmin(c) if is_min else jnp.argmax(c)
        return c[i], i

    jf = jax.jit(partial)
    body = detail.measured_body(jf, x)
    p = detail.plan(policy, x.shape[0], body,
                    key=("min" if is_min else "max", str(x.dtype)))
    if not p.parallel:
        return jf(x)

    def thunk(c):
        v, i = jf(x[c.start:c.start + c.size])
        jax.block_until_ready(v)
        return v, i + c.start

    partials = when_all(
        p.executor.bulk_async_execute(thunk, p.chunks)).result()
    vals = jnp.stack([v for v, _ in partials])
    idxs = jnp.stack([i for _, i in partials])
    sel = jnp.argmin(vals) if is_min else jnp.argmax(vals)
    return vals[sel], idxs[sel]


def min_element(policy, x: jax.Array):
    return _arg_extreme(policy, x, True)


def max_element(policy, x: jax.Array):
    return _arg_extreme(policy, x, False)
