"""Map-type algorithms: transform, for_each, copy, fill, generate.

These are the algorithms the paper classifies as "map-type" (Section 1).
Each takes an execution policy first, mirroring the C++ API:

    transform(par.on(adaptive(HostParallelExecutor())), x, fn)
    transform(par.on(HostParallelExecutor()).with_(acc), x, fn)   # equivalent
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import detail


def _chunk_key(fn: Callable, x: jax.Array, tag: str):
    return (tag, id(fn), str(x.dtype))


def transform(policy, x: jax.Array, fn: Callable,
              y: jax.Array | None = None) -> jax.Array:
    """out[i] = fn(x[i])  (or fn(x[i], y[i]) for the binary overload)."""
    arrays = (x,) if y is None else (x, y)
    jf = jax.jit(jnp.vectorize(fn) if _is_scalar_fn(fn) else fn)
    count = x.shape[0]
    body = detail.measured_body(jf, *arrays)
    p = detail.plan(policy, count, body, key=_chunk_key(fn, x, "transform"))
    mexec = detail.mesh_executor_of(p.executor)
    if mexec is not None and p.parallel:
        if y is None:
            return detail.mesh_map(mexec, p.cores, jf, x)
        # binary: zip shards by stacking then splitting inside the shard
        mesh = detail.submesh_1d(mexec, p.cores)
        from jax.sharding import PartitionSpec as P

        xp, n = detail.pad_to(x, p.cores)
        yp, _ = detail.pad_to(y, p.cores)
        f = jax.jit(detail.shard_map(
            jf, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=P("data")))
        return f(xp, yp)[:n]
    return detail.run_map_chunks(p, jf, *arrays)


def _is_scalar_fn(fn: Callable) -> bool:
    """Heuristic: treat fns as array-level (preferred).  Users pass
    jnp-vectorised bodies; scalar bodies can be wrapped with jnp.vectorize
    by the caller.  Kept for API parity."""
    return False


def for_each(policy, x: jax.Array, fn: Callable) -> jax.Array:
    """Apply fn to every element (returns the mapped array — JAX arrays are
    immutable, so for_each is transform with the result returned)."""
    return transform(policy, x, fn)


def copy(policy, x: jax.Array) -> jax.Array:
    return transform(policy, x, lambda a: a + 0)


def fill(policy, x: jax.Array, value) -> jax.Array:
    return transform(policy, x, lambda a: jnp.full_like(a, value))


def generate(policy, count: int, fn: Callable, dtype=jnp.float32) -> jax.Array:
    """out[i] = fn(i) — fn must be jnp-vectorised over an index array."""
    idx = jnp.arange(count, dtype=jnp.int32)
    out = transform(policy, idx, fn)
    return out.astype(dtype) if out.dtype != dtype else out
