"""Feed-forward blocks: gated GLU (llama-style) or plain MLP."""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm


def init(key, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    kg = cm.KeyGen(key)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    p = {"w_up": cm.linear_init(kg(), d, ff, dtype=dt),
         "w_down": cm.linear_init(kg(), ff, d, dtype=dt)}
    if cfg.ffn_gated:
        p["w_gate"] = cm.linear_init(kg(), d, ff, dtype=dt)
    return p


def apply(p: dict, x, cfg: ArchConfig):
    cd = jnp.dtype(cfg.compute_dtype)
    act = cm.act_fn(cfg.act)
    up = cm.linear(p["w_up"], x, cd)
    if cfg.ffn_gated:
        up = act(cm.linear(p["w_gate"], x, cd)) * up
    else:
        up = act(up)
    return cm.linear(p["w_down"], up, cd)
