"""Model definitions for all assigned architecture families."""
from . import attention, common, ffn, gla, lm, mamba2, moe, xlstm
from .lm import forward, forward_cached, init_caches, init_params, loss_fn

__all__ = [
    "attention", "common", "ffn", "gla", "lm", "mamba2", "moe", "xlstm",
    "init_params", "forward", "forward_cached", "init_caches", "loss_fn",
]
