"""Mamba2 (SSD) block, built on the chunked gated-linear-attention engine
(models/gla.py) — the SSD duality: q=C, k=B, v=x, log-decay = Δ·A,
log-gain = log Δ.

Parallel (train/prefill) path: chunked_gla.  Decode path: O(1) recurrent
``gla_step`` + depthwise-conv ring state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from . import gla


def init(key, cfg: ArchConfig) -> dict:
    kg = cm.KeyGen(key)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt = jnp.dtype(cfg.param_dtype)
    conv_ch = di + 2 * n
    return {
        # order: z (gate), x, B, C, dt
        "in_proj": cm.linear_init(kg(), d, 2 * di + 2 * n + h, dtype=dt),
        "conv_w": (jax.random.normal(kg(), (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((h,), dt),               # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((h,), dt),
        "d_skip": jnp.ones((h,), dt),
        "out_proj": cm.linear_init(kg(), di, d, dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d.  x: (B, L, C); w: (W, C).
    ``state``: (B, W-1, C) carry-in; returns (out, new_state)."""
    bsz, l, c = x.shape
    wlen = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, wlen - 1, c), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(wlen):
        out = out + ext[:, i:i + l].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    new_state = ext[:, -(wlen - 1):] if wlen > 1 else state
    return (jax.nn.silu(out + b.astype(jnp.float32))).astype(x.dtype), new_state


def _project(p, xin, cfg: ArchConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cd = jnp.dtype(cfg.compute_dtype)
    zxbcdt = cm.linear(p["in_proj"], xin, cd)
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    b_ = zxbcdt[..., 2 * di:2 * di + n]
    c_ = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt_raw = zxbcdt[..., 2 * di + 2 * n:]
    return z, x, b_, c_, dt_raw


def apply(p: dict, xin: jax.Array, cfg: ArchConfig, *,
          state: dict | None = None) -> tuple[jax.Array, dict | None]:
    """xin: (B, L, d).  state (decode): {"ssm": (B,H,N,P), "conv": (B,W-1,C)}.

    Parallel path when state is None; recurrent when a state is given
    (then L is the number of new tokens, scanned one by one)."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    z, x, b_, c_, dt_raw = _project(p, xin, cfg)

    conv_in = jnp.concatenate([x, b_, c_], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    x = conv_out[..., :di]
    b_ = conv_out[..., di:di + n]
    c_ = conv_out[..., di + n:]

    bsz, l, _ = xin.shape
    a = -jnp.exp(p["a_log"].astype(jnp.float32))          # (h,)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # (B,L,h)
    log_decay = delta * a                                  # (B,L,h)
    log_gain = jnp.log(delta + 1e-9)

    xh = x.reshape(bsz, l, h, hd)
    qh = jnp.broadcast_to(c_[:, :, None, :], (bsz, l, h, n))
    kh = jnp.broadcast_to(b_[:, :, None, :], (bsz, l, h, n))

    if state is None:
        pad = (-l) % gla.DEFAULT_CHUNK
        if pad:
            padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            qh, kh, xh = padf(qh), padf(kh), padf(xh)
            log_decay, log_gain = padf(log_decay), padf(log_gain)
        y, s_final = gla.chunked_gla(qh, kh, xh, log_decay, log_gain)
        y = y[:, :l]
        new_state = {"ssm": s_final, "conv": new_conv}
    else:
        s = state["ssm"]
        ys = []
        for t in range(l):
            yt, s = gla.gla_step(qh[:, t], kh[:, t], xh[:, t],
                                 log_decay[:, t], log_gain[:, t], s)
            ys.append(yt)
        y = jnp.stack(ys, axis=1)
        new_state = {"ssm": s, "conv": new_conv}

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh[:, :l].astype(jnp.float32)
    y = y.reshape(bsz, l, di).astype(cd)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    return cm.linear(p["out_proj"], y, cd), new_state


def init_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    h, n, hd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * n
    return {"ssm": jnp.zeros((batch, h, n, hd), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype)}
