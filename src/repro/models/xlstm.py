"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel via the GLA
engine) and sLSTM (scalar memory, stabilised exponential gating,
lax.scan over time).

Deviations documented in DESIGN.md: the mLSTM normaliser uses the
sum-normaliser variant (denominator = GLA with v ≡ 1, floored at 1),
which keeps the chunked form exact; the paper's running-max normaliser
couples chunks sequentially.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm
from . import gla


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ArchConfig) -> dict:
    kg = cm.KeyGen(key)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": cm.linear_init(kg(), d, di, dtype=dt),
        "wk": cm.linear_init(kg(), d, di, dtype=dt),
        "wv": cm.linear_init(kg(), d, di, dtype=dt),
        "w_if": cm.linear_init(kg(), d, 2 * h, dtype=dt),   # i, f gates
        "w_o": cm.linear_init(kg(), d, di, dtype=dt),       # output gate
        "out_proj": cm.linear_init(kg(), di, d, dtype=dt),
    }


def mlstm_apply(p: dict, xin: jax.Array, cfg: ArchConfig, *,
                state: dict | None = None) -> tuple[jax.Array, dict | None]:
    bsz, l, _ = xin.shape
    di, h = cfg.d_inner, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    cd = jnp.dtype(cfg.compute_dtype)

    q = cm.linear(p["wq"], xin, cd).reshape(bsz, l, h, hd) * (hd ** -0.5)
    k = cm.linear(p["wk"], xin, cd).reshape(bsz, l, h, hd)
    v = cm.linear(p["wv"], xin, cd).reshape(bsz, l, h, hd)
    gates = cm.linear(p["w_if"], xin, cd).astype(jnp.float32)
    i_raw, f_raw = gates[..., :h], gates[..., h:]
    log_f = jax.nn.log_sigmoid(f_raw)            # forget in (0,1)
    log_i = jax.nn.log_sigmoid(i_raw)            # bounded input gate
    o = jax.nn.sigmoid(cm.linear(p["w_o"], xin, cd).astype(jnp.float32))

    # Append a ones-column to v: the extra output channel is the
    # normaliser n·q computed by the same recurrence.
    v1 = jnp.concatenate([v.astype(jnp.float32),
                          jnp.ones((bsz, l, h, 1), jnp.float32)], axis=-1)

    if state is None:
        pad = (-l) % gla.DEFAULT_CHUNK
        if pad:
            padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            qp, kp, vp = padf(q), padf(k), padf(v1)
            ldp, lgp = padf(log_f), padf(log_i)
        else:
            qp, kp, vp, ldp, lgp = q, k, v1, log_f, log_i
        y1, s_final = gla.chunked_gla(qp, kp, vp, ldp, lgp)
        y1 = y1[:, :l]
        new_state = {"mem": s_final}
    else:
        s = state["mem"]
        ys = []
        for t in range(l):
            yt, s = gla.gla_step(q[:, t], k[:, t], v1[:, t],
                                 log_f[:, t], log_i[:, t], s)
            ys.append(yt)
        y1 = jnp.stack(ys, axis=1)
        new_state = {"mem": s}

    num, den = y1[..., :hd], y1[..., hd:]
    yh = num / jnp.maximum(jnp.abs(den), 1.0)
    y = (o.reshape(bsz, l, h, hd) * yh).reshape(bsz, l, di).astype(cd)
    return cm.linear(p["out_proj"], y, cd), new_state


def mlstm_init_state(cfg: ArchConfig, batch: int) -> dict:
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    return {"mem": jnp.zeros((batch, h, hd, hd + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ArchConfig) -> dict:
    kg = cm.KeyGen(key)
    d, di = cfg.d_model, cfg.d_inner
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_zifo": cm.linear_init(kg(), d, 4 * di, dtype=dt),
        "out_proj": cm.linear_init(kg(), di, d, dtype=dt),
    }


def slstm_apply(p: dict, xin: jax.Array, cfg: ArchConfig, *,
                state: dict | None = None) -> tuple[jax.Array, dict | None]:
    """Stabilised sLSTM (exponential gating with running max m)."""
    bsz, l, _ = xin.shape
    di = cfg.d_inner
    cd = jnp.dtype(cfg.compute_dtype)
    zifo = cm.linear(p["w_zifo"], xin, cd).astype(jnp.float32)
    z = jnp.tanh(zifo[..., :di])
    i_raw = zifo[..., di:2 * di]
    f_raw = zifo[..., 2 * di:3 * di]
    o = jax.nn.sigmoid(zifo[..., 3 * di:])

    if state is None:
        c0 = jnp.zeros((bsz, di), jnp.float32)
        n0 = jnp.zeros((bsz, di), jnp.float32)
        m0 = jnp.full((bsz, di), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        zt, it, ft = inp
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h = c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new), h

    (c, n, m), hs = jax.lax.scan(
        step, (c0, n0, m0),
        (z.swapaxes(0, 1), i_raw.swapaxes(0, 1), f_raw.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1) * o
    out = cm.linear(p["out_proj"], h.astype(cd), cd)
    return out, {"c": c, "n": n, "m": m}


def slstm_init_state(cfg: ArchConfig, batch: int) -> dict:
    di = cfg.d_inner
    return {"c": jnp.zeros((batch, di), jnp.float32),
            "n": jnp.zeros((batch, di), jnp.float32),
            "m": jnp.full((batch, di), -1e30, jnp.float32)}
