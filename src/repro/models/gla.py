"""Chunked gated linear attention — the shared engine for Mamba2 (SSD) and
mLSTM (both are gated-linear-attention recurrences).

    y_i = sum_{j<=i} (q_i · k_j) * exp(cum_i - cum_j + g_j) * v_j
    cum = inclusive cumsum of per-step log-decay

computed chunk-parallel (the paper's chunking insight applied to the
sequence dimension): intra-chunk quadratic term + inter-chunk state
S (B, H, N, P) carried by a lax.scan over chunks.  Per-chunk max
stabilisation keeps the exponentials in fp32 range; chunk length 64
bounds exp(local-cum) underflow.

Decode uses the O(1) recurrent step (``gla_step``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 64


def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                log_decay: jax.Array, log_gain: jax.Array | None = None,
                *, chunk: int = DEFAULT_CHUNK,
                initial_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """q, k: (B, L, H, N); v: (B, L, H, P); log_decay/log_gain: (B, L, H).

    Returns (y (B, L, H, P) fp32, final_state (B, H, N, P) fp32).
    """
    b, l, h, n = q.shape
    p = v.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    qf = q.astype(jnp.float32).reshape(b, nc, chunk, h, n)
    kf = k.astype(jnp.float32).reshape(b, nc, chunk, h, n)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    ld = log_decay.astype(jnp.float32).reshape(b, nc, chunk, h)
    g = (jnp.zeros_like(ld) if log_gain is None
         else log_gain.astype(jnp.float32).reshape(b, nc, chunk, h))

    lcum = jnp.cumsum(ld, axis=2)                  # within-chunk cumsum
    total = lcum[:, :, -1, :]                      # (b, nc, h)
    a = g - lcum                                   # exponent "source" term
    m = jax.lax.stop_gradient(jnp.max(a, axis=2, keepdims=True))
    ks = kf * jnp.exp(a - m)[..., None]            # stabilised keys
    qd = qf * jnp.exp(lcum)[..., None]             # decayed queries

    # intra-chunk: att[i, j] = (qd_i · ks_j) masked to i >= j, times exp(m)
    att = jnp.einsum("bcihn,bcjhn->bchij", qd, ks)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = jnp.where(mask[None, None, None], att, 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att, vf) \
        * jnp.exp(m)[..., None]                    # m: (b, nc, 1, h)

    # local end-of-chunk states: S_loc = exp(total + m) * sum_j ks_j ⊗ v_j
    s_loc = jnp.einsum("bcjhn,bcjhp->bchnp", ks, vf) \
        * jnp.exp(total + m[:, :, 0, :])[..., None, None]

    # scan chunks: S_c = exp(total_c) * S_{c-1} + S_loc_c
    decay_c = jnp.exp(total)                       # (b, nc, h)

    def step(s_prev, inp):
        dc, sl = inp                               # (b, h), (b, h, n, p)
        s_new = s_prev * dc[..., None, None] + sl
        return s_new, s_prev

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    from . import flags

    if flags.UNROLL_FOR_ACCOUNTING:
        s, prevs = s0, []
        for c in range(nc):
            prevs.append(s)
            s, _ = step(s, (decay_c[:, c], s_loc[:, c]))
        s_final = s
        s_prevs = jnp.stack(prevs, axis=1)
    else:
        s_final, s_prevs = jax.lax.scan(
            step, s0, (decay_c.swapaxes(0, 1), s_loc.swapaxes(0, 1)))
        s_prevs = s_prevs.swapaxes(0, 1)           # (b, nc, h, n, p)

    # inter-chunk: y_i += exp(lcum_i) * q_i · S_{c-1}
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", qd, s_prevs)

    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, s_final


def gla_step(q: jax.Array, k: jax.Array, v: jax.Array,
             log_decay: jax.Array, log_gain: jax.Array | None,
             state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrent step.

    q, k: (B, H, N); v: (B, H, P); log_decay/log_gain: (B, H);
    state: (B, H, N, P).  Returns (y (B, H, P), new_state).
    """
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    ld = log_decay.astype(jnp.float32)
    gain = (jnp.zeros_like(ld) if log_gain is None
            else log_gain.astype(jnp.float32))
    s_new = state * jnp.exp(ld)[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", kf * jnp.exp(gain)[..., None], vf)
    y = jnp.einsum("bhn,bhnp->bhp", qf, s_new)
    return y, s_new


def gla_reference(q, k, v, log_decay, log_gain=None):
    """Naive O(L²) oracle for tests."""
    b, l, h, n = q.shape
    cum = jnp.cumsum(log_decay.astype(jnp.float32), axis=1)
    g = (jnp.zeros_like(cum) if log_gain is None
         else log_gain.astype(jnp.float32))
    w = cum[:, :, None, :] - cum[:, None, :, :] + g[:, None, :, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    w = jnp.where(mask[None, :, :, None], jnp.exp(w), 0.0)
    att = jnp.einsum("bihn,bjhn->bijh", q.astype(jnp.float32),
                     k.astype(jnp.float32)) * w
    return jnp.einsum("bijh,bjhp->bihp", att, v.astype(jnp.float32))
