"""Trace-time flags.

UNROLL_FOR_ACCOUNTING: when True, inner sequence loops (chunked-attention
kv blocks, GLA chunk scan) trace as python loops instead of lax.scan.
XLA's cost analysis counts a while-loop body once regardless of trip
count (verified experimentally), so the dry-run's *accounting* lowerings
unroll them to get true FLOP/byte/collective totals; the *deliverable*
lowerings keep scans (fast compiles, correct memory analysis).
"""
from __future__ import annotations

import contextlib

UNROLL_FOR_ACCOUNTING = False

# NamedSharding (or None) pinning the residual stream (B, S, d).  Without
# it GSPMD may resolve the FSDP weight/batch 'data'-axis conflict by
# all-gathering the *batch* (observed: 16× attention flops per device on
# the single-pod mesh); constraining activations forces the intended
# weight-gather resolution.  Set by the launch layer around trace time.
ACT_SHARDING = None

# Measured Pallas block autotuner (kernels/autotune.KernelTuner) or None.
# When set, model-layer norms and the flash-attention path run on the
# Pallas kernels with measured block plans instead of analytic defaults.
# Read at trace time: the serve/train launchers set it around their jit
# traces (--kernel-autotune), so compiled steps bake the tuned blocks in.
KERNEL_TUNER = None

# MoE dispatch locality: number of token groups (= data-axis extent).
# None/1 = global dispatch (baseline: capacity positions via a cumsum
# over the GLOBAL token axis — GSPMD turns the scatter into full-buffer
# all-reduces over 'data').  Set to the dp extent for group-local
# dispatch: tokens never leave their data shard (§Perf iteration).
MOE_DISPATCH_GROUPS = None


@contextlib.contextmanager
def unroll_for_accounting():
    global UNROLL_FOR_ACCOUNTING
    prev = UNROLL_FOR_ACCOUNTING
    UNROLL_FOR_ACCOUNTING = True
    try:
        yield
    finally:
        UNROLL_FOR_ACCOUNTING = prev


@contextlib.contextmanager
def activation_sharding(named_sharding):
    global ACT_SHARDING
    prev = ACT_SHARDING
    ACT_SHARDING = named_sharding
    try:
        yield
    finally:
        ACT_SHARDING = prev


@contextlib.contextmanager
def kernel_tuner(tuner):
    global KERNEL_TUNER
    prev = KERNEL_TUNER
    KERNEL_TUNER = tuner
    try:
        yield
    finally:
        KERNEL_TUNER = prev


@contextlib.contextmanager
def moe_dispatch_groups(g):
    global MOE_DISPATCH_GROUPS
    prev = MOE_DISPATCH_GROUPS
    MOE_DISPATCH_GROUPS = g
    try:
        yield
    finally:
        MOE_DISPATCH_GROUPS = prev


def constrain_batch0(x):
    """Pin only the leading (group/batch) axis of a 3-d tensor to the
    active activation sharding's batch axes (used for MoE buffers)."""
    if ACT_SHARDING is None or x.ndim != 3:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = ACT_SHARDING.spec
    ns = NamedSharding(ACT_SHARDING.mesh, P(spec[0], None, None))
    return jax.lax.with_sharding_constraint(x, ns)


def constrain(x):
    """Apply the activation constraint if one is active (trace time)."""
    if ACT_SHARDING is not None and x.ndim == 3:
        return __import__("jax").lax.with_sharding_constraint(x, ACT_SHARDING)
    return x
