"""Shared model-layer primitives (pure-functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


class KeyGen:
    """Sequential PRNG key splitter."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    out = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        out = out + p["b"].astype(compute_dtype)
    return out


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p: dict, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return p["table"].astype(compute_dtype)[tokens]


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    from . import flags

    if flags.KERNEL_TUNER is not None:
        # Opt-in (--kernel-autotune): the fused Pallas kernel on measured
        # row blocks.  Import here — kernels must stay importable without
        # the model layer and vice versa.
        from ..kernels import ops as kops

        return kops.rmsnorm(x, p["g"], eps=eps, tuner=flags.KERNEL_TUNER)
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * p["g"].astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings (rotate-half convention)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim // 2)."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (S, D/2) (broadcast over B, H)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
