"""Mixture-of-Experts: top-k router with capacity-bounded scatter dispatch.

Dispatch avoids the GShard one-hot einsum (tokens × E × C memory blow-up):
positions come from an exclusive cumsum of the per-expert one-hot
(tokens×k × E ints), tokens are scatter-added into the (E·C, d) expert
buffer, and combined back by gather.  Peak extra memory is E·C·d —
directly controlled by the acc microbatching decision (smaller chunks ⇒
smaller dispatch buffers), which is the paper's chunking lever applied to
MoE.

Expert FFNs are computed with per-expert stacked weights (E, d, ff); the
launch layer shards them 2-D (d over 'data', ff over 'model') — expert
tensor parallelism.  An all_to_all expert-parallel variant exists as a
hillclimb option in the launch layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as cm


def init(key, cfg: ArchConfig) -> dict:
    kg = cm.KeyGen(key)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    scale = d ** -0.5

    def ew(d_in, d_out):
        return (jax.random.normal(kg(), (e, d_in, d_out), jnp.float32)
                * scale).astype(dt)

    p = {"router": cm.linear_init(kg(), d, e, dtype=dt),
         "w_up": ew(d, ff), "w_down": ew(ff, d)}
    if cfg.ffn_gated:
        p["w_gate"] = ew(d, ff)
    return p


def _dispatch_compute_combine(tokens, gate_idx, gate_w, p, cfg,
                              capacity: int):
    """Capacity dispatch + expert FFN + weighted combine for ONE token
    group.  vmapped over groups in the local-dispatch path."""
    t, d = tokens.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cd = jnp.dtype(cfg.compute_dtype)

    oh = jax.nn.one_hot(gate_idx.reshape(-1), e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh
    my_pos = jnp.sum(pos * oh, axis=-1)                         # (T*k,)
    expert = gate_idx.reshape(-1)
    keep = my_pos < capacity
    dest = jnp.where(keep, expert * capacity + my_pos, e * capacity)

    buf = jnp.zeros((e * capacity + 1, d), cd)
    src = jnp.repeat(tokens.astype(cd), k, axis=0)              # token-major
    buf = buf.at[dest].add(src * keep[:, None].astype(cd))
    dispatched = buf[:-1].reshape(e, capacity, d)

    act = cm.act_fn(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", dispatched, p["w_up"].astype(cd))
    if cfg.ffn_gated:
        up = act(jnp.einsum("ecd,edf->ecf", dispatched,
                            p["w_gate"].astype(cd))) * up
    else:
        up = act(up)
    eout = jnp.einsum("ecf,efd->ecd", up, p["w_down"].astype(cd))

    flat = jnp.concatenate([eout.reshape(e * capacity, d),
                            jnp.zeros((1, d), cd)])             # drop slot
    per_choice = flat[dest] * (gate_w.reshape(-1, 1).astype(cd)
                               * keep[:, None].astype(cd))
    return per_choice.reshape(t, k, d).sum(axis=1)


def apply(p: dict, x: jax.Array, cfg: ArchConfig
          ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    from . import flags

    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]

    logits = cm.linear(p["router"], tokens, jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.clip(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    assign = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(assign, 0) * jnp.mean(probs, 0))

    groups = flags.MOE_DISPATCH_GROUPS or 1
    if groups > 1 and t % groups == 0 and t // groups >= 1:
        # group-local dispatch: capacity positions and the scatter are
        # computed within each data shard, so no cross-shard buffer
        # reductions exist to partition (§Perf; baseline = global path).
        tl = t // groups
        capacity = max(-(-tl * k // e) * cfg.capacity_factor, 1.0)
        capacity = int(max(capacity, min(tl, 16)))
        out = jax.vmap(
            lambda tk, gi, gw, pp: _dispatch_compute_combine(
                tk, gi, gw, pp, cfg, capacity),
            in_axes=(0, 0, 0, None))(
            flags.constrain_batch0(tokens.reshape(groups, tl, d)),
            gate_idx.reshape(groups, tl, k),
            gate_w.reshape(groups, tl, k), p)
        out = out.reshape(t, d)
    else:
        # Statistical capacity, floored so tiny (decode) batches never
        # drop: with t <= 16 the worst case (one hot expert) is cheap.
        capacity = max(-(-t * k // e) * cfg.capacity_factor, 1.0)
        capacity = int(max(capacity, min(t, 16)))
        out = _dispatch_compute_combine(tokens, gate_idx, gate_w, p, cfg,
                                        capacity)
    return out.reshape(bsz, s, d), aux
