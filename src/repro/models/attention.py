"""Attention: GQA / MQA / MHA, qk-norm, QKV bias, sliding windows,
cross-attention (VLM), KV caches (full + ring-buffer for SWA).

Three softmax-attention implementations share one signature:
  * naive   — full S×S materialisation (oracle; small shapes only)
  * chunked — online-softmax over kv blocks in pure jnp (lax.scan); the
              default for big shapes and for the dry-run (no S² buffers)
  * flash   — the Pallas kernel (kernels/flash_attention.py)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops as kops
from ..kernels import ref as kref
from . import common as cm


# ---------------------------------------------------------------------------
# chunked online-softmax attention (pure jnp, GQA-aware, no repeat)
# ---------------------------------------------------------------------------

def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      kv_len: Any = None, scale: float | None = None,
                      block_kv: int = 1024) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).

    Online softmax over kv blocks — peak memory O(Sq * block_kv), flash
    math in pure jnp.  ``kv_len`` (int or traced scalar) masks cache/pad
    slots; q positions are end-aligned: row r ↦ kv_len - Sq + r.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kv_len = skv if kv_len is None else kv_len
    block_kv = min(block_kv, skv)
    nblocks = (skv + block_kv - 1) // block_kv
    pad = nblocks * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32) * scale
    qi = (kv_len - sq) + jnp.arange(sq)  # global q positions

    def step(carry, inputs):
        m, l, acc = carry
        kblk, vblk, jblk = inputs  # (hkv? no: (B? ...)) see swap: (hkv? )
        # kblk: (B, hkv, block_kv, d) after swapaxes: axis0 moved
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kblk.astype(jnp.float32))
        kj = jblk * block_kv + jnp.arange(block_kv)
        mask = kj[None, :] < kv_len
        if causal:
            mask = mask & (qi[:, None] >= kj[None, :])
        if window is not None:
            mask = mask & ((qi[:, None] - kj[None, :]) < window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None, None],
                      jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.where(m == -jnp.inf, 0.0, jnp.exp(m - m_new))
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    kb = k.reshape(b, hkv, nblocks, block_kv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblocks, block_kv, d).transpose(2, 0, 1, 3, 4)
    from . import flags

    if flags.UNROLL_FOR_ACCOUNTING:
        carry = (m0, l0, a0)
        for j in range(nblocks):
            carry, _ = step(carry, (kb[j], vb[j], jnp.int32(j)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (kb, vb, jnp.arange(nblocks)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    return out.reshape(b, hq, sq, d)


def sdpa(q, k, v, *, impl: str = "chunked", causal: bool = True,
         window: int | None = None, kv_len: Any = None,
         scale: float | None = None) -> jax.Array:
    if impl == "skip":
        # Accounting aid: removes the attention mixing entirely so the
        # dry-run can isolate attention's flop/byte contribution by
        # subtraction (flash-adjusted roofline).  The value path is kept
        # live (seq-mean of v, broadcast to q's shape) so projections and
        # shapes survive while the O(S²) mixing disappears.
        group = q.shape[1] // k.shape[1]
        vbar = jnp.mean(v.astype(jnp.float32), axis=2, keepdims=True)
        vbar = jnp.repeat(vbar, group, axis=1).astype(q.dtype)
        return jnp.broadcast_to(vbar, q.shape) + 0 * q
    if impl == "flash":
        # Pallas kernel needs static kv_len; only full (non-cache) path.
        # With a tuner flagged on (--kernel-autotune) the (block_q,
        # block_kv) tile is a measured winner instead of the analytic
        # plan_attention prior.
        assert kv_len is None or isinstance(kv_len, int)
        from . import flags

        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    scale=scale, tuner=flags.KERNEL_TUNER)
    if impl == "naive":
        assert kv_len is None or isinstance(kv_len, int)
        return kref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)
    return attention_chunked(q, k, v, causal=causal, window=window,
                             kv_len=kv_len, scale=scale)


# ---------------------------------------------------------------------------
# attention module
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig, *, cross: bool = False) -> dict:
    kg = cm.KeyGen(key)
    d, hd = cfg.d_model, cfg.head_dim_
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": cm.linear_init(kg(), d, cfg.n_heads * hd, bias=cfg.qkv_bias,
                             dtype=dt),
        "wk": cm.linear_init(kg(), d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                             dtype=dt),
        "wv": cm.linear_init(kg(), d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                             dtype=dt),
        "wo": cm.linear_init(kg(), cfg.n_heads * hd, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = cm.rmsnorm_init(hd, dt)
        p["k_norm"] = cm.rmsnorm_init(hd, dt)
    if cross:
        p["kv_norm"] = cm.rmsnorm_init(d, dt)
    return p


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def self_attention(p: dict, x: jax.Array, cfg: ArchConfig, *,
                   positions: jax.Array, window: int | None,
                   impl: str = "chunked",
                   cache: dict | None = None,
                   cache_pos: Any = None) -> tuple[jax.Array, dict | None]:
    """x: (B, S, d).  Without a cache: full causal self-attention (train /
    one-shot prefill).  With a cache: write K/V at ``cache_pos`` (ring
    slot for SWA) and attend against the whole cache (decode / chunked
    prefill)."""
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    q = _split_heads(cm.linear(p["wq"], x, cd), cfg.n_heads, hd)
    k = _split_heads(cm.linear(p["wk"], x, cd), cfg.n_kv_heads, hd)
    v = _split_heads(cm.linear(p["wv"], x, cd), cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = cm.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = cm.rope_angles(positions, hd, cfg.rope_theta)
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)

    if cache is None:
        out = sdpa(q, k, v, impl=impl, causal=True, window=window)
        new_cache = None
    else:
        s_cache = cache["k"].shape[2]
        slot = cache_pos % s_cache if window is not None else cache_pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
        new_cache = {"k": ck, "v": cv}
        kv_len = jnp.minimum(cache_pos + q.shape[2], s_cache)
        if window is None:
            # Decode attention is a memory-bound GEMV over the cache; the
            # einsum-softmax form partitions cleanly when the cache seq dim
            # is sharded (long-context SP), unlike a kv-block scan.
            out = _cache_attention(q, ck, cv, kv_len, causal=True)
        else:
            # Ring buffer: every populated slot is within the window by
            # construction (cache length == window).
            out = _ring_attention(q, ck, cv, cache_pos, s_cache)
    out = cm.linear(p["wo"], _merge_heads(out), cd)
    return out, new_cache


def _cache_attention(q, ck, cv, kv_len, *, causal: bool):
    """Einsum-softmax attention over a (possibly sharded) KV cache.
    q: (B, Hq, Sq, D); ck/cv: (B, Hkv, S, D); kv_len: valid slot count."""
    b, hq, sq, d = q.shape
    hkv, s_cache = ck.shape[1], ck.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32) / (d ** 0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, ck.astype(jnp.float32))
    kj = jnp.arange(s_cache)[None, :]
    mask = kj < kv_len
    if causal:
        qi = (kv_len - sq) + jnp.arange(sq)[:, None]
        mask = mask & (qi >= kj)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, cv.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def _ring_attention(q, ck, cv, cache_pos, s_cache):
    """Decode attention over a ring-buffer SWA cache: softmax over the
    populated slots (≤ window of them); permutation-invariant since RoPE
    phases were applied at write time."""
    b, hq, sq, d = q.shape
    hkv = ck.shape[1]
    group = hq // hkv
    n_valid = jnp.minimum(cache_pos + sq, s_cache)
    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32) / (d ** 0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, ck.astype(jnp.float32))
    mask = jnp.arange(s_cache)[None, :] < n_valid
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, cv.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def cross_attention(p: dict, x: jax.Array, kv_feats: jax.Array,
                    cfg: ArchConfig, *, impl: str = "chunked"
                    ) -> jax.Array:
    """x: (B, S, d) queries; kv_feats: (B, T, d) frontend embeddings
    (image patches / conditioning frames).  Non-causal, no RoPE."""
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    feats = cm.rmsnorm(p["kv_norm"], kv_feats.astype(cd), cfg.norm_eps)
    q = _split_heads(cm.linear(p["wq"], x, cd), cfg.n_heads, hd)
    k = _split_heads(cm.linear(p["wk"], feats, cd), cfg.n_kv_heads, hd)
    v = _split_heads(cm.linear(p["wv"], feats, cd), cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = cm.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    out = sdpa(q, k, v, impl="chunked" if impl == "flash" else impl,
               causal=False)
    return cm.linear(p["wo"], _merge_heads(out), cd)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               window: int | None, dtype) -> dict:
    s = min(window, max_len) if window is not None else max_len
    shape = (batch, cfg.n_kv_heads, s, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
