"""The language model: embedding → layer stack → head, for all assigned
families (dense / MoE / hybrid / SSM / VLM / audio).

The layer stack is organised as ``lax.scan`` over *pattern groups*: the
block pattern (e.g. zamba2's mamba×5 + shared-attn, llama-vision's
attn×3 + cross + attn) repeats every ``period`` layers, so parameters are
stacked over ``n_layers // period`` groups and the group body is compiled
once — essential for 88-layer dry-run compiles.  Leftover layers (when
period ∤ n_layers) run unscanned.

Two entry points:
  * ``forward``      — full-sequence (train / one-shot prefill), scan path.
  * ``forward_cached`` — serve path with per-layer caches (KV ring buffers,
    SSM/xLSTM states), python loop over layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention, common as cm, ffn, flags, mamba2, moe, xlstm

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ArchConfig, kind: str) -> dict:
    kg = cm.KeyGen(key)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": cm.rmsnorm_init(d, dt)}
    if kind == "attn":
        p["attn"] = attention.init(kg(), cfg)
        p["ln2"] = cm.rmsnorm_init(d, dt)
        if cfg.n_experts:
            p["moe"] = moe.init(kg(), cfg)
        elif cfg.d_ff:
            p["ffn"] = ffn.init(kg(), cfg)
    elif kind == "cross_attn":
        p["xattn"] = attention.init(kg(), cfg, cross=True)
        p["ln2"] = cm.rmsnorm_init(d, dt)
        if cfg.d_ff:
            p["ffn"] = ffn.init(kg(), cfg)
    elif kind == "shared_attn":
        # Per-use projection only; the block itself is shared (top level).
        p["proj"] = cm.linear_init(kg(), d, d, dtype=dt)
    elif kind == "mamba2":
        p["mamba"] = mamba2.init(kg(), cfg)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(kg(), cfg)
    elif kind == "slstm":
        p["slstm"] = xlstm.slstm_init(kg(), cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    kg = cm.KeyGen(key)
    dt = jnp.dtype(cfg.param_dtype)
    kinds = cfg.layer_kinds()
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period

    params: dict[str, Any] = {
        "embed": cm.embedding_init(kg(), cfg.vocab_size, cfg.d_model, dt),
        "final_norm": cm.rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.linear_init(kg(), cfg.d_model,
                                           cfg.vocab_size, dtype=dt)
    if "shared_attn" in cfg.block_pattern:
        params["shared_block"] = {
            "ln1": cm.rmsnorm_init(cfg.d_model, dt),
            "attn": attention.init(kg(), cfg),
            "ln2": cm.rmsnorm_init(cfg.d_model, dt),
            "ffn": ffn.init(kg(), cfg),
        }

    # stacked group params: blocks[f"pos{i}"] has leading dim n_groups
    if n_groups:
        blocks = {}
        for i, kind in enumerate(cfg.block_pattern):
            per_group = [_block_init(kg(), cfg, kind) for _ in range(n_groups)]
            blocks[f"pos{i}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_group) \
                if n_groups > 1 else jax.tree.map(
                    lambda x: x[None], per_group[0])
        params["blocks"] = blocks
    params["tail"] = [
        _block_init(kg(), cfg, kinds[n_groups * period + j])
        for j in range(cfg.n_layers - n_groups * period)]
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_shared(shared: dict, proj: dict, x, cfg: ArchConfig, *,
                  positions, window, impl, cache=None, cache_pos=None):
    h = cm.rmsnorm(shared["ln1"], x, cfg.norm_eps)
    a, new_cache = attention.self_attention(
        shared["attn"], h, cfg, positions=positions, window=window,
        impl=impl, cache=cache, cache_pos=cache_pos)
    h = h + a
    h2 = cm.rmsnorm(shared["ln2"], h, cfg.norm_eps)
    h = h + ffn.apply(shared["ffn"], h2, cfg)
    return x + cm.linear(proj, h, jnp.dtype(cfg.compute_dtype)), new_cache


def _apply_block(kind: str, p: dict, x, cfg: ArchConfig, *,
                 positions, window, impl, shared=None, frontend_feats=None,
                 cache=None, cache_pos=None):
    """Returns (x, aux_loss, new_cache)."""
    cd = jnp.dtype(cfg.compute_dtype)
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if kind == "attn":
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_cache = attention.self_attention(
            p["attn"], h, cfg, positions=positions, window=window,
            impl=impl, cache=cache, cache_pos=cache_pos)
        x = x + a
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, aux = moe.apply(p["moe"], h, cfg)
            x = x + y
        elif cfg.d_ff:
            x = x + ffn.apply(p["ffn"], h, cfg)
    elif kind == "cross_attn":
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        feats = frontend_feats
        if feats is None:
            raise ValueError("cross_attn block needs frontend_feats")
        x = x + attention.cross_attention(p["xattn"], h, feats.astype(cd),
                                          cfg, impl=impl)
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.d_ff:
            x = x + ffn.apply(p["ffn"], h, cfg)
    elif kind == "shared_attn":
        x, new_cache = _apply_shared(
            shared, p["proj"], x, cfg, positions=positions,
            window=window, impl=impl, cache=cache, cache_pos=cache_pos)
    elif kind == "mamba2":
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, new_cache = mamba2.apply(p["mamba"], h, cfg, state=cache)
        x = x + y
    elif kind == "mlstm":
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, new_cache = xlstm.mlstm_apply(p["mlstm"], h, cfg, state=cache)
        x = x + y
    elif kind == "slstm":
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, new_cache = xlstm.slstm_apply(p["slstm"], h, cfg, state=cache)
        x = x + y
    else:
        raise ValueError(kind)
    return x, aux, new_cache


def _logits(params, cfg: ArchConfig, x):
    cd = jnp.dtype(cfg.compute_dtype)
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].astype(cd).T
    return cm.linear(params["lm_head"], x, cd)


# ---------------------------------------------------------------------------
# full-sequence forward (train / one-shot prefill)
# ---------------------------------------------------------------------------

def forward(params: dict, batch: dict, cfg: ArchConfig, *,
            attn_impl: str = "chunked", window: int | None = None,
            remat: bool = False) -> tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B, S) int32, optional "frontend_feats"}.
    Returns (logits (B, S, V), aux_loss)."""
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    cd = jnp.dtype(cfg.compute_dtype)
    window = window if window is not None else cfg.attn_window
    x = flags.constrain(cm.embed(params["embed"], tokens, cd))
    positions = jnp.arange(s)
    feats = batch.get("frontend_feats")
    period = len(cfg.block_pattern)

    def group_body(carry, group_params):
        h, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            h, a, _ = _apply_block(
                kind, group_params[f"pos{i}"], h, cfg,
                positions=positions, window=window, impl=attn_impl,
                shared=params.get("shared_block"), frontend_feats=feats)
            h = flags.constrain(h)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    aux0 = jnp.zeros((), jnp.float32)
    if "blocks" in params:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        aux = aux0
    kinds = cfg.layer_kinds()
    n_groups = (cfg.n_layers // period) if "blocks" in params else 0
    for j, p in enumerate(params["tail"]):
        kind = kinds[n_groups * period + j]
        x, a, _ = _apply_block(
            kind, p, x, cfg, positions=positions, window=window,
            impl=attn_impl, shared=params.get("shared_block"),
            frontend_feats=feats)
        x = flags.constrain(x)
        aux = aux + a
    return _logits(params, cfg, x), aux


def loss_fn(params: dict, batch: dict, cfg: ArchConfig, *,
            attn_impl: str = "chunked", remat: bool = False) -> jax.Array:
    logits, aux = forward(params, batch, cfg, attn_impl=attn_impl,
                          remat=remat)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    # SPMD-friendly NLL for vocab-sharded logits: logsumexp and a masked
    # sum both reduce over the sharded vocab dim (psum), no sharded gather.
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0),
                     axis=-1)
    return jnp.mean(lse - picked) + AUX_LOSS_WEIGHT * aux


# ---------------------------------------------------------------------------
# cached forward (serve: chunked prefill + decode)
# ---------------------------------------------------------------------------

def _layer_params(params, cfg: ArchConfig, layer: int):
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period if "blocks" in params else 0
    if layer < n_groups * period:
        g, i = divmod(layer, period)
        return jax.tree.map(lambda a: a[g], params["blocks"][f"pos{i}"])
    return params["tail"][layer - n_groups * period]


def forward_cached(params: dict, tokens: jax.Array, caches: list, pos,
                   cfg: ArchConfig, *, window: int | None = None,
                   frontend_feats=None, logit_index=None,
                   all_logits: bool = False
                   ) -> tuple[jax.Array, list]:
    """tokens: (B, L_new); caches: per-layer state list; pos: scalar count
    of tokens already cached.  Returns (logits of one position, caches):
    the last position by default, or ``logit_index`` (int or traced
    scalar) — the serving scheduler pads prefill chunks to a bucketed
    length and needs the logits of the last *real* token.  With
    ``all_logits`` the head runs over every fed position (``(B, L_new,
    V)``) — the speculative verify needs the model's prediction after
    each draft token in one batched forward."""
    cd = jnp.dtype(cfg.compute_dtype)
    window = window if window is not None else cfg.attn_window
    x = flags.constrain(cm.embed(params["embed"], tokens, cd))
    l_new = tokens.shape[1]
    positions = pos + jnp.arange(l_new)
    kinds = cfg.layer_kinds()
    new_caches = []
    for layer, kind in enumerate(kinds):
        p = _layer_params(params, cfg, layer)
        x, _, nc = _apply_block(
            kind, p, x, cfg, positions=positions, window=window,
            impl="chunked", shared=params.get("shared_block"),
            frontend_feats=frontend_feats,
            cache=caches[layer], cache_pos=pos)
        x = flags.constrain(x)
        new_caches.append(nc)
    if all_logits:
        xs = x
    elif logit_index is None:
        xs = x[:, -1:]
    else:
        xs = jax.lax.dynamic_slice_in_dim(x, logit_index, 1, axis=1)
    return _logits(params, cfg, xs), new_caches


def init_caches(cfg: ArchConfig, batch: int, max_len: int, *,
                window: int | None = None, dtype=None) -> list:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    window = window if window is not None else cfg.attn_window
    caches: list = []
    for kind in cfg.layer_kinds():
        if kind in ("attn", "shared_attn"):
            caches.append(attention.init_cache(
                cfg, batch, max_len, window=window, dtype=dtype))
        elif kind == "cross_attn":
            caches.append(None)  # image KV recomputed from feats
        elif kind == "mamba2":
            caches.append(mamba2.init_state(cfg, batch, dtype))
        elif kind == "mlstm":
            caches.append(xlstm.mlstm_init_state(cfg, batch))
        elif kind == "slstm":
            caches.append(xlstm.slstm_init_state(cfg, batch))
        else:
            raise ValueError(kind)
    return caches
