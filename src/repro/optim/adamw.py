"""AdamW, pure-functional, fp32 moments regardless of param dtype."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # bf16 moments halve optimizer HBM (perf-iteration lever; update math
    # stays fp32 — moments are cast at rest only)
    moment_dtype: str = "float32"
    # keep bf16 working params + a sharded fp32 master in the optimizer
    # state: FSDP weight all-gathers move bf16 (half the wire bytes) while
    # updates stay full precision (perf-iteration lever)
    master_weights: bool = False


def init_state(params: Any, cfg: AdamWConfig | None = None) -> dict:
    dt = jnp.dtype(cfg.moment_dtype) if cfg else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg is not None and cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads: Any, state: dict, params: Any, cfg: AdamWConfig,
           lr: jax.Array | float | None = None) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, master):
        base = master if master is not None else p.astype(jnp.float32)
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * delta
        return (new_master.astype(p.dtype), m_new.astype(mdt),
                v_new.astype(mdt), new_master)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_master = (jax.tree.leaves(state["master"])
                   if "master" in state else [None] * len(flat_p))
    out = [upd(p, g, m, v, mw) for p, g, m, v, mw in
           zip(flat_p, flat_g, flat_m, flat_v, flat_master,
               strict=True)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(
            tdef, [o[3] for o in out])
    return new_p, new_state, {"grad_norm": gnorm}
