from .adamw import AdamWConfig, global_norm, init_state, update
from .schedule import constant, warmup_cosine

__all__ = ["AdamWConfig", "init_state", "update", "global_norm",
           "warmup_cosine", "constant"]
