"""Training driver.

Real entry point for CPU/TPU runs (reduced configs train end-to-end on
this container; full configs need the real pod):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt /tmp/run1

Features wired here: acc-planned microbatching, fault-tolerant driver
(checkpoint/restart), optional int8-compressed DP, elastic restart.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCH_NAMES, get_config
from ..core import strict
from ..core.adaptive import adaptive
from ..core.executor import MeshExecutor
from ..data import make_batch
from ..models import lm
from ..optim import AdamWConfig, adamw
from ..runtime import FaultTolerantTrainer
from ..train import make_train_step
from . import mesh as mesh_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=None,
                    help="grad-accum microbatches (default: acc decides)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-cal-cache", action="store_true",
                    help="do not persist T0/t_iter calibrations to disk")
    ap.add_argument("--cal-cache-dir", default=None,
                    help="calibration cache dir (default: "
                         "$REPRO_CAL_CACHE_DIR or ~/.cache/repro-acc)")
    ap.add_argument("--kernel-autotune", action="store_true",
                    help="measured Pallas blocks for model-layer kernels "
                         "(winners persist in the calibration cache, "
                         "shared with serving)")
    ap.add_argument("--explain-decisions", action="store_true",
                    help="dump the ExecutionModel decision trace: the "
                         "train plan and kernel-block choices with the "
                         "policy and inputs that produced them")
    ap.add_argument("--strict", action="store_true",
                    help="strict runtime mode (same guards as "
                         "REPRO_STRICT=1): the train step runs with "
                         "implicit device->host transfers disallowed")
    args = ap.parse_args()

    if args.strict:
        strict.enable()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"devices={len(jax.devices())}")

    # One in-memory cache view per process: save() rewrites the whole
    # file, so two views over one path would clobber each other's writes.
    from ..core.calibration import CalibrationCache

    cache = CalibrationCache() if args.no_cal_cache \
        else CalibrationCache.persistent(args.cal_cache_dir)

    accum = args.accum
    if accum is None:
        # acc decision over this host's devices
        from ..configs.base import ShapeConfig
        from ..core.acc import AdaptiveCoreChunk
        from ..train.autotune import choose_plan

        mesh = mesh_lib.make_host_mesh()
        # acc rides on the executor; calibrations persist across runs
        mexec = adaptive(MeshExecutor(mesh), AdaptiveCoreChunk(cache=cache))
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        plan = choose_plan(cfg, shape, mexec)
        accum = plan.accum
        print(f"acc plan: data_parallel={plan.data_parallel} accum={accum} "
              f"(N_C raw {plan.decision.n_cores_unclamped:.1f})")

    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw.init_state(params)
    if args.kernel_autotune:
        from ..models import flags
        from ..train.autotune import make_kernel_tuner

        # Global flag, read at jit-trace time: the one compiled train
        # step bakes in the measured blocks (same store serving reads).
        flags.KERNEL_TUNER = make_kernel_tuner(cache)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=accum, remat=True))

    def data_iter():
        i = 0
        while True:
            yield make_batch(cfg, args.batch, args.seq, kind="train", seed=i)
            i += 1

    trainer = FaultTolerantTrainer(step_fn, args.ckpt,
                                   save_every=args.save_every)
    t0 = time.time()
    params, opt_state, log = trainer.run(params, opt_state, data_iter(),
                                         num_steps=args.steps)
    dt = time.time() - t0
    for i, m in enumerate(log):
        if i % args.log_every == 0 or i == len(log) - 1:
            print(f"step {i:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f}")
    tok_s = args.batch * args.seq * len(log) / dt
    print(f"done: {len(log)} steps in {dt:.1f}s ({tok_s:.0f} tok/s)")
    if args.explain_decisions:
        from ..core.model import ExecutionModel

        # The acc plan and any kernel-autotune searches share the engine
        # bound to this process's calibration cache.
        print(ExecutionModel.of(cache).explain())


if __name__ == "__main__":
    main()
