import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/roofline artifacts.

This file — and ONLY this file — forces 512 host platform devices (the
two lines above run before any jax import).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b \
        --shape train_4k --mesh both
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis import roofline
from ..configs import ARCH_NAMES, SHAPES, get_config, get_shape, \
    shape_applicable
from ..core.acc import AdaptiveCoreChunk
from ..core.adaptive import adaptive
from ..core.executor import MeshExecutor
from ..models import lm
from ..optim import adamw
from ..serve import engine as serve_engine
from ..train import autotune, train_loop
from . import mesh as mesh_lib
from . import sharding

DEFAULT_OUT = "runs/dryrun"


def _mesh(multi_pod: bool):
    m = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    return m, ("multi" if multi_pod else "single"), \
        (512 if multi_pod else 256)


def _serve_cfg(cfg):
    # serving: bf16 weights, no optimizer state
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def _long_window(cfg, shape):
    if shape.name == "long_500k" and cfg.long_context_window:
        return cfg.long_context_window
    return cfg.attn_window


def _act_sharding(cfg, mesh, shape, seq_shard: bool = False):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    bspec = sharding.batch_specs(cfg, mesh, shape.global_batch)["tokens"]
    # seq_shard: Megatron-SP style — the residual stream's sequence dim is
    # sharded over 'model' between blocks, turning row-parallel activation
    # all-reduces into reduce-scatter + all-gather pairs (half the bytes).
    seq_ax = "model" if seq_shard else None
    return NamedSharding(mesh, P(bspec[0], seq_ax, None))


def _dp_extent(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def lower_train(cfg, shape, mesh, *, accum: int, attn_impl: str,
                remat: bool, moment_dtype: str = "float32",
                accum_dtype: str = "float32", seq_shard: bool = False,
                moe_local: bool = False, bf16_params: bool = False,
                moe_ff2d: bool = False):
    from ..models import flags

    if bf16_params:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    opt_cfg = adamw.AdamWConfig(moment_dtype=moment_dtype,
                                master_weights=bf16_params)
    step = train_loop.make_train_step(cfg, opt_cfg, accum=accum,
                                      attn_impl=attn_impl, remat=remat,
                                      accum_dtype=accum_dtype)
    params_s = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(functools.partial(adamw.init_state, cfg=opt_cfg),
                           params_s)
    from ..data import input_specs

    batch_s = input_specs(cfg, shape)
    pspec = sharding.param_specs(params_s, mesh, moe_ff2d=moe_ff2d)
    ospec = sharding.opt_specs(pspec, master=bf16_params)
    bspec = sharding.batch_specs(cfg, mesh, shape.global_batch)
    bspec = {k: bspec[k] for k in batch_s}
    in_sh = (sharding.to_shardings(mesh, pspec),
             sharding.to_shardings(mesh, ospec),
             sharding.to_shardings(mesh, bspec))
    out_sh = (in_sh[0], in_sh[1], None)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    with flags.activation_sharding(_act_sharding(cfg, mesh, shape,
                                                 seq_shard)), \
            flags.moe_dispatch_groups(_dp_extent(mesh) if moe_local
                                      else None):
        return jitted.lower(params_s, opt_s, batch_s)


def lower_prefill(cfg, shape, mesh, *, attn_impl: str):
    cfg = _serve_cfg(cfg)
    window = _long_window(cfg, shape)
    step = serve_engine.make_prefill_step(cfg, window=window,
                                          attn_impl=attn_impl)
    params_s = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    from ..data import input_specs

    batch_s = input_specs(cfg, shape)
    pspec = sharding.param_specs(params_s, mesh)
    bspec = sharding.batch_specs(cfg, mesh, shape.global_batch)
    bspec = {k: bspec[k] for k in batch_s}
    jitted = jax.jit(step,
                     in_shardings=(sharding.to_shardings(mesh, pspec),
                                   sharding.to_shardings(mesh, bspec)))
    from ..models import flags

    with flags.activation_sharding(_act_sharding(cfg, mesh, shape)):
        return jitted.lower(params_s, batch_s)


def lower_decode(cfg, shape, mesh, *, cache_seq_model: bool = False,
                 serve_no_fsdp: bool = False):
    cfg = _serve_cfg(cfg)
    window = _long_window(cfg, shape)
    step = serve_engine.make_decode_step(cfg, window=window)
    params_s = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    caches_s = jax.eval_shape(
        lambda: lm.init_caches(cfg, shape.global_batch, shape.seq_len,
                               window=window))
    cache_len = min(window, shape.seq_len) if window else shape.seq_len
    tokens_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)

    pspec = sharding.param_specs(
        params_s, mesh, drop_axes=("data",) if serve_no_fsdp else ())
    cspec = sharding.cache_specs(cfg, mesh, shape.global_batch, cache_len,
                                 seq_over_model=cache_seq_model)
    bspec_all = sharding.batch_specs(cfg, mesh, shape.global_batch)
    from jax.sharding import PartitionSpec as P

    feats_s = None
    feats_sh = None
    if cfg.frontend == "vision":  # cross-attn layers read image embeddings
        feats_s = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
        feats_sh = sharding.to_shardings(
            mesh, bspec_all.get("frontend_feats", P()))

    in_sh = (sharding.to_shardings(mesh, pspec),
             sharding.to_shardings(mesh, cspec),
             sharding.to_shardings(mesh, bspec_all["tokens"]),
             sharding.to_shardings(mesh, P()),
             feats_sh)
    out_sh = (None, in_sh[1])
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    from ..models import flags

    with flags.activation_sharding(_act_sharding(cfg, mesh, shape)):
        return jitted.lower(params_s, caches_s, tokens_s, pos_s, feats_s)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             out_dir: str, use_acc: bool = True, accum: int | None = None,
             attn_impl: str = "chunked", remat: bool = True,
             moment_dtype: str = "float32", accum_dtype: str = "float32",
             seq_shard: bool = False, cache_seq_model: bool = False,
             moe_local: bool = False, serve_no_fsdp: bool = False,
             bf16_params: bool = False, moe_ff2d: bool = False,
             verbose: bool = True, tag: str = "",
             acc: AdaptiveCoreChunk | None = None,
             plan_only: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    mesh, mesh_name, chips = _mesh(multi_pod)
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        _save(out_dir, cell_id, rec)
        if verbose:
            print(f"SKIP  {arch:22s} {shape_name:12s} {mesh_name:6s} {reason}")
        return rec
    if plan_only and shape.kind != "train":
        rec = {"cell": cell_id, "status": "skipped",
               "reason": "plan-only runs cover train cells (acc plan)"}
        _save(out_dir, cell_id, rec)
        if verbose:
            print(f"SKIP  {arch:22s} {shape_name:12s} {mesh_name:6s} "
                  f"plan-only")
        return rec

    t0 = time.time()
    try:
        if shape.kind == "train":
            if accum is None:
                if use_acc:
                    mexec = adaptive(
                        MeshExecutor(mesh, data_axes=("pod", "data")), acc)
                    plan = autotune.choose_plan(cfg, shape, mexec)
                    accum = plan.accum
                else:
                    accum = 1
            if plan_only:
                # acc-plan sweep without the production-mesh compile:
                # exercises the ExecutionModel end to end (profile →
                # engine decision → divisor snapping → trace) and is
                # what CI runs to produce the decision-trace artifact.
                rec = {"cell": cell_id, "status": "planned",
                       "accum": accum, "plan_s": time.time() - t0}
                _save(out_dir, cell_id, rec)
                if verbose:
                    print(f"PLAN  {arch:22s} {shape_name:12s} "
                          f"{mesh_name:6s} accum={accum}")
                return rec
            lowered = lower_train(cfg, shape, mesh, accum=accum,
                                  attn_impl=attn_impl, remat=remat,
                                  moment_dtype=moment_dtype,
                                  accum_dtype=accum_dtype,
                                  seq_shard=seq_shard,
                                  moe_local=moe_local,
                                  bf16_params=bf16_params,
                                  moe_ff2d=moe_ff2d)
        elif shape.kind == "prefill":
            lowered = lower_prefill(cfg, shape, mesh, attn_impl=attn_impl)
        else:
            lowered = lower_decode(cfg, shape, mesh,
                                   cache_seq_model=cache_seq_model,
                                   serve_no_fsdp=serve_no_fsdp)
        compiled = lowered.compile()
        t1 = time.time()
        if shape.kind == "decode":
            # the decode path is loop-free (python layer loop, einsum
            # attention): cost analysis needs no calibration
            report = roofline.analyze(compiled, cfg=cfg, shape=shape,
                                      mesh_name=mesh_name, chips=chips)
        else:
            report = _calibrated_report(
                compiled, cfg, shape, mesh, mesh_name, chips,
                attn_impl=attn_impl, remat=remat,
                moment_dtype=moment_dtype, accum_dtype=accum_dtype,
                seq_shard=seq_shard, moe_local=moe_local,
                bf16_params=bf16_params, moe_ff2d=moe_ff2d)
        rec = report.to_dict()
        rec.update(cell=cell_id, status="ok", accum=accum,
                   compile_s=t1 - t0,
                   memory_analysis=str(compiled.memory_analysis()))
        _save(out_dir, cell_id, rec)
        if verbose:
            print(f"OK    {roofline.format_row(report)}  "
                  f"(compile {t1-t0:.0f}s, accum={accum})")
        return rec
    except Exception as e:  # noqa: BLE001 - report and continue the sweep
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        _save(out_dir, cell_id, rec)
        if verbose:
            print(f"FAIL  {arch:22s} {shape_name:12s} {mesh_name:6s} "
                  f"{type(e).__name__}: {str(e)[:160]}")
        return rec


def _calibrated_report(full_compiled, cfg, shape, mesh, mesh_name, chips, *,
                       attn_impl: str, remat: bool,
                       moment_dtype: str = "float32",
                       accum_dtype: str = "float32",
                       seq_shard: bool = False, moe_local: bool = False,
                       bf16_params: bool = False,
                       moe_ff2d: bool = False):
    """Loop-calibrated roofline (see roofline.analyze_calibrated): lower
    the cell with one pattern group and with zero layers, inner loops
    unrolled, accum=1 (grad accumulation conserves total flops)."""
    from ..models import flags

    period = len(cfg.block_pattern)
    multiplier = cfg.n_layers / period
    cfg_a = dataclasses.replace(cfg, n_layers=period)
    cfg_b = dataclasses.replace(cfg, n_layers=0)
    with flags.unroll_for_accounting():
        if shape.kind == "train":
            comp_a = lower_train(cfg_a, shape, mesh, accum=1,
                                 attn_impl=attn_impl, remat=remat,
                                 moment_dtype=moment_dtype,
                                 accum_dtype=accum_dtype,
                                 seq_shard=seq_shard,
                                 moe_local=moe_local,
                                 bf16_params=bf16_params,
                                 moe_ff2d=moe_ff2d).compile()
            comp_b = lower_train(cfg_b, shape, mesh, accum=1,
                                 attn_impl=attn_impl, remat=remat,
                                 moment_dtype=moment_dtype,
                                 accum_dtype=accum_dtype,
                                 seq_shard=seq_shard,
                                 moe_local=moe_local,
                                 bf16_params=bf16_params,
                                 moe_ff2d=moe_ff2d).compile()
        else:
            comp_a = lower_prefill(cfg_a, shape, mesh,
                                   attn_impl=attn_impl).compile()
            comp_b = lower_prefill(cfg_b, shape, mesh,
                                   attn_impl=attn_impl).compile()
    return roofline.analyze_calibrated(
        full_compiled, comp_a, comp_b, multiplier, cfg=cfg, shape=shape,
        mesh_name=mesh_name, chips=chips)


def _save(out_dir: str, cell_id: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-acc", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--attn-impl", default="chunked",
                    choices=["chunked", "naive", "flash", "skip"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--accum-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--seq-shard", action="store_true",
                    help="Megatron-SP activation constraint (seq over "
                         "'model' between blocks)")
    ap.add_argument("--cache-seq-model", action="store_true",
                    help="decode KV cache: shard seq dim over 'model'")
    ap.add_argument("--moe-local", action="store_true",
                    help="group-local MoE dispatch (no cross-shard "
                         "capacity buffers)")
    ap.add_argument("--moe-ff2d", action="store_true",
                    help="weight-stationary expert TP: expert ff over "
                         "both mesh axes, d unsharded (no gathers)")
    ap.add_argument("--bf16-params", action="store_true",
                    help="bf16 working params + sharded fp32 master "
                         "in the optimizer (halves FSDP gather bytes)")
    ap.add_argument("--serve-no-fsdp", action="store_true",
                    help="decode: drop 'data' from weight specs (no "
                         "per-token FSDP gathers; weights must fit TP)")
    ap.add_argument("--plan-only", action="store_true",
                    help="acc plans only, no lower/compile (fast; the "
                         "CI path for the decision-trace artifact)")
    ap.add_argument("--explain-decisions", action="store_true",
                    help="dump the ExecutionModel decision trace and "
                         "write it to <out>/decision_trace.txt")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    # One acc object (one calibration cache, one ExecutionModel engine)
    # for the whole sweep, so every cell's plan lands in a single
    # explainable trace.
    acc = AdaptiveCoreChunk()
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, out_dir=args.out,
                               use_acc=not args.no_acc, accum=args.accum,
                               attn_impl=args.attn_impl,
                               remat=not args.no_remat,
                               moment_dtype=args.moment_dtype,
                               accum_dtype=args.accum_dtype,
                               seq_shard=args.seq_shard,
                               cache_seq_model=args.cache_seq_model,
                               moe_local=args.moe_local,
                               serve_no_fsdp=args.serve_no_fsdp,
                               bf16_params=args.bf16_params,
                               moe_ff2d=args.moe_ff2d,
                               tag=args.tag, acc=acc,
                               plan_only=args.plan_only)
                n_ok += rec["status"] in ("ok", "planned")
                n_skip += rec["status"] == "skipped"
                n_fail += rec["status"] == "error"
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} failed")
    if args.explain_decisions:
        from ..core.model import ExecutionModel

        text = ExecutionModel.of(acc.cache).explain()
        os.makedirs(args.out, exist_ok=True)
        trace_path = os.path.join(args.out, "decision_trace.txt")
        with open(trace_path, "w") as f:
            f.write(text + "\n")
        print(text)
        print(f"decision trace written to {trace_path}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
