"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): single-pod (16, 16) = (data, model) — one v5e
pod slice of 256 chips — or multi-pod (2, 16, 16) = (pod, data, model),
512 chips.  The dry-run launcher forces 512 host platform devices before
any jax import; real launches get real device topologies.
"""
from __future__ import annotations

import jax
import numpy as np


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` (and make_mesh's ``axis_types=``) only
    exist from jax 0.5; 0.4.x builds the mesh without them — every axis
    is Auto there anyway, which is exactly what we'd request.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_serve_mesh(data: int, model: int):
    """A (data, model) serving mesh over the first ``data*model`` devices.

    Serving meshes are allowed to occupy a *subset* of the host's devices
    (``jax.make_mesh`` wants the full set), so this reshapes an explicit
    device slice: 'data' carries the data-parallel slot-group replicas,
    'model' the tensor-parallel shards within each replica.
    """
    data, model = int(data), int(model)
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got ({data}, {model})")
    devs = jax.devices()
    need = data * model
    if need > len(devs):
        raise ValueError(
            f"mesh ({data}, {model}) needs {need} devices, "
            f"host has {len(devs)}")
    arr = np.asarray(devs[:need]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def n_data_replicas(mesh) -> int:
    """Number of data-parallel replicas (product of the non-'model'
    batch axes): the serve pool's slot dim splits into this many groups."""
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU runs)."""
    devs = jax.devices()
    n = len(devs)
    mp = model_parallel
    while n % mp:
        mp -= 1
    arr = np.asarray(devs).reshape(n // mp, mp)
    return jax.sharding.Mesh(arr, ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_axes(mesh):
    """The PartitionSpec entry for a global-batch dimension."""
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)
