"""Sharding rules: logical PartitionSpecs for params, optimizer state,
batches and serve caches, with shape-aware divisibility fallbacks.

Strategy (DESIGN.md §6):
  * weights — Megatron TP over 'model' (column: out-dim, row: in-dim)
    combined with FSDP over 'data' on the other dim; 'pod' is pure DP.
  * MoE expert weights — stacked (E, ·, ·), expert dim replicated, inner
    dims 2-D sharded (expert tensor parallelism).
  * batches — batch dim over ('pod','data') when divisible.
  * serve caches — batch over data axes when divisible, else sequence
    (long-context SP); KV heads over 'model' when divisible, else head_dim.

Any axis that does not divide its dim is dropped (never a compile error);
the dry-run memory analysis shows the consequences and the perf loop
iterates on them.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import mesh as mesh_lib

# (path-suffix regex, spec for the TRAILING dims of the leaf)
_RULES: list[tuple[str, tuple]] = [
    (r"(wq|wk|wv)/w$", ("data", "model")),
    (r"(wq|wk|wv)/b$", ("model",)),
    (r"wo/w$", ("model", "data")),
    (r"(w_up|w_gate)/w$", ("data", "model")),
    (r"w_down/w$", ("model", "data")),
    (r"router/w$", (None, None)),
    (r"moe/(w_up|w_gate)$", (None, "data", "model")),
    (r"moe/w_down$", (None, "model", "data")),
    (r"in_proj/w$", ("data", "model")),
    (r"out_proj/w$", ("model", "data")),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"w_if/w$", ("data", None)),
    (r"w_o/w$", ("data", "model")),
    (r"w_zifo/w$", ("data", "model")),
    (r"proj/w$", ("data", "model")),
    (r"embed/table$", ("model", None)),
    (r"lm_head/w$", ("data", "model")),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _fit(shape: tuple[int, ...], trailing: tuple, axis_sizes: dict) -> P:
    """Pad the trailing spec to ndim and drop non-dividing axes."""
    spec: list = [None] * (len(shape) - len(trailing)) + list(trailing)
    out = []
    for dim, ax in zip(shape, spec, strict=True):
        if ax is None:
            out.append(None)
            continue
        size = axis_sizes.get(ax)
        if size and dim % size == 0 and dim >= size:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# Weight-stationary expert rules (--moe-ff2d): expert ff sharded over BOTH
# mesh axes, d unsharded — no weight or dispatch-buffer gathers at all
# (the contraction-dim 'data' sharding of the FSDP rules is what forces
# GSPMD to regather the MoE dispatch path; see EXPERIMENTS.md §Perf).
_MOE_FF2D_RULES: list[tuple[str, tuple]] = [
    (r"moe/(w_up|w_gate)$", (None, None, ("data", "model"))),
    (r"moe/w_down$", (None, ("data", "model"), None)),
]


def _fit2(shape, trailing, axis_sizes):
    out = []
    spec = [None] * (len(shape) - len(trailing)) + list(trailing)
    for dim, ax in zip(shape, spec, strict=True):
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= axis_sizes.get(a, 1)
            out.append(ax if dim % size == 0 and dim >= size else None)
        else:
            size = axis_sizes.get(ax)
            out.append(ax if size and dim % size == 0 and dim >= size
                       else None)
    return P(*out)


def param_specs(params: Any, mesh, drop_axes: tuple = (),
                moe_ff2d: bool = False) -> Any:
    """``drop_axes``: remove these mesh axes from weight specs — e.g.
    serving drops 'data' (no optimizer state to shard; FSDP gathers per
    decoded token would dominate the step).  ``moe_ff2d``: use the
    weight-stationary expert rules."""
    axis_sizes = dict(mesh.shape)
    rules = (_MOE_FF2D_RULES + _RULES) if moe_ff2d else _RULES
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        for pat, trailing in rules:
            if re.search(pat, ps):
                t = tuple(None if (not isinstance(a, tuple)
                                   and a in drop_axes) else a
                          for a in trailing)
                specs.append(_fit2(leaf.shape, t, axis_sizes))
                break
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(tdef, specs)


def opt_specs(param_spec_tree: Any, master: bool = False) -> dict:
    out = {"m": param_spec_tree, "v": param_spec_tree, "step": P()}
    if master:
        out["master"] = param_spec_tree
    return out


def batch_specs(cfg: ArchConfig, mesh, global_batch: int) -> dict:
    bax = mesh_lib.batch_axes(mesh)
    n_data = 1
    for a in mesh_lib.data_axes(mesh):
        n_data *= mesh.shape[a]
    bspec = bax if (global_batch % n_data == 0 and global_batch >= n_data) \
        else None
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.frontend == "vision":
        out["frontend_feats"] = P(bspec, None, None)
    return out


def _heads_spec(n_heads: int, head_dim: int, axis_sizes: dict
                ) -> tuple[Any, Any]:
    """(heads_axis, head_dim_axis): prefer sharding KV heads over 'model';
    fall back to head_dim (contraction dim → psum) when heads don't
    divide."""
    m = axis_sizes.get("model", 1)
    if n_heads % m == 0 and n_heads >= m:
        return "model", None
    if head_dim % m == 0 and head_dim >= m:
        return None, "model"
    return None, None


def cache_specs(cfg: ArchConfig, mesh, global_batch: int,
                cache_len: int, *, seq_over_model: bool = False) -> list:
    """Per-layer cache PartitionSpecs mirroring lm.init_caches.

    ``seq_over_model``: shard the KV cache sequence dim over 'model'
    instead of KV heads / head_dim — for MQA/GQA archs whose kv heads
    don't divide the model axis, this turns the decode attention psum
    from O(B·H·S) logits into O(B·H·D) partials + tiny softmax stats
    (perf-iteration lever, §Perf cell 2)."""
    axis_sizes = dict(mesh.shape)
    bax = mesh_lib.batch_axes(mesh)
    n_data = 1
    for a in mesh_lib.data_axes(mesh):
        n_data *= mesh.shape[a]
    batch_ok = global_batch % n_data == 0 and global_batch >= n_data
    bspec = bax if batch_ok else None
    # When the batch can't occupy the data axes, shard the cache sequence
    # dim instead (long-context sequence parallelism).
    data_ax = "data" if not batch_ok else None
    hax, dax = _heads_spec(cfg.n_kv_heads, cfg.head_dim_, axis_sizes)

    specs: list = []
    for kind in cfg.layer_kinds():
        if kind in ("attn", "shared_attn"):
            if seq_over_model and cache_len % axis_sizes.get("model", 1) == 0:
                kv = P(bspec, None, "model", None)
            else:
                seq_ax = data_ax if (data_ax and cache_len % axis_sizes.get(
                    "data", 1) == 0) else None
                kv = P(bspec, hax, seq_ax, dax)
            specs.append({"k": kv, "v": kv})
        elif kind == "cross_attn":
            specs.append(None)
        elif kind == "mamba2":
            h = cfg.ssm_heads
            hm = "model" if h % axis_sizes.get("model", 1) == 0 else None
            conv_ch = cfg.d_inner + 2 * cfg.ssm_state
            cm_ = "model" if conv_ch % axis_sizes.get("model", 1) == 0 else None
            specs.append({"ssm": P(bspec, hm, None, None),
                          "conv": P(bspec, None, cm_)})
        elif kind == "mlstm":
            pm = ("model" if cfg.ssm_head_dim % axis_sizes.get("model", 1) == 0
                  else None)
            specs.append({"mem": P(bspec, None, pm, None)})
        elif kind == "slstm":
            dm = ("model" if cfg.d_inner % axis_sizes.get("model", 1) == 0
                  else None)
            specs.append({"c": P(bspec, dm), "n": P(bspec, dm),
                          "m": P(bspec, dm)})
        else:
            raise ValueError(kind)
    return specs


def paged_cache_specs(cfg: ArchConfig, mesh, n_slots: int,
                      max_len: int) -> list:
    """Per-layer PartitionSpecs for ``serve/kv_cache.PagedKVCachePool``.

    Attention layers are flat token-major page stores
    ``(n_pages * page_size, H_kv, D)``.  The page dim is **replicated
    over the data axes**: prefix sharing means any lane may map any
    page, so sharding rows over 'data' would turn every lane's
    page-table gather into a cross-replica all-gather of its whole
    logical row.  Replicating keeps gathers local; the cost is one
    small per-step all-gather of the ``(n_slots, H_kv, D)`` lane
    updates scattered back into the shared store — O(B·H·D), the same
    order as the decode attention partials.  KV heads shard over
    'model' exactly as in ``cache_specs`` (head_dim fallback).

    Recurrent layer state stays slot-major and keeps the ``cache_specs``
    treatment (slot dim over data axes when divisible)."""
    axis_sizes = dict(mesh.shape)
    hax, dax = _heads_spec(cfg.n_kv_heads, cfg.head_dim_, axis_sizes)
    slot_specs = cache_specs(cfg, mesh, n_slots, max_len)
    specs: list = []
    for kind, slot_spec in zip(cfg.layer_kinds(), slot_specs,
                               strict=True):
        if kind in ("attn", "shared_attn"):
            kv = P(None, hax, dax)
            specs.append({"k": kv, "v": kv})
        else:
            specs.append(slot_spec)
    return specs


def to_shardings(mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def serve_shardings(cfg: ArchConfig, mesh, params, n_slots: int,
                    max_len: int) -> tuple[Any, Any]:
    """(param_shardings, cache_shardings) for mesh-sharded serving.

    Weights are tensor-parallel over 'model' only — serving drops the
    'data' axis from the weight rules (no optimizer state to shard, and
    FSDP gathers per decoded token would dominate the step), so each
    data replica holds a full TP copy.  The slot pool's cache specs come
    from the same ``cache_specs`` rules as the training/dry-run path:
    the slot (batch) dim splits over the data axes when ``n_slots``
    divides, KV heads over 'model'.
    """
    pspecs = param_specs(params, mesh, drop_axes=("data",))
    cspecs = cache_specs(cfg, mesh, n_slots, max_len)
    return to_shardings(mesh, pspecs), to_shardings(mesh, cspecs)
