"""Launch layer: meshes, sharding rules, dry-run, train/serve drivers."""
