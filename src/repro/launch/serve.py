"""Serving driver: the continuous-batching scheduler under a request load.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 8 --prompt-len 32 --new-tokens 16 --slots 4

Requests (synthetic prompts of jittered lengths) go through the
``ServeScheduler``: admission into cache slots, acc-decided prefill
chunking/batching per tick, slot-batched decode.  Reports throughput and
per-request latency percentiles.  T0/t_iter calibrations persist across
runs under ``--cal-cache-dir`` unless ``--no-cal-cache``.

``--frontend`` switches to the asyncio ``ServeFrontend`` path: a seeded
open-loop arrival trace (serve/loadgen.py) replayed with per-request
token streaming, deadline shedding and adaptive admission — the report
leads with SLO-goodput and the deadline-miss rate instead of raw
throughput.  ``--print-launch-profile`` emits the recommended process
environment (shell-sourceable) for production runs.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax

from ..configs import ARCH_NAMES, get_config
from ..core.acc import AdaptiveCoreChunk
from ..core.adaptive import adaptive
from ..core import strict
from ..core.calibration import CalibrationCache
from ..core.executor import SequentialExecutor
from ..data import make_batch
from ..models import lm
from ..serve import (ServeEngine, ServeFrontend, ServeScheduler, SLOModel,
                     heavy_tailed_trace, materialize, percentile)

# Recommended process environment for serving runs — (var, value, why).
# Source it with:  eval "$(python -m repro.launch.serve --print-launch-profile)"
# The malloc and logging lines follow the launch scripts of production
# JAX training rigs (SNIPPETS §1-2); the compilation-cache lines keep
# warm-start latency flat across process restarts, which matters for a
# serving tier that redeploys often.
LAUNCH_PROFILE = (
    ("LD_PRELOAD", "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
     "tcmalloc: faster malloc under slot-pool churn (skip if absent)"),
    ("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000",
     "silence large-alloc warnings for cache-pool buffers"),
    ("TF_CPP_MIN_LOG_LEVEL", "4",
     "quiet XLA/TSL startup chatter on the serving console"),
    ("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=true",
     "threaded CPU backend for host-fallback ops"),
    ("JAX_COMPILATION_CACHE_DIR", "~/.cache/repro-jax-cache",
     "persist compiled executables across restarts"),
    ("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1",
     "cache anything that took >=1s to compile"),
)


def print_launch_profile() -> None:
    for var, value, why in LAUNCH_PROFILE:
        print(f"export {var}={value}  # {why}")


def serve_cross_attention(cfg, params, args, executor, tuner=None) -> None:
    """Cross-attention (VLM) archs carry per-request frontend feats the
    scheduler does not model — they serve through the engine's lock-step
    batch path instead (kernel tuning applies there too)."""
    batch = make_batch(cfg, args.requests, args.prompt_len, kind="prefill")
    engine = ServeEngine(cfg, params, batch=args.requests,
                         max_len=args.prompt_len + args.new_tokens + 1,
                         executor=executor, kernel_tuner=tuner)
    t0 = time.monotonic()
    out = engine.generate(batch["tokens"], args.new_tokens,
                          frontend_feats=batch.get("frontend_feats"))
    dt = time.monotonic() - t0
    gen = int(out.shape[0] * out.shape[1])
    print(f"arch={cfg.name} (cross-attention: lock-step batch path) "
          f"requests={args.requests}")
    print(f"generated {gen} tokens in {dt:.2f}s ({gen / dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


def serve_frontend(sched: ServeScheduler, args) -> None:
    """Async front-end replay: a seeded heavy-tailed open-loop trace
    with streaming consumers and per-request SLO deadlines — the mode
    whose headline is goodput, not throughput."""
    slo = SLOModel()
    trace = heavy_tailed_trace(
        args.requests, rate_rps=args.rate_rps,
        max_prompt=max(args.prompt_len, 8), max_new=args.new_tokens,
        seed=args.seed, slo=slo)
    mat = materialize(trace, sched.cfg.vocab_size, seed=args.seed)
    frontend = ServeFrontend(sched, max_queue=args.max_queue)

    async def replay():
        async with frontend:
            t0 = time.monotonic()

            async def one(tr, prompt):
                delay = tr.arrival_s - (time.monotonic() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                deadline = None if tr.deadline_s is None \
                    else t0 + tr.deadline_s
                stream = await frontend.submit(prompt, tr.new_tokens,
                                               deadline=deadline, wait=True)
                async for _tok in stream:
                    pass

            await asyncio.gather(*(one(tr, p) for tr, p in mat))
            return time.monotonic() - t0

    makespan = asyncio.run(replay())
    stats = frontend.stats()
    recs = list(frontend.records.values())
    ttfts = [r.first_token_at - r.submitted_at for r in recs
             if r.first_token_at is not None]
    goodput = stats["goodput_tokens"] / makespan if makespan else 0.0
    eligible = max(stats["submitted"] - stats["cancelled"], 1)
    print(f"arch={sched.cfg.name} frontend requests={args.requests} "
          f"slots={sched.pool.n_slots} admission={sched.admission} "
          f"ticks={len(sched.trace)}")
    print(f"SLO-goodput {goodput:.1f} tok/s over {makespan:.2f}s | "
          f"completed {stats['completed']} "
          f"(in-SLO {stats['completed_in_slo']}) shed {stats['shed']} "
          f"rejected {stats['rejected']} | "
          f"miss rate {stats['missed'] / eligible:.1%} | "
          f"ttft p50={percentile(ttfts, 50) * 1e3:.0f}ms "
          f"p99={percentile(ttfts, 99) * 1e3:.0f}ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=False)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--no-cal-cache", action="store_true",
                    help="do not persist T0/t_iter calibrations to disk")
    ap.add_argument("--cal-cache-dir", default=None,
                    help="calibration cache dir (default: "
                         "$REPRO_CAL_CACHE_DIR or ~/.cache/repro-acc)")
    ap.add_argument("--kernel-autotune", action="store_true",
                    help="measured Pallas blocks for prefill/decode "
                         "(winners persist in the calibration cache)")
    ap.add_argument("--mesh", default="off",
                    help="mesh-sharded serving: 'DATA,MODEL' device "
                         "counts (e.g. '4,2': 4 data-parallel replicas "
                         "x 2-way tensor parallel), or 'off' (single "
                         "device).  Slots round up to a multiple of the "
                         "replica count; per-device batch width becomes "
                         "a serve_mesh_batch engine decision")
    ap.add_argument("--dispatch-depth", default="auto",
                    help="fused decode tokens per device dispatch: "
                         "'auto' (adaptive serve_dispatch_depth decision, "
                         "default), an integer (fixed depth), or 'off' "
                         "(legacy per-tick decode, one round-trip per "
                         "token)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: fixed-size pages + on-device "
                         "page tables instead of contiguous per-slot "
                         "rows, with copy-on-write prefix reuse (a hot "
                         "system prompt is prefilled once and shared "
                         "read-only) and chunked prefill interleaved "
                         "into the fused decode loop.  Requires a fused "
                         "--dispatch-depth")
    ap.add_argument("--page-size", default="auto",
                    help="tokens per KV page: 'auto' (serve_page_size "
                         "engine decision from the Overhead-Law prior, "
                         "default) or an integer")
    ap.add_argument("--prefill-interleave", default="auto",
                    help="max prefill chunk-ops interleaved per fused "
                         "decode tick: 'auto' (serve_prefill_interleave "
                         "engine decision, default) or an integer")
    ap.add_argument("--speculate", default="off",
                    help="self-speculative decoding inside the fused "
                         "loop (n-gram prompt-lookup drafts, one "
                         "batched verify, device-side rollback): 'auto' "
                         "(adaptive serve_spec_depth decision with "
                         "backoff when acceptance collapses), an "
                         "integer draft window, or 'off' (default).  "
                         "Requires a fused --dispatch-depth; output is "
                         "byte-identical to non-speculative decoding")
    ap.add_argument("--explain-decisions", action="store_true",
                    help="dump the ExecutionModel decision trace: every "
                         "serve-tick, admission and kernel-block choice "
                         "with the policy and inputs that produced it")
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the asyncio ServeFrontend: "
                         "seeded open-loop trace, streaming consumers, "
                         "SLO deadlines; reports SLO-goodput")
    ap.add_argument("--admission", choices=("greedy", "adaptive"),
                    default=None,
                    help="admission width policy (default: adaptive "
                         "with --frontend, greedy otherwise)")
    ap.add_argument("--rate-rps", type=float, default=40.0,
                    help="--frontend arrival rate (requests/s)")
    ap.add_argument("--max-queue", type=int, default=128,
                    help="--frontend bounded admission queue")
    ap.add_argument("--seed", type=int, default=0,
                    help="--frontend trace seed (arrivals, lengths, "
                         "prompt tokens)")
    ap.add_argument("--strict", action="store_true",
                    help="strict runtime mode (same guards as "
                         "REPRO_STRICT=1): donated cache pools poison "
                         "on read-after-donation and the serve tick "
                         "disallows implicit device->host transfers")
    ap.add_argument("--print-launch-profile", action="store_true",
                    help="print the recommended serving environment "
                         "(shell-sourceable) and exit")
    args = ap.parse_args()

    if args.strict:
        strict.enable()
    if args.print_launch_profile:
        print_launch_profile()
        return
    if args.arch is None:
        ap.error("--arch is required (unless --print-launch-profile)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    cache = CalibrationCache() if args.no_cal_cache \
        else CalibrationCache.persistent(args.cal_cache_dir)
    acc = AdaptiveCoreChunk(cache=cache)
    executor = adaptive(SequentialExecutor(), acc)
    tuner = None
    if args.kernel_autotune:
        from ..train.autotune import make_kernel_tuner

        tuner = make_kernel_tuner(cache)   # shared store with acc/train
    if "cross_attn" in cfg.layer_kinds():
        serve_cross_attention(cfg, params, args, executor, tuner)
        if tuner is not None:
            print(f"kernel autotune: {tuner.searches} measured searches, "
                  f"{tuner.cache_hits} persisted winners reused")
        if args.explain_decisions:
            from ..core.model import ExecutionModel

            print(ExecutionModel.of(cache).explain())
        return
    max_len = args.prompt_len + args.new_tokens + 1
    depth = args.dispatch_depth.strip().lower()
    depth = None if depth in ("off", "none", "0") else \
        depth if depth == "auto" else int(depth)
    admission = args.admission or \
        ("adaptive" if args.frontend else "greedy")
    mesh, n_slots = None, args.slots
    if args.mesh.strip().lower() not in ("off", "none", ""):
        from .mesh import make_serve_mesh, n_data_replicas

        data, model_par = (int(x) for x in args.mesh.split(","))
        mesh = make_serve_mesh(data, model_par)
        reps = n_data_replicas(mesh)
        if n_slots % reps:      # slot dim must split into replica groups
            n_slots = -(-n_slots // reps) * reps
            print(f"mesh: rounding --slots {args.slots} up to {n_slots} "
                  f"({reps} data replicas)")
        print(f"mesh {data}x{model_par} over {mesh.devices.size} of "
              f"{len(jax.devices())} {jax.default_backend()} devices | "
              f"{reps} replicas x {n_slots // reps} slots")
    page_size = args.page_size.strip().lower()
    page_size = "auto" if page_size == "auto" else int(page_size)
    interleave = args.prefill_interleave.strip().lower()
    interleave = "auto" if interleave == "auto" else int(interleave)
    speculate = args.speculate.strip().lower()
    speculate = None if speculate in ("off", "none", "0") else \
        speculate if speculate == "auto" else int(speculate)
    sched = ServeScheduler(cfg, params, n_slots=n_slots, max_len=max_len,
                           executor=executor, kernel_tuner=tuner,
                           dispatch_depth=depth, admission=admission,
                           mesh=mesh, paged=args.paged,
                           page_size=page_size,
                           prefill_interleave=interleave,
                           speculate=speculate)
    sched.warmup()

    def print_paged_stats():
        if sched._spec:
            st = sched.spec_stats()
            print(f"speculate: depth={st['depth']} "
                  f"verifies={st['verifies']} emitted={st['emitted']} | "
                  f"{st['tokens_per_verify']:.2f} tok/verify "
                  f"(acceptance {st['acceptance_rate']:.1%})")
        if not args.paged:
            return
        st = sched.pool.prefix_stats()
        print(f"paged: page_size={st['page_size']} pages "
              f"{st['pages_in_use']}/{st['n_pages']} | prefix hits "
              f"{st['prefix_hits']}/{st['prefix_lookups']} avoided "
              f"{st['prefill_tokens_avoided']} tok | cow "
              f"{st['cow_copies']} | prefill stall "
              f"{sched.prefill_stall_s * 1e3:.0f}ms")

    if args.frontend:
        serve_frontend(sched, args)
        print_paged_stats()
        if args.explain_decisions:
            model = sched.decision_model()
            if model is not None:
                print(model.explain())
        if not args.no_cal_cache:
            cache.save()
            print(f"calibration cache: {cache.path} "
                  f"({len(cache)} entries)")
        return

    # Jittered prompt lengths: requests join and leave the batch at
    # different ticks — the continuous-batching case, not lock-step.
    tokens = make_batch(cfg, args.requests, args.prompt_len,
                        kind="prefill")["tokens"]
    t_start = time.monotonic()
    rids = []
    for i in range(args.requests):
        plen = max(args.prompt_len - (i % 3) * (args.prompt_len // 4), 1)
        rids.append(sched.submit(tokens[i, :plen],
                                 max_new_tokens=args.new_tokens))
    outs = sched.run_until_idle()
    dt = time.monotonic() - t_start

    lats = [sched.requests[rid].finished_at - sched.requests[rid].arrival
            for rid in rids]
    ttfts = [sched.requests[rid].first_token_at - sched.requests[rid].arrival
             for rid in rids]
    gen = sum(len(outs[rid]) for rid in rids)
    print(f"arch={cfg.name} requests={args.requests} slots={sched.pool.n_slots} "
          f"ticks={len(sched.trace)} dispatch-depth={args.dispatch_depth} "
          f"({sched.decode_dispatches} decode dispatches, "
          f"{sched.host_roundtrips} host round-trips, "
          f"{gen and sched.host_overhead_s / gen * 1e3:.2f}ms host "
          f"overhead/token)")
    print(f"generated {gen} tokens in {dt:.2f}s ({gen / dt:.1f} tok/s) | "
          f"latency p50={percentile(lats, 50) * 1e3:.0f}ms "
          f"p95={percentile(lats, 95) * 1e3:.0f}ms | "
          f"ttft p50={percentile(ttfts, 50) * 1e3:.0f}ms")
    print("sample:", outs[rids[0]])
    print_paged_stats()
    if tuner is not None:
        print(f"kernel autotune: {tuner.searches} measured searches, "
              f"{tuner.cache_hits} persisted winners reused")
    if args.explain_decisions:
        # acc, scheduler ticks and the kernel tuner all share the engine
        # bound to `cache`, so one dump attributes every decision made
        # this run — serve ticks, train-style plans, kernel blocks.
        model = sched.decision_model()
        if model is not None:
            print(model.explain())
    if not args.no_cal_cache:
        cache.save()   # flush any write-throttled smoothing updates
        print(f"calibration cache: {cache.path} ({len(cache)} entries)")


if __name__ == "__main__":
    main()
