"""Serving driver: batched prefill + decode with the acc-chunked engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCH_NAMES, get_config
from ..core.adaptive import adaptive
from ..core.executor import SequentialExecutor
from ..data import make_batch
from ..models import lm
from ..serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, args.batch, args.prompt_len, kind="prefill")
    feats = batch.get("frontend_feats")

    engine = ServeEngine(cfg, params, batch=args.batch,
                         max_len=args.prompt_len + args.new_tokens,
                         executor=adaptive(SequentialExecutor()))
    t0 = time.time()
    out = engine.generate(batch["tokens"], args.new_tokens,
                          frontend_feats=feats)
    t1 = time.time()
    print(f"arch={cfg.name} prefill {args.prompt_len} + decode "
          f"{args.new_tokens} tok in {t1-t0:.2f}s "
          f"({args.batch*args.new_tokens/(t1-t0):.1f} decode tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
