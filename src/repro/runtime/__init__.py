from .elastic import elastic_plan, reshard, surviving_mesh
from .ft import FaultTolerantTrainer, SimulatedFailure
from .stragglers import mitigation_table, straggler_step_time

__all__ = ["FaultTolerantTrainer", "SimulatedFailure", "surviving_mesh",
           "elastic_plan", "reshard", "mitigation_table",
           "straggler_step_time"]
