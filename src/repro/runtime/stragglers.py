"""Straggler mitigation analysis: why C = 8 chunks per core.

On an SPMD mesh there is no cross-chip work stealing, so over-
decomposition is the available lever: with each device's step split into
C chunks (grad-accum microbatches / Pallas grid steps), a straggling chunk
delays the step by ~(slowdown-1)/C of a device-step instead of
(slowdown-1).  ``straggler_step_time`` quantifies this with the calibrated
SimMachine (greedy rebalancing models XLA's async collectives absorbing
slack); benchmarks/fig_straggler.py plots it.
"""
from __future__ import annotations

import numpy as np


def straggler_step_time(*, n_devices: int, chunks_per_device: int,
                        slowdown: float, straggler_fraction: float = 0.02,
                        seed: int = 0) -> float:
    """Relative step time (1.0 = no stragglers) when a fraction of chunk
    executions run ``slowdown``× slower, with C-deep over-decomposition."""
    rng = np.random.RandomState(seed)
    n_chunks = n_devices * chunks_per_device
    base = 1.0 / chunks_per_device  # chunk duration in device-step units
    durations = np.full(n_chunks, base)
    slow = rng.rand(n_chunks) < straggler_fraction
    durations[slow] *= slowdown
    # static assignment: chunk i -> device i % n_devices (no stealing)
    per_dev = np.zeros(n_devices)
    for i, d in enumerate(durations):
        per_dev[i % n_devices] += d
    return float(per_dev.max())


def mitigation_table(slowdown: float = 5.0, n_devices: int = 256,
                     cs=(1, 2, 4, 8, 16, 32)) -> dict[int, float]:
    return {c: straggler_step_time(n_devices=n_devices,
                                   chunks_per_device=c,
                                   slowdown=slowdown)
            for c in cs}
