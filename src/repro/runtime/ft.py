"""Fault tolerance: checkpoint/restart training driver.

``FaultTolerantTrainer`` wraps any (params, opt_state, batch) → ... step:
periodic async checkpoints, restart-from-latest on failure, bounded retry.
Failures are injected in tests via ``failure_hook`` (the CPU container has
no real node loss); on a real cluster the same hook is where the
coordinator's health signal lands.  On restart the trainer re-resolves its
device pool — if devices were lost, runtime/elastic.py recomputes the
data-parallel width with the paper's Eq. 7 and the checkpoint is resharded
onto the surviving mesh.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Iterator

from ..checkpoint import checkpointer
from ..core import strict

log = logging.getLogger("repro.ft")


class SimulatedFailure(RuntimeError):
    """Injected node/step failure (tests)."""


@dataclasses.dataclass
class FaultTolerantTrainer:
    train_step: Callable
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    failure_hook: Callable[[int], None] | None = None

    def run(self, params: Any, opt_state: Any, data: Iterator,
            num_steps: int, *, start_step: int = 0) -> tuple[Any, Any, list]:
        saver = checkpointer.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        metrics_log: list = []
        restarts = 0
        step = start_step

        # resume if a checkpoint exists
        path = checkpointer.latest(self.ckpt_dir)
        if path is not None:
            (params, opt_state), step = checkpointer.restore(
                path, (params, opt_state))
            log.info("resumed from %s at step %d", path, step)

        while step < num_steps:
            batch = next(data)
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                # Strict mode disallows implicit host syncs inside the
                # step itself; the metrics float() below runs outside
                # the guard — logging is allowed to block, the step not.
                with strict.hot_dispatch_guard():
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, batch)
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                saver.wait()
                path = checkpointer.latest(self.ckpt_dir)
                if path is None:
                    log.warning("failure before first checkpoint; "
                                "restarting from step 0 state")
                    continue
                (params, opt_state), step = checkpointer.restore(
                    path, (params, opt_state))
                log.warning("restart %d from %s at step %d",
                            restarts, path, step)
                continue
            step += 1
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            if step % self.save_every == 0:
                saver.save_async(step, (params, opt_state))
        saver.wait()
        checkpointer.save(self.ckpt_dir, step, (params, opt_state),
                          keep=self.keep)
        return params, opt_state, metrics_log
