"""Elastic scaling: re-plan parallelism for a changed device pool.

The paper's Eq. 7 is reused verbatim as the elastic-scaling rule: given
the surviving devices, the acc model recomputes how many the workload can
use at the target efficiency, and the checkpoint is resharded onto the new
mesh.  Straggler mitigation is the C=8 over-decomposition (each device's
work is split into C chunks, so one slow step costs 1/C of a device-step,
and XLA can overlap the accumulation loop with collectives) — quantified
in runtime/stragglers.py.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.acc import AdaptiveCoreChunk
from ..core.adaptive import adaptive
from ..core.cost_model import WorkloadProfile
from ..core.executor import MeshExecutor


def surviving_mesh(n_devices: int | None = None, *,
                   model_parallel: int = 1) -> jax.sharding.Mesh:
    """Largest regular (data, model) mesh over the currently visible
    devices (after a loss, the pool shrinks; keep the mesh rectangular)."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(n_devices, len(devs))
    mp = model_parallel
    while n % mp:
        mp -= 1
    dp = n // mp
    arr = np.asarray(devs[: dp * mp]).reshape(dp, mp)
    return jax.sharding.Mesh(arr, ("data", "model"))


def elastic_plan(cfg_profile: WorkloadProfile, n_elements: int,
                 mesh: jax.sharding.Mesh,
                 acc: AdaptiveCoreChunk | None = None):
    """acc decision over the surviving mesh (Eq. 7 as the scaling rule)."""
    mexec = adaptive(MeshExecutor(mesh, data_axes=("data",)), acc)
    return mexec.params.decide_for_profile(mexec, cfg_profile, n_elements)


def reshard(tree: Any, mesh: jax.sharding.Mesh, spec_tree: Any = None) -> Any:
    """Move a (restored) pytree onto a new mesh.  ``spec_tree`` may be a
    single PartitionSpec, a matching pytree, or None (replicate)."""
    if spec_tree is None:
        spec_tree = P()
    if isinstance(spec_tree, P):
        sharding = NamedSharding(mesh, spec_tree)
        return jax.device_put(tree, sharding)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(tree, shardings)
