"""End-to-end training driver example: a reduced-family model for a few
hundred steps on CPU with acc microbatching, fault-tolerant
checkpointing, and a (simulated) mid-run failure.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import time

import jax

from repro.configs import get_config
from repro.data import make_batch
from repro.models import init_params
from repro.optim import AdamWConfig, adamw
from repro.runtime import FaultTolerantTrainer, SimulatedFailure
from repro.train import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
args = ap.parse_args()

shutil.rmtree(args.ckpt, ignore_errors=True)
cfg = get_config(args.arch).reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt_state = adamw.init_state(params)
n = sum(x.size for x in jax.tree.leaves(params))
print(f"training reduced {cfg.name}: {n/1e6:.2f}M params, "
      f"{args.steps} steps, batch {args.batch}x{args.seq}")

step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), accum=2,
                                  remat=True))


def data_iter():
    # small fixed corpus (4 batches, cycled): the loss drop demonstrates a
    # working end-to-end optimisation path (memorisation)
    corpus = [make_batch(cfg, args.batch, args.seq, kind="train", seed=i)
              for i in range(4)]
    i = 0
    while True:
        yield corpus[i % len(corpus)]
        i += 1


# inject one failure at 60% to demonstrate checkpoint/restart
fail_at = {int(args.steps * 0.6)}


def failure_hook(step):
    if step in fail_at:
        fail_at.discard(step)
        print(f"!! simulated node failure at step {step} — recovering")
        raise SimulatedFailure(str(step))


trainer = FaultTolerantTrainer(step_fn, args.ckpt, save_every=25,
                               failure_hook=failure_hook)
t0 = time.time()
params, opt_state, log = trainer.run(params, opt_state, data_iter(),
                                     num_steps=args.steps)
dt = time.time() - t0
for i in range(0, len(log), max(len(log) // 10, 1)):
    print(f"  step {i:4d}: loss {log[i]['loss']:.4f}")
print(f"  step {len(log)-1:4d}: loss {log[-1]['loss']:.4f}")
print(f"done in {dt:.1f}s "
      f"({args.batch*args.seq*len(log)/dt:.0f} tok/s incl. restart)")
assert log[-1]["loss"] < log[0]["loss"], "loss should improve"
