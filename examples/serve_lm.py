"""Serving example: batched requests through the engine — acc-chunked
prefill, then batched greedy decode (and a VLM request with stub image
embeddings).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs import get_config
from repro.data import make_batch
from repro.models import init_params
from repro.serve import ServeEngine

# --- text LM ---------------------------------------------------------------
cfg = get_config("h2o-danube-1.8b").reduced()   # SWA family: ring KV cache
params = init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, batch=4, max_len=96)

prompts = make_batch(cfg, 4, 24, kind="prefill", seed=0)["tokens"]
t0 = time.time()
out = engine.generate(prompts, n_new=16)
dt = time.time() - t0
print(f"[{cfg.name}] 4 requests x 24-token prompts -> 16 new tokens "
      f"in {dt:.2f}s ({4*16/dt:.1f} tok/s)")
print("  request 0:", out[0].tolist())

# --- VLM request (stub vision frontend: precomputed patch embeddings) ------
vcfg = get_config("llama-3.2-vision-11b").reduced()
vparams = init_params(jax.random.PRNGKey(1), vcfg)
vbatch = make_batch(vcfg, 2, 16, kind="prefill", seed=2)
vengine = ServeEngine(vcfg, vparams, batch=2, max_len=48)
vout = vengine.generate(vbatch["tokens"], n_new=8,
                        frontend_feats=vbatch["frontend_feats"])
print(f"[{vcfg.name}] 2 image+text requests -> 8 tokens each")
print("  request 0:", vout[0].tolist())
