"""Quickstart: the adaptive core/chunk executor in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro import algorithms as alg
from repro.core import (AdaptiveCoreChunk, HostParallelExecutor, par, seq)

# 1. A parallel algorithm with an execution policy — the C++17 API shape.
x = jnp.asarray(np.random.rand(1_000_000).astype(np.float32))
d_seq = alg.adjacent_difference(seq, x)

# 2. Bind the adaptive_core_chunk_size (acc) execution-parameters object:
#    measure_iteration / processing_units_count / get_chunk_size now run
#    the paper's Overhead-Law model at the first invocation.
host = HostParallelExecutor()
acc = AdaptiveCoreChunk(efficiency=0.95, chunks_per_core=8)
policy = par.on(host).with_(acc)
d_acc = alg.adjacent_difference(policy, x)
np.testing.assert_allclose(np.asarray(d_seq), np.asarray(d_acc), rtol=1e-5)

# 3. Inspect the decision the model made for this workload.
t_iter = acc.measure_iteration(
    host, lambda s, n: alg.adjacent_difference(seq, x[s:s + n]),
    x.shape[0], key="demo")
decision = acc.decide(host, t_iter, x.shape[0])
print(f"T0 (measured empty-task)   : {decision.t0*1e6:9.2f} us")
print(f"t_iter (measured)          : {decision.t_iter*1e9:9.3f} ns/elem")
print(f"N_C  (Eq. 7, clamped)      : {decision.n_cores}")
print(f"chunk (Eq. 10, T_m floor)  : {decision.chunk_elems} elems "
      f"({decision.n_chunks} chunks)")
print(f"predicted speedup          : {decision.predicted_speedup:5.2f}x "
      f"@ {decision.predicted_efficiency*100:.0f}% efficiency")

# 4. The same model drives the LM stack: microbatching for a train step.
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.executor import MeshExecutor
from repro.launch.mesh import make_host_mesh
from repro.train.autotune import choose_plan

cfg = get_config("qwen3-0.6b")
plan = choose_plan(cfg, ShapeConfig("demo", 4096, 256, "train"),
                   MeshExecutor(make_host_mesh()))
print(f"\nLM autotune for {cfg.name} @ train_4k: "
      f"data_parallel={plan.data_parallel}, accum={plan.accum}, "
      f"microbatch={plan.microbatch} seqs")
