"""Quickstart: the adaptive core/chunk executor in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro import algorithms as alg
from repro.core import (AdaptiveCoreChunk, HostParallelExecutor, adaptive,
                        par, seq, when_all)

# 1. A parallel algorithm with an execution policy — the C++17 API shape.
x = jnp.asarray(np.random.rand(1_000_000).astype(np.float32))
d_seq = alg.adjacent_difference(seq, x)

# 2a. v2, one word: wrap any executor in adaptive() and the paper's
#     Overhead-Law model (measure_iteration / processing_units_count /
#     get_chunk_size) runs behind the executor — no extra arguments.
host = HostParallelExecutor()
d_v2 = alg.adjacent_difference(par.on(adaptive(host)), x)
np.testing.assert_allclose(np.asarray(d_seq), np.asarray(d_v2), rtol=1e-5)

# 2b. Equivalent spelled with an explicit execution-parameters object
#     (.with_ is executor-property sugar: prefer(with_params, policy, acc)).
acc = AdaptiveCoreChunk(efficiency=0.95, chunks_per_core=8)
policy = par.on(host).with_(acc)
d_acc = alg.adjacent_difference(policy, x)
np.testing.assert_allclose(np.asarray(d_seq), np.asarray(d_acc), rtol=1e-5)

# 2c. The executors themselves are asynchronous: futures + continuations.
f = host.async_execute(lambda: float(x[0]))
g = host.then_execute(lambda v: v * 2, f)
outs = when_all(host.bulk_async_execute(
    lambda c: float(x[c.start]), alg.detail.make_chunks(8, 2))).result()
assert g.result() == float(x[0]) * 2 and len(outs) == 4

# 3. Inspect the decision the model made for this workload.
t_iter = acc.measure_iteration(
    host, lambda s, n: alg.adjacent_difference(seq, x[s:s + n]),
    x.shape[0], key="demo")
decision = acc.decide(host, t_iter, x.shape[0])
print(f"T0 (measured empty-task)   : {decision.t0*1e6:9.2f} us")
print(f"t_iter (measured)          : {decision.t_iter*1e9:9.3f} ns/elem")
print(f"N_C  (Eq. 7, clamped)      : {decision.n_cores}")
print(f"chunk (Eq. 10, T_m floor)  : {decision.chunk_elems} elems "
      f"({decision.n_chunks} chunks)")
print(f"predicted speedup          : {decision.predicted_speedup:5.2f}x "
      f"@ {decision.predicted_efficiency*100:.0f}% efficiency")

# 4. The same model drives the LM stack: microbatching for a train step.
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.executor import MeshExecutor
from repro.launch.mesh import make_host_mesh
from repro.train.autotune import choose_plan

cfg = get_config("qwen3-0.6b")
plan = choose_plan(cfg, ShapeConfig("demo", 4096, 256, "train"),
                   adaptive(MeshExecutor(make_host_mesh())))
print(f"\nLM autotune for {cfg.name} @ train_4k: "
      f"data_parallel={plan.data_parallel}, accum={plan.accum}, "
      f"microbatch={plan.microbatch} seqs")

# 5. The same decision drives serving: the continuous-batching scheduler
#    picks per-tick batch width and prefill chunk from the queued tokens,
#    and every chunk it runs is timed back into the calibration cache.
import jax

from repro.models import init_params
from repro.serve import ServeScheduler

from repro.core import SequentialExecutor

scfg = get_config("qwen3-0.6b").reduced()
sched = ServeScheduler(scfg, init_params(jax.random.PRNGKey(0), scfg),
                       n_slots=2, max_len=48,
                       executor=adaptive(SequentialExecutor()))
rids = [sched.submit(jnp.arange(1 + 7 * i, 13 + 7 * i) % scfg.vocab_size,
                     max_new_tokens=4) for i in range(3)]
outs = sched.run_until_idle()
print(f"\nserved {len(rids)} requests (2 slots) in {len(sched.trace)} "
      f"ticks: {[len(outs[r]) for r in rids]} tokens each")
print("adaptive chunk per tick:",
      [rec.chunk for rec in sched.trace if rec.prefill_ops])
