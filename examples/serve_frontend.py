"""Front-end example: the asyncio serving surface in one tour —
streaming consumers, a mid-stream cancellation, a deadline shed, and
backpressure, all over one scheduler with adaptive admission.

    PYTHONPATH=src python examples/serve_frontend.py
"""
import asyncio
import time

import jax

from repro.configs import get_config
from repro.core.acc import AdaptiveCoreChunk
from repro.core.adaptive import adaptive
from repro.core.executor import SequentialExecutor
from repro.data import make_batch
from repro.models import init_params
from repro.serve import ServeFrontend, ServeScheduler

cfg = get_config("qwen3-0.6b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
sched = ServeScheduler(cfg, params, n_slots=2, max_len=64,
                       executor=adaptive(SequentialExecutor(),
                                         AdaptiveCoreChunk()),
                       dispatch_depth="auto", admission="adaptive")
sched.warmup()
prompts = make_batch(cfg, 3, 16, kind="prefill", seed=0)["tokens"]


async def stream_all(fe, stream, label):
    toks = []
    async for tok in stream:
        toks.append(tok)
    rec = stream.record
    ttft_ms = 0.0 if rec.first_token_at is None \
        else (rec.first_token_at - rec.submitted_at) * 1e3
    print(f"  [{label}] {rec.status}: {len(toks)} tokens "
          f"(ttft {ttft_ms:.0f}ms)")
    return toks


async def main():
    async with ServeFrontend(sched, max_queue=4) as fe:
        # 1. Two concurrent streaming requests.
        s0 = await fe.submit(prompts[0], 12)
        s1 = await fe.submit(prompts[1][:9], 12)

        # 2. A consumer that walks away after 3 tokens: the cancel
        #    releases the cache slot mid-generation.
        s2 = await fe.submit(prompts[2][:6], 48)

        async def impatient():
            got = 0
            async for _tok in s2:
                got += 1
                if got >= 3:
                    await s2.cancel()
            print(f"  [cancel] walked away after {got} tokens "
                  f"-> {s2.record.status}")

        # 3. A request whose deadline already passed: shed before its
        #    prefill burns a slot (enforce_deadlines is on by default).
        dead = await fe.submit(prompts[0][:8], 8,
                               deadline=time.monotonic() - 1.0)

        async def doomed():
            async for _tok in dead:
                pass
            print(f"  [deadline] {dead.record.status} "
                  f"(missed={dead.record.missed})")

        await asyncio.gather(stream_all(fe, s0, "stream-0"),
                             stream_all(fe, s1, "stream-1"),
                             impatient(), doomed())
        print("  stats:", fe.stats())

print(f"[{cfg.name}] asyncio front end: 2 streams + 1 cancel + 1 shed")
asyncio.run(main())
print(f"  slot pool intact: allocations={sched.pool.allocations}, "
      f"free={sched.pool.free_slots()}/2")
