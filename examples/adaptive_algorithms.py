"""Reproduce the paper's figures as ASCII curves: speedup vs input size
for static core counts and the acc executor (calibrated machine model of
the paper's 40-core Skylake; see DESIGN.md §2 for why simulated).

    PYTHONPATH=src python examples/adaptive_algorithms.py
"""
from repro.core import (ADJACENT_DIFFERENCE, INTEL_SKYLAKE_40C, SKYLAKE_40,
                        artificial_work, t_iter_analytic)
from repro.core.model import AnalyticOverheadLaw

PRIOR = AnalyticOverheadLaw()   # the ExecutionModel's analytic prior

SIZES = [2 ** k for k in range(10, 25, 2)]


def curve(t_iter, label, sat=None):
    print(f"\n=== {label} ===")
    print(f"{'n':>10} | " + " ".join(f"{c:>7}" for c in (1, 4, 16, 40))
          + " |     acc (cores, chunk)")
    for n in SIZES:
        statics = [SKYLAKE_40.speedup(t_iter=t_iter, count=n, n_cores=c,
                                      chunks_per_core=4,
                                      saturation_cores=sat)
                   for c in (1, 4, 16, 40)]
        d = PRIOR.decide(t_iter=t_iter, count=n,
                         t0=SKYLAKE_40.t0_for(40), max_cores=40)
        s_acc = t_iter * n / SKYLAKE_40.run_decision(d, saturation_cores=sat)
        marker = "*" if s_acc >= max(statics) * 0.99 else " "
        print(f"{n:>10} | " + " ".join(f"{s:7.2f}" for s in statics)
              + f" | {s_acc:7.2f}{marker} (N_C={d.n_cores:2d}, "
              f"chunk={d.chunk_elems})")


curve(t_iter_analytic(ADJACENT_DIFFERENCE, INTEL_SKYLAKE_40C),
      "adjacent_difference (memory-bound, bw saturates ~10 cores) — Fig. 2",
      sat=10)
curve(t_iter_analytic(artificial_work(2048), INTEL_SKYLAKE_40C),
      "artificial work (compute-bound) — paper Fig. 3")
print("\n'*' = acc matches/beats the best static configuration (the "
      "paper's claim).")
